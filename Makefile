# Build/test entry points for the Rust coordinator workspace.
#
# The workspace is fully offline: all dependencies are vendored path
# crates (rust/vendor/*), so every target below works without network or
# a crates.io registry.  `make artifacts` (the Python AOT lowering) is
# only needed for the artifact-gated integration tests/benches; the
# hermetic `sim*` reference-backend paths run everywhere.

.PHONY: ci build test clippy fmt-check bench-smoke bench-smoke-fabric pool-demo fabric-demo clean

## The CI gate: release build, full test suite, clippy as errors, rustfmt.
ci: build test clippy fmt-check

build:
	cargo build --release

test:
	cargo test -q

clippy:
	cargo clippy -p origami -- -D warnings

## Formatting drift fails fast (no write; CI runs this).
fmt-check:
	cargo fmt --check

## Fast smoke of the pool-scaling bench (reference backend, no artifacts).
bench-smoke:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig14_pool_scaling

## Fast smoke of the fabric-sharing bench (asserts the ≥1.2x sharing gain).
bench-smoke-fabric:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig15_fabric_sharing

## The worker-pool demo: 4 pipelined workers vs the serial path.
pool-demo:
	cargo run --release -p origami --example pool_serving

## The multi-tenant demo: two models sharing a lane fabric + autoscaler.
fabric-demo:
	cargo run --release -p origami --example multi_model_serving

clean:
	cargo clean
