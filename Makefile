# Build/test entry points for the Rust coordinator workspace.
#
# The workspace is fully offline: all dependencies are vendored path
# crates (rust/vendor/*), so every target below works without network or
# a crates.io registry.  `make artifacts` (the Python AOT lowering) is
# only needed for the artifact-gated integration tests/benches; the
# hermetic `sim*` reference-backend paths run everywhere.

.PHONY: ci build test test-sim clippy fmt-check doc bench-smoke bench-smoke-fabric bench-smoke-slo bench-smoke-admission bench-smoke-epc bench-smoke-blinding bench-smoke-kernels bench-smoke-net bench-smoke-tracks bench-smoke-oblivious pool-demo fabric-demo net-demo clean

## The CI gate: release build, full test suite, clippy as errors, rustfmt,
## and warning-free rustdoc.
ci: build test clippy fmt-check doc

build:
	cargo build --release

test:
	cargo test -q

## The serving-simulation harness tests under a fixed seed: the fair
## queue / splitting / SLO-autoscale / admission suites replayed
## deterministically.  Override the seed to hunt seed-coupled
## assertions: `make test-sim ORIGAMI_SIM_SEED=1` (CI runs both).
ORIGAMI_SIM_SEED ?= 2019
test-sim:
	ORIGAMI_SIM_SEED=$(ORIGAMI_SIM_SEED) cargo test -q --test slo_integration --test fabric_integration --test pool_integration --test admission_integration --test cluster_integration --test scenario_catalog

clippy:
	cargo clippy -p origami -- -D warnings -D clippy::large_stack_arrays

## Formatting drift fails fast (no write; CI runs this).
fmt-check:
	cargo fmt --check

## API docs must build clean: broken intra-doc links and malformed
## rustdoc fail the build (CI's docs leg).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p origami

## Fast smoke of the pool-scaling bench (reference backend, no artifacts).
bench-smoke:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig14_pool_scaling

## Fast smoke of the fabric-sharing bench (asserts the ≥1.2x sharing gain).
bench-smoke-fabric:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig15_fabric_sharing

## Fast smoke of the SLO-autoscaling bench (asserts p95 ≤ SLO at ≥1.2x
## fewer lane-seconds than depth scaling).
bench-smoke-slo:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig16_slo_autoscale

## Fast smoke of the admission bench (asserts compliant tenants hold
## their SLO under a 10x rogue overload, with only the rogue shed).
bench-smoke-admission:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig17_admission

## Fast smoke of the EPC packing bench (asserts packed co-scheduling
## sustains ≥1 more concurrent sim224 tenant within usable EPC with
## zero paging-storm ticks, at bit-identical outputs).
bench-smoke-epc:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig18_epc_packing

## Fast smoke of the blinding-pipeline bench (asserts zero
## factor_pool_miss on a warm pool, blocked kernels bit-identical to
## naive, and ≥1.3x tier-1 p95 gain over inline blinding).
bench-smoke-blinding:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig19_blinding_pipeline

## Fast smoke of the kernel-speed bench (asserts simd kernels ≥1.5x
## Gmadds over blocked at equal threads and bit-identical to naive,
## int8 tails within tolerance with a bit-identical blinded path, and
## zero steady-state activation allocations in the arena leg).
bench-smoke-kernels:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig20_kernel_speed

## Fast smoke of the session-table bench (asserts the sharded table
## sustains ≥1M live sessions with bounded sweep p95 and beats the
## single-mutex map ≥1.2x on the 8-thread bind path).
bench-smoke-net:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig21_net_sessions

## Fast smoke of the track-routing bench (asserts a 3-node track is
## bit-identical to a single node, a mid-stream node kill migrates every
## pinned session with zero losses inside the post-kill p95 SLO, and the
## partition/heal replay is deterministic across seeds and cadences).
bench-smoke-tracks:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig22_track_routing

## Fast smoke of the data-oblivious bench (asserts oblivious serving
## bit-identical to the branchy baseline, input-independent kernel
## access traces, and the overhead multiplier consumed by the SLO
## autoscaler and the EPC packer).
bench-smoke-oblivious:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig23_oblivious

## The worker-pool demo: 4 pipelined workers vs the serial path.
pool-demo:
	cargo run --release -p origami --example pool_serving

## The multi-tenant demo: two models sharing a lane fabric + autoscaler.
fabric-demo:
	cargo run --release -p origami --example multi_model_serving

## The front-door demo: attested TCP handshake, session-keyed inference,
## epoch refresh and revocation over loopback.
net-demo:
	cargo run --release -p origami --example net_client

clean:
	cargo clean
