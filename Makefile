# Build/test entry points for the Rust coordinator workspace.
#
# The workspace is fully offline: all dependencies are vendored path
# crates (rust/vendor/*), so every target below works without network or
# a crates.io registry.  `make artifacts` (the Python AOT lowering) is
# only needed for the artifact-gated integration tests/benches; the
# hermetic `sim*` reference-backend paths run everywhere.

.PHONY: ci build test clippy bench-smoke pool-demo clean

## The CI gate: release build, full test suite, clippy as errors.
ci: build test clippy

build:
	cargo build --release

test:
	cargo test -q

clippy:
	cargo clippy -p origami -- -D warnings

## Fast smoke of the pool-scaling bench (reference backend, no artifacts).
bench-smoke:
	ORIGAMI_BENCH_FAST=1 cargo bench -p origami --bench fig14_pool_scaling

## The worker-pool demo: 4 pipelined workers vs the serial path.
pool-demo:
	cargo run --release -p origami --example pool_serving

clean:
	cargo clean
