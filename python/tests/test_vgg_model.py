"""L2 model-layer tests: topology, shapes, stage functions, manifest."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import data
from compile.model import (
    build_vgg,
    linear_layers,
    model_manifest_entry,
    partition_candidates,
    stage_fns,
)
from compile.vgg import (
    apply_linear_blinded,
    apply_linear_open,
    features_at,
    forward_full,
    forward_range,
)
from compile.inversion import features_at_ref
from compile.kernels import MOD_P, quantize_blind, quantize_weights, unblind_dequantize
from compile.kernels.blind import SCALE_X, SCALE_XW


def test_vgg16_topology():
    m = build_vgg("vgg16-32")
    kinds = [l.kind for l in m.layers]
    assert kinds.count("conv") == 13
    assert kinds.count("pool") == 5
    assert kinds.count("dense") == 3
    assert kinds[-1] == "softmax"
    # the paper's privacy-critical indices: layer 3 and 6 are max-pools
    assert m.layer(3).kind == "pool"
    assert m.layer(6).kind == "pool"


def test_vgg19_topology():
    m = build_vgg("vgg19-32")
    kinds = [l.kind for l in m.layers]
    assert kinds.count("conv") == 16
    assert kinds.count("pool") == 5


def test_full_scale_topology_shapes():
    m = build_vgg("vgg16")
    assert m.image == 224
    assert m.layer(1).out_shape == (224, 224, 64)
    dense = [l for l in m.layers if l.kind == "dense"]
    assert dense[0].weight_shape == (7 * 7 * 512, 4096)
    assert dense[-1].out_shape == (1000,)


def test_forward_full_is_probability():
    m = build_vgg("vgg16-32")
    x = jnp.asarray(data.make_images(2, 32, seed=3))
    y = np.asarray(forward_full(m, x))
    assert y.shape == (2, 10)
    np.testing.assert_allclose(y.sum(axis=1), 1.0, atol=1e-5)
    assert (y >= 0).all()


def test_forward_range_composes():
    m = build_vgg("vgg16-32")
    x = jnp.asarray(data.make_images(1, 32, seed=4))
    p = 6
    head = forward_range(m, x, 1, p)
    tail = forward_range(m, head, p + 1, len(m.layers))
    full = forward_full(m, x)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full), atol=1e-5)


def test_features_ref_matches_pallas_path():
    """The differentiable oracle forward must equal the Pallas forward."""
    m = build_vgg("vgg16-32")
    x = jnp.asarray(data.make_images(1, 32, seed=5))
    for p in [1, 3, 6, 9]:
        a = np.asarray(features_at(m, x, p))
        b = np.asarray(features_at_ref(m, x, p))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_blinded_layer_decodes_to_open_layer():
    """Per-layer Slalom correctness on the real model weights."""
    m = build_vgg("vgg16-32")
    spec = m.layer(1)
    x = jnp.asarray(data.make_images(1, 32, seed=6))
    rng = np.random.default_rng(0)
    r = rng.integers(0, int(MOD_P), (1,) + spec.in_shape).astype(np.float32)

    blinded = quantize_blind(x, r)
    y_b = apply_linear_blinded(m, spec, blinded)
    r_u = apply_linear_blinded(m, spec, jnp.asarray(r))
    y = np.asarray(unblind_dequantize(y_b, r_u))
    # open equivalent on the quantized weights (bias excluded in blind path)
    wq = np.asarray(quantize_weights(m.weights[spec.name]))
    from compile.kernels import ref

    want = np.asarray(
        ref.conv2d_ref(jnp.round(x * SCALE_X), jnp.asarray(wq))) / SCALE_XW
    np.testing.assert_allclose(y, want, atol=1e-5)


def test_stage_fns_cover_linear_and_partitions():
    m = build_vgg("vgg16-32")
    stages = stage_fns(m, 1)
    for idx in linear_layers(m):
        assert f"layer{idx:02d}_lin_open" in stages
        assert f"layer{idx:02d}_lin_blind" in stages
    for p in partition_candidates(m):
        assert f"tail_p{p:02d}" in stages
        assert f"head_p{p:02d}" in stages
    assert "full_open" in stages


def test_stage_head_tail_equals_full():
    m = build_vgg("vgg16-32")
    stages = stage_fns(m, 1)
    x = jnp.asarray(data.make_images(1, 32, seed=8))
    p = 6
    head_fn, _ = stages[f"head_p{p:02d}"]
    tail_fn, _ = stages[f"tail_p{p:02d}"]
    full_fn, _ = stages["full_open"]
    (feat,) = head_fn(x)
    (out,) = tail_fn(feat)
    (want,) = full_fn(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_manifest_entry_fields():
    m = build_vgg("vgg16-32")
    e = model_manifest_entry(m)
    assert e["name"] == "vgg16-32"
    assert len(e["layers"]) == len(m.layers)
    conv1 = e["layers"][0]
    assert conv1["kind"] == "conv"
    assert conv1["params_bytes"] == 4 * (3 * 3 * 3 * 8 + 8)
    assert len(conv1["bias"]) == 8
    # pools have no params
    pool = e["layers"][2]
    assert pool["params_bytes"] == 0 and pool["bias"] == []


def test_weights_deterministic():
    a = build_vgg("vgg16-32", seed=2019)
    b = build_vgg("vgg16-32", seed=2019)
    for k in a.weights:
        np.testing.assert_array_equal(a.weights[k], b.weights[k])


def test_unknown_model_rejected():
    with pytest.raises(ValueError):
        build_vgg("vgg13-32")
