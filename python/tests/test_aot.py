"""AOT export path: HLO text round-trips through the XLA text parser."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data
from compile.aot import lower_stage, to_hlo_text
from compile.model import build_vgg, stage_fns

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_module():
    m = build_vgg("vgg16-32")
    stages = stage_fns(m, 1)
    fn, specs = stages["layer01_lin_open"]
    text = to_hlo_text(lower_stage(fn, specs))
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_structure_round_trips():
    """The emitted text must contain the tuple-root entry computation the
    Rust loader expects (`return_tuple=True` → `to_tuple1` unwrap), and the
    parameter/result shapes of the stage.  (Actual *execution* of the text
    artifacts against golden vectors happens in the Rust integration
    tests, which exercise the real PJRT loader.)"""
    m = build_vgg("vgg16-32")
    stages = stage_fns(m, 1)
    fn, specs = stages["layer01_lin_open"]
    text = to_hlo_text(lower_stage(fn, specs))
    assert "ENTRY" in text
    # tuple-rooted result and the f32[1,32,32,3] parameter both appear
    assert "(f32[" in text or "tuple(" in text
    assert "f32[1,32,32,3]" in text.replace(" ", "")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_references_existing_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == 1
    names = set()
    for model in man["models"]:
        assert model["layers"], model["name"]
        for st in model["stages"]:
            path = os.path.join(ART, model["name"], f"b{st['batch']}",
                                os.path.basename(st["file"]))
            # file paths in the manifest are relative to artifacts/
            full = os.path.join(ART, st["file"])
            assert os.path.exists(full), st["file"]
            names.add((model["name"], st["stage"], st["batch"]))
    # both batch sizes exported for the default models
    assert ("vgg16-32", "full_open", 1) in names
    assert ("vgg16-32", "full_open", 8) in names


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "golden")),
                    reason="artifacts not built")
def test_golden_vectors_match_model():
    with open(os.path.join(ART, "golden", "vgg16-32_golden.json")) as f:
        g = json.load(f)
    m = build_vgg("vgg16-32")
    x = np.array(g["input"], np.float32).reshape(g["input_shape"])
    from compile.vgg import forward_full

    logits = np.asarray(forward_full(m, jnp.asarray(x)))[0]
    np.testing.assert_allclose(logits, np.array(g["logits"]), atol=1e-5)
