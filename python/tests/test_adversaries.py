"""Adversary tooling tests: inversion ordering, c-GAN mechanics, dataset."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import cgan, data
from compile.inversion import features_at_ref, invert
from compile.kernels import mean_ssim
from compile.model import build_vgg


def test_dataset_shapes_and_range():
    x = data.make_images(8, size=32, seed=0)
    assert x.shape == (8, 32, 32, 3)
    assert x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_dataset_deterministic_and_varied():
    a = data.make_images(4, seed=5)
    b = data.make_images(4, seed=5)
    np.testing.assert_array_equal(a, b)
    c = data.make_images(4, seed=6)
    assert np.abs(a - c).max() > 0.1  # different seeds → different scenes


def test_dataset_has_structure():
    """Images must not be flat noise — windows should correlate."""
    x = data.make_images(4, seed=1)
    # neighboring-pixel correlation well above white noise
    v = x[:, :-1, :, :] - x[:, 1:, :, :]
    assert float(np.abs(v).mean()) < 0.15


def test_train_val_split_disjoint():
    tr, va = data.train_val_split(4, 4, seed=0)
    assert np.abs(tr[:4] - va[:4]).max() > 0.05


def test_inversion_shallow_beats_deep():
    """The paper's core privacy claim, in miniature: reconstructability
    decays with partition depth (shallow conv ≫ deep conv)."""
    m = build_vgg("vgg16-32")
    val = data.make_images(4, 32, seed=42)
    ssims = {}
    for p in [1, 7]:
        f = np.asarray(features_at_ref(m, jnp.asarray(val), p))
        recon, _ = invert(m, f, p, steps=50)
        ssims[p] = float(mean_ssim(jnp.asarray(val), jnp.asarray(recon)))
    assert ssims[1] > ssims[7] + 0.1, ssims


def test_inversion_output_in_range():
    m = build_vgg("vgg16-32")
    val = data.make_images(2, 32, seed=9)
    f = np.asarray(features_at_ref(m, jnp.asarray(val), 2))
    recon, loss = invert(m, f, 2, steps=10)
    assert recon.shape == val.shape
    assert recon.min() >= 0.0 and recon.max() <= 1.0
    assert np.isfinite(loss)


@pytest.mark.parametrize("p", [2, 10])
def test_cgan_shapes_and_training_step(p):
    """c-GAN builds for shallow (large) and deep (small) feature maps and
    one training step changes the generator."""
    m = build_vgg("vgg16-32")
    tr = data.make_images(8, 32, seed=2)
    f = np.asarray(features_at_ref(m, jnp.asarray(tr), p))
    gp0, gmeta = cgan.init_generator(f.shape[1:], 32)
    out0 = cgan.reconstruct(gp0, gmeta, f[:2])
    assert out0.shape == (2, 32, 32, 3)
    assert out0.min() >= 0.0 and out0.max() <= 1.0

    gp, gmeta2, hist = cgan.train_cgan(f, tr, steps=2, batch=4)
    out1 = cgan.reconstruct(gp, gmeta2, f[:2])
    assert np.abs(out1 - cgan.reconstruct(gp0, gmeta, f[:2])).max() >= 0  # runs
    assert len(hist) >= 1 and np.isfinite(hist[0]["g_loss"])


def test_discriminator_logits_finite():
    m = build_vgg("vgg16-32")
    tr = data.make_images(4, 32, seed=3)
    f = np.asarray(features_at_ref(m, jnp.asarray(tr), 3))
    dp, dmeta = cgan.init_discriminator(f.shape[1:], 32)
    logits = cgan.discriminator_forward(dp, dmeta, jnp.asarray(tr), jnp.asarray(f))
    assert logits.shape == (4, 1)
    assert np.isfinite(np.asarray(logits)).all()


def test_adam_decreases_quadratic():
    """Sanity-pin the from-scratch Adam on a convex problem."""
    params = {"w": jnp.asarray(np.array([5.0, -3.0], np.float32))}
    m, v = cgan.adam_init(params)
    import jax

    grad = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    for t in range(1, 200):
        params, m, v = cgan.adam_update(params, grad(params), m, v, t, lr=0.1)
    assert float(jnp.abs(params["w"]).max()) < 0.2
