"""im2col conv kernels vs lax.conv oracle (open + blinded domains)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conv2d, conv2d_mod, quantize_blind, quantize_weights
from compile.kernels.blind import MOD_P
from compile.kernels import ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize(
    "n,h,w,ci,co,k,stride,padding",
    [
        (1, 8, 8, 3, 8, 3, 1, "SAME"),
        (2, 16, 16, 4, 16, 3, 1, "SAME"),
        (1, 8, 8, 3, 4, 3, 2, "SAME"),
        (1, 9, 9, 2, 4, 3, 1, "VALID"),
        (1, 7, 7, 1, 2, 1, 1, "SAME"),
        (2, 12, 10, 3, 5, 5, 2, "SAME"),
    ],
)
def test_conv2d_matches_ref(n, h, w, ci, co, k, stride, padding):
    x = RNG.standard_normal((n, h, w, ci)).astype(np.float32)
    wt = RNG.standard_normal((k, k, ci, co)).astype(np.float32) * 0.2
    b = RNG.standard_normal((co,)).astype(np.float32)
    got = conv2d(x, wt, b, stride=stride, padding=padding)
    want = ref.conv2d_ref(x, wt, b, stride=stride, padding=padding)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "n,h,w,ci,co,stride",
    [(1, 8, 8, 3, 8, 1), (2, 8, 8, 4, 4, 1), (1, 16, 16, 2, 4, 2)],
)
def test_conv2d_mod_exact(n, h, w, ci, co, stride):
    x = RNG.integers(0, int(MOD_P), (n, h, w, ci)).astype(np.float32)
    wq = RNG.integers(-255, 256, (3, 3, ci, co)).astype(np.float32)
    got = np.asarray(conv2d_mod(x, wq, stride=stride))
    want = np.asarray(ref.conv2d_mod_ref(x, wq, stride=stride))
    np.testing.assert_array_equal(got, want)


def test_conv_blinded_roundtrip_matches_open_quantized():
    """End-to-end conv decodability: blind → conv_mod → unblind == open."""
    from compile.kernels import unblind_dequantize
    from compile.kernels.blind import SCALE_X, SCALE_XW

    x = RNG.uniform(-1, 1, (1, 8, 8, 3)).astype(np.float32)
    wf = RNG.uniform(-0.3, 0.3, (3, 3, 3, 8)).astype(np.float32)
    wq = np.asarray(quantize_weights(wf))
    r = RNG.integers(0, int(MOD_P), x.shape).astype(np.float32)

    blinded = np.asarray(quantize_blind(x, r))
    y_b = np.asarray(conv2d_mod(blinded, wq))
    r_u = np.asarray(conv2d_mod(r, wq))
    y = np.asarray(unblind_dequantize(y_b, r_u))

    xq = np.round(x * SCALE_X)
    want = np.asarray(ref.conv2d_ref(xq, wq)) / SCALE_XW
    np.testing.assert_allclose(y, want, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 14),
    ci=st.integers(1, 6),
    co=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_conv2d_hypothesis(h, ci, co, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, h, h, ci)).astype(np.float32)
    wt = rng.standard_normal((3, 3, ci, co)).astype(np.float32) * 0.2
    got = conv2d(x, wt)
    want = ref.conv2d_ref(x, wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
