"""Blinding arithmetic: kernel-vs-oracle and the cryptographic invariants.

The properties checked here are the paper's correctness core:
  1. blind→linear(mod)→unblind == quantized open linear  (decodability)
  2. blinding output is exactly (q + r) mod 2^24          (pad arithmetic)
  3. the blinded tensor is statistically independent of x  (hiding —
     checked as: two different inputs under the same r differ by exactly
     their quantized difference mod P, and the marginal is full-range)
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    MOD_P,
    SCALE_X,
    SCALE_XW,
    matmul_mod,
    quantize_blind,
    quantize_weights,
    unblind_dequantize,
)
from compile.kernels import ref

RNG = np.random.default_rng(7)
P = int(MOD_P)


def _rand_r(shape, rng=RNG):
    return rng.integers(0, P, shape).astype(np.float32)


@pytest.mark.parametrize("shape", [(4,), (3, 5), (2, 8, 8, 3), (1, 1), (511,)])
def test_quantize_blind_matches_ref(shape):
    x = RNG.uniform(-4, 4, shape).astype(np.float32)
    r = _rand_r(shape)
    got = np.asarray(quantize_blind(x, r))
    want = np.asarray(ref.quantize_blind_ref(x, r))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0 and got.max() < MOD_P


@pytest.mark.parametrize("shape", [(6,), (4, 4), (2, 4, 4, 2)])
def test_unblind_dequantize_matches_ref(shape):
    y = _rand_r(shape)
    ru = _rand_r(shape)
    got = np.asarray(unblind_dequantize(y, ru))
    want = np.asarray(ref.unblind_dequantize_ref(y, ru))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_blind_then_unblind_identity():
    """Unblinding with R = r recovers the quantized input exactly."""
    x = RNG.uniform(-8, 8, (64,)).astype(np.float32)
    r = _rand_r((64,))
    b = np.asarray(quantize_blind(x, r))
    back = np.asarray(unblind_dequantize(b, r))
    np.testing.assert_allclose(back, np.round(x * SCALE_X) / SCALE_XW, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 48),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_slalom_roundtrip_property(m, k, n, seed):
    """Property 1: the offloaded blinded GEMM decodes to the open result."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, (m, k)).astype(np.float32)
    wf = rng.uniform(-0.5, 0.5, (k, n)).astype(np.float32)
    wq = np.asarray(quantize_weights(wf))
    r = _rand_r((m, k), rng)

    blinded = np.asarray(quantize_blind(x, r))
    y_b = np.asarray(matmul_mod(blinded, wq))          # untrusted device
    r_u = np.asarray(matmul_mod(r, wq))                # precomputed factors
    y = np.asarray(unblind_dequantize(y_b, r_u))       # enclave decodes

    y_true = (np.round(x * SCALE_X) @ wq) / SCALE_XW   # open quantized GEMM
    np.testing.assert_allclose(y, y_true, atol=1e-6)


def test_blinded_difference_is_quantized_difference():
    """Property 3a: same pad, two inputs — difference leaks only q1-q2 mod P
    (i.e. the pad cancels; the blinding itself adds no other structure)."""
    x1 = RNG.uniform(-2, 2, (128,)).astype(np.float32)
    x2 = RNG.uniform(-2, 2, (128,)).astype(np.float32)
    r = _rand_r((128,))
    b1 = np.asarray(quantize_blind(x1, r))
    b2 = np.asarray(quantize_blind(x2, r))
    dq = np.mod(np.round(x1 * SCALE_X) - np.round(x2 * SCALE_X), P)
    np.testing.assert_array_equal(np.mod(b1 - b2, P), dq)


def test_blinded_marginal_is_full_range():
    """Property 3b: with uniform r the blinded values cover Z_P uniformly —
    a chi-square-ish sanity check on 2^16 buckets."""
    n = 1 << 16
    x = np.full((n,), 0.123, np.float32)  # constant input: worst case
    r = _rand_r((n,))
    b = np.asarray(quantize_blind(x, r)).astype(np.int64)
    buckets = np.bincount(b >> 8, minlength=1 << 16)  # 2^16 buckets of 2^8
    # Uniform multinomial: mean 1, std 1; the max bucket should stay small.
    assert buckets.max() <= 10, f"suspiciously peaked blinded marginal: {buckets.max()}"


def test_decodability_range_invariant():
    """Values whose true magnitude exceeds the centered range must wrap —
    documents (and pins) the |y| < 2^23/SCALE_XW decodability bound."""
    big = np.array([float((1 << 23) // int(SCALE_X) + 10)], np.float32)
    r = _rand_r((1,))
    b = np.asarray(quantize_blind(big, r))
    back = np.asarray(unblind_dequantize(b, r))
    assert not np.allclose(back, np.round(big * SCALE_X) / SCALE_XW)


def test_quantize_weights_integral_and_clamped():
    w = RNG.standard_normal((1000,)).astype(np.float32) * 1000
    q = np.asarray(quantize_weights(w))
    np.testing.assert_array_equal(q, np.round(q))
    assert np.abs(q).max() < 2**15
