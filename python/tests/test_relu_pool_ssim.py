"""Non-linear kernels (relu / maxpool / fusion) and the SSIM kernel."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (
    maxpool2x2,
    mean_ssim,
    relu,
    relu_maxpool2x2,
    ssim_map,
)
from compile.kernels import ref

RNG = np.random.default_rng(5)


@pytest.mark.parametrize("shape", [(4,), (3, 7), (2, 8, 8, 3), (1, 1, 1, 1)])
def test_relu_matches_ref(shape):
    x = RNG.standard_normal(shape).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(relu(x)), np.asarray(ref.relu_ref(x)))


@pytest.mark.parametrize("n,h,w,c", [(1, 4, 4, 1), (2, 8, 8, 3), (1, 16, 8, 7)])
def test_maxpool_matches_ref(n, h, w, c):
    x = RNG.standard_normal((n, h, w, c)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(maxpool2x2(x)), np.asarray(ref.maxpool2x2_ref(x))
    )


@pytest.mark.parametrize("n,h,w,c", [(1, 4, 4, 2), (2, 8, 8, 3)])
def test_fused_relu_maxpool(n, h, w, c):
    x = RNG.standard_normal((n, h, w, c)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(relu_maxpool2x2(x)), np.asarray(ref.relu_maxpool2x2_ref(x))
    )


def test_pool_rejects_odd_spatial():
    x = RNG.standard_normal((1, 5, 4, 1)).astype(np.float32)
    with pytest.raises(AssertionError):
        maxpool2x2(x)


def test_ssim_identity_is_one():
    x = RNG.uniform(0, 1, (2, 16, 16, 3)).astype(np.float32)
    assert abs(float(mean_ssim(x, x)) - 1.0) < 1e-6


def test_ssim_uncorrelated_noise_is_low():
    x = RNG.uniform(0, 1, (1, 32, 32, 3)).astype(np.float32)
    y = RNG.uniform(0, 1, (1, 32, 32, 3)).astype(np.float32)
    assert float(mean_ssim(x, y)) < 0.25


def test_ssim_matches_ref_map():
    x = RNG.uniform(0, 1, (2, 24, 24, 3)).astype(np.float32)
    y = np.clip(x + RNG.normal(0, 0.15, x.shape), 0, 1).astype(np.float32)
    got = np.asarray(ssim_map(x, y))
    want = np.asarray(ref.ssim_map_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ssim_symmetry():
    x = RNG.uniform(0, 1, (1, 16, 16, 1)).astype(np.float32)
    y = RNG.uniform(0, 1, (1, 16, 16, 1)).astype(np.float32)
    assert abs(float(mean_ssim(x, y)) - float(mean_ssim(y, x))) < 1e-6


@settings(max_examples=10, deadline=None)
@given(
    sigma=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31),
)
def test_ssim_decreases_with_noise(sigma, seed):
    """SSIM(x, x+noise) should not be higher than SSIM(x, x)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.2, 0.8, (1, 16, 16, 1)).astype(np.float32)
    y = np.clip(x + rng.normal(0, sigma, x.shape), 0, 1).astype(np.float32)
    assert float(mean_ssim(x, y)) <= 1.0 + 1e-6
