"""Pallas GEMM vs pure-jnp oracle, incl. hypothesis shape/value sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import matmul, matmul_mod
from compile.kernels.blind import MOD_P
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (8, 8, 8), (128, 128, 128), (256, 128, 64), (33, 65, 17), (7, 3, 5)],
)
def test_matmul_matches_ref(m, k, n):
    x = RNG.standard_normal((m, k)).astype(np.float32)
    w = RNG.standard_normal((k, n)).astype(np.float32)
    _assert_close(matmul(x, w), ref.matmul_ref(x, w), tol=1e-3)


def test_matmul_blocking_covers_multi_step_k():
    # K larger than the block forces the revisited-output accumulate path.
    x = RNG.standard_normal((64, 512)).astype(np.float32)
    w = RNG.standard_normal((512, 32)).astype(np.float32)
    _assert_close(matmul(x, w, block=64), ref.matmul_ref(x, w), tol=1e-2)


@pytest.mark.parametrize("m,k,n", [(16, 16, 16), (64, 256, 32), (31, 47, 9)])
def test_matmul_mod_exact(m, k, n):
    x = RNG.integers(0, int(MOD_P), (m, k)).astype(np.float32)
    w = RNG.integers(-255, 256, (k, n)).astype(np.float32)
    got = np.asarray(matmul_mod(x, w))
    want = np.asarray(ref.matmul_mod_ref(x, w))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0.0 and got.max() < MOD_P


def test_matmul_mod_output_is_integral():
    x = RNG.integers(0, int(MOD_P), (32, 64)).astype(np.float32)
    w = RNG.integers(-255, 256, (64, 8)).astype(np.float32)
    y = np.asarray(matmul_mod(x, w))
    np.testing.assert_array_equal(y, np.round(y))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    _assert_close(matmul(x, w), ref.matmul_ref(x, w), tol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31),
)
def test_matmul_mod_hypothesis_exactness(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, int(MOD_P), (m, k)).astype(np.float32)
    w = rng.integers(-(2**15) + 1, 2**15, (k, n)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(matmul_mod(x, w)), np.asarray(ref.matmul_mod_ref(x, w))
    )
