"""VGG-16 / VGG-19 model definitions over the L1 Pallas kernels.

Two scales of each topology:

- ``vgg16`` / ``vgg19``        — the paper's 224x224x3 ImageNet shapes.
- ``vgg16-32`` / ``vgg19-32``  — 32x32x3 variants with channels/8 and a
  10-way head: identical layer *structure* (13/16 convs, 5 pools, 3 dense)
  so every partitioning / blinding / scheduling experiment exercises the
  same code paths at CI-friendly cost.  (Substitution documented in
  DESIGN.md §2: runtime and memory experiments depend on layer shapes,
  not ImageNet weights.)

Layers are numbered 1..N in *sequence order including pools* — the paper's
convention (its "layer 3" is the first max-pool, "layer 6" the second,
which is Origami's minimum private partition for VGG-16).  Weights are
deterministic He-init from a fixed seed; biases are small and layer-unique
so end-to-end numerics are non-trivial.
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from .kernels import (
    conv2d,
    conv2d_mod,
    matmul,
    matmul_mod,
    quantize_weights,
    relu,
    relu_maxpool2x2,
    maxpool2x2,
)

# Channel plans ('M' = 2x2 max-pool).
_PLAN16 = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
_PLAN19 = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
           512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


@dataclass
class LayerSpec:
    """One sequential stage of the network."""

    index: int               # 1-based sequence index (paper convention)
    kind: str                # conv | pool | flatten | dense | softmax
    name: str
    in_shape: Tuple[int, ...]   # per-sample (no batch dim)
    out_shape: Tuple[int, ...]
    weight_shape: Optional[Tuple[int, ...]] = None
    has_relu: bool = False   # conv/dense followed by in-enclave ReLU
    flops: int = 0
    params_bytes: int = 0


@dataclass
class VggModel:
    """A VGG topology instance: specs + materialized weights."""

    name: str
    image: int              # input spatial size
    in_channels: int
    layers: List[LayerSpec] = field(default_factory=list)
    weights: dict = field(default_factory=dict)   # name -> np.ndarray
    biases: dict = field(default_factory=dict)    # name -> np.ndarray

    @property
    def conv_indices(self) -> List[int]:
        return [l.index for l in self.layers if l.kind == "conv"]

    @property
    def pool_indices(self) -> List[int]:
        return [l.index for l in self.layers if l.kind == "pool"]

    def layer(self, index: int) -> LayerSpec:
        return self.layers[index - 1]

    def feature_bytes(self, index: int) -> int:
        """Bytes of the (f32) feature map output by layer ``index``."""
        return 4 * int(np.prod(self.layer(index).out_shape))


def _he(rng: np.random.Generator, shape, fan_in: int) -> np.ndarray:
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def build_vgg(name: str, seed: int = 2019) -> VggModel:
    """Construct a named VGG variant with deterministic weights.

    ``name`` in {vgg16, vgg19, vgg16-32, vgg19-32}.
    """
    small = name.endswith("-32")
    base = name.split("-")[0]
    plan = _PLAN16 if base == "vgg16" else _PLAN19
    if base not in ("vgg16", "vgg19"):
        raise ValueError(f"unknown model {name}")
    image = 32 if small else 224
    ch_div = 8 if small else 1
    dense_plan = [64, 64, 10] if small else [4096, 4096, 1000]

    rng = np.random.default_rng(seed)
    m = VggModel(name=name, image=image, in_channels=3)
    h = image
    c = 3
    idx = 0
    for item in plan:
        idx += 1
        if item == "M":
            spec = LayerSpec(
                index=idx, kind="pool", name=f"pool{idx}",
                in_shape=(h, h, c), out_shape=(h // 2, h // 2, c),
            )
            h //= 2
        else:
            co = int(item) // ch_div
            wshape = (3, 3, c, co)
            flops = 2 * h * h * co * 3 * 3 * c
            spec = LayerSpec(
                index=idx, kind="conv", name=f"conv{idx}",
                in_shape=(h, h, c), out_shape=(h, h, co),
                weight_shape=wshape, has_relu=True, flops=flops,
                params_bytes=4 * (int(np.prod(wshape)) + co),
            )
            m.weights[spec.name] = _he(rng, wshape, fan_in=9 * c)
            m.biases[spec.name] = (rng.standard_normal(co) * 0.05).astype(np.float32)
            c = co
        m.layers.append(spec)

    # flatten
    idx += 1
    flat = h * h * c
    m.layers.append(LayerSpec(idx, "flatten", f"flatten{idx}",
                              in_shape=(h, h, c), out_shape=(flat,)))
    d_in = flat
    for j, d_out in enumerate(dense_plan):
        idx += 1
        last = j == len(dense_plan) - 1
        spec = LayerSpec(
            index=idx, kind="dense", name=f"dense{idx}",
            in_shape=(d_in,), out_shape=(d_out,),
            weight_shape=(d_in, d_out), has_relu=not last,
            flops=2 * d_in * d_out,
            params_bytes=4 * (d_in * d_out + d_out),
        )
        m.weights[spec.name] = _he(rng, (d_in, d_out), fan_in=d_in)
        m.biases[spec.name] = (rng.standard_normal(d_out) * 0.05).astype(np.float32)
        m.layers.append(spec)
        d_in = d_out
    idx += 1
    m.layers.append(LayerSpec(idx, "softmax", f"softmax{idx}",
                              in_shape=(d_in,), out_shape=(d_in,)))
    return m


# ---------------------------------------------------------------------------
# Forward functions (L2) — all compute flows through the L1 kernels.
# ---------------------------------------------------------------------------

def apply_layer_open(m: VggModel, spec: LayerSpec, x):
    """Open-domain (f32) application of one layer, ReLU fused where spec'd."""
    if spec.kind == "conv":
        w = jnp.asarray(m.weights[spec.name])
        b = jnp.asarray(m.biases[spec.name])
        y = conv2d(x, w, b)
        return relu(y) if spec.has_relu else y
    if spec.kind == "pool":
        return maxpool2x2(x)
    if spec.kind == "flatten":
        return x.reshape(x.shape[0], -1)
    if spec.kind == "dense":
        w = jnp.asarray(m.weights[spec.name])
        b = jnp.asarray(m.biases[spec.name])
        y = matmul(x, w) + b
        return relu(y) if spec.has_relu else y
    if spec.kind == "softmax":
        z = x - x.max(axis=-1, keepdims=True)
        e = jnp.exp(z)
        return e / e.sum(axis=-1, keepdims=True)
    raise ValueError(spec.kind)


def apply_linear_open(m: VggModel, spec: LayerSpec, x):
    """Only the linear part (conv/dense + bias), no activation — this is
    what a per-layer artifact computes; the enclave applies the ReLU."""
    if spec.kind == "conv":
        return conv2d(x, jnp.asarray(m.weights[spec.name]),
                      jnp.asarray(m.biases[spec.name]))
    if spec.kind == "dense":
        return matmul(x, jnp.asarray(m.weights[spec.name])) + jnp.asarray(
            m.biases[spec.name])
    raise ValueError(f"layer {spec.name} has no linear part")


def apply_linear_blinded(m: VggModel, spec: LayerSpec, x_b):
    """Blinded-domain linear part: exact mod-2^24 GEMM on blinded input.

    No bias — the enclave folds the float bias in after unblinding, keeping
    the offloaded computation strictly linear (Slalom's requirement).
    """
    wq = quantize_weights(jnp.asarray(m.weights[spec.name]))
    if spec.kind == "conv":
        return conv2d_mod(x_b, wq)
    if spec.kind == "dense":
        return matmul_mod(x_b, wq)
    raise ValueError(f"layer {spec.name} has no linear part")


def forward_range(m: VggModel, x, start: int, end: int):
    """Open-domain forward through layers [start, end] inclusive (1-based)."""
    for spec in m.layers[start - 1 : end]:
        x = apply_layer_open(m, spec, x)
    return x


def forward_full(m: VggModel, x):
    return forward_range(m, x, 1, len(m.layers))


def features_at(m: VggModel, x, p: int):
    """Θ(X): the intermediate feature map after layer ``p`` (the tensor an
    adversary observes when the tail is offloaded in the open)."""
    return forward_range(m, x, 1, p)
