"""Conditional-GAN adversary (paper §IV-V): reconstruct X from Θ(X).

Architecture follows §V-A scaled to the 32x32 substitute dataset
(DESIGN.md §2): the Generator is an encoder → residual blocks → nearest-
neighbor-upsampling decoder; the Discriminator downsamples the candidate
image to the feature map's spatial size, concatenates the conditioning
feature map, and classifies real/fake through strided convs + a sigmoid
head.  BatchNorm is replaced by per-channel InstanceNorm (batch-size
robust, no running stats to thread through a hand-rolled trainer) and the
optimizer is a from-scratch Adam (optax is not available offline).

Everything here is *offline adversary tooling* — it never touches the
request path.  ``export_generator`` lowers a trained generator to an HLO
artifact so the Rust coordinator can run reconstructions natively during
partition search.
"""

import functools
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Layer primitives (plain jnp — the adversary is not on the AOT hot path,
# but the generator *is* exported via aot.to_hlo_text for Rust-side use).
# ---------------------------------------------------------------------------

def conv(p, x, name, stride=1):
    w, b = p[f"{name}_w"], p[f"{name}_b"]
    y = lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def inorm(p, x, name, eps=1e-5):
    mu = x.mean(axis=(1, 2), keepdims=True)
    var = x.var(axis=(1, 2), keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + eps)
    return xhat * p[f"{name}_g"] + p[f"{name}_be"]


def lrelu(x, a=0.2):
    return jnp.where(x >= 0, x, a * x)


def upsample2(x):
    n, h, w, c = x.shape
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


def _init_conv(rng, params, name, kh, kw, ci, co):
    k = rng.standard_normal((kh, kw, ci, co))
    params[f"{name}_w"] = (k * np.sqrt(2.0 / (kh * kw * ci))).astype(np.float32)
    params[f"{name}_b"] = np.zeros((co,), np.float32)


def _init_norm(rng, params, name, c):
    params[f"{name}_g"] = np.ones((c,), np.float32)
    params[f"{name}_be"] = np.zeros((c,), np.float32)


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------

def init_generator(
    feat_shape: Tuple[int, int, int],
    img_size: int = 32,
    base: int = 32,
    n_res: int = 2,
    seed: int = 0,
) -> Tuple[Dict[str, np.ndarray], dict]:
    """Build generator params for a feature map of shape (H, W, C).

    The encoder downsamples (stride 2) until spatial dim == bottleneck
    (img_size//4, the 32-scale analogue of the paper's 14x14), then
    ``n_res`` residual blocks, then nearest-neighbor upsampling back to
    ``img_size``.  If the feature map is *smaller* than the bottleneck
    (deep partition layers), the encoder upsamples instead — information
    is what's missing there, not resolution.
    """
    h, w, c = feat_shape
    bott = max(4, img_size // 4)
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    plan: List[Tuple[str, int]] = []  # (op, arg)

    # encoder to bottleneck spatial size
    cur_h, cur_c = h, c
    i = 0
    _init_conv(rng, params, f"ge{i}", 3, 3, cur_c, base)
    _init_norm(rng, params, f"gen{i}", base)
    plan.append(("conv_norm_relu", i))
    cur_c = base
    while cur_h > bott:
        i += 1
        _init_conv(rng, params, f"ge{i}", 4, 4, cur_c, cur_c * 2)
        _init_norm(rng, params, f"gen{i}", cur_c * 2)
        plan.append(("down", i))
        cur_c *= 2
        cur_h //= 2
    while cur_h < bott:
        i += 1
        _init_conv(rng, params, f"ge{i}", 3, 3, cur_c, max(base, cur_c // 2))
        _init_norm(rng, params, f"gen{i}", max(base, cur_c // 2))
        plan.append(("up_enc", i))
        cur_c = max(base, cur_c // 2)
        cur_h *= 2

    # residual blocks
    for r in range(n_res):
        _init_conv(rng, params, f"gr{r}a", 3, 3, cur_c, cur_c)
        _init_norm(rng, params, f"grn{r}a", cur_c)
        _init_conv(rng, params, f"gr{r}b", 3, 3, cur_c, cur_c)
        _init_norm(rng, params, f"grn{r}b", cur_c)

    # decoder to img_size
    d = 0
    dec_c = cur_c
    dec_h = cur_h
    while dec_h < img_size:
        _init_conv(rng, params, f"gd{d}", 3, 3, dec_c, max(base // 2, dec_c // 2))
        _init_norm(rng, params, f"gdn{d}", max(base // 2, dec_c // 2))
        dec_c = max(base // 2, dec_c // 2)
        dec_h *= 2
        d += 1
    _init_conv(rng, params, "gout", 3, 3, dec_c, 3)

    meta = {
        "plan": plan,
        "n_res": n_res,
        "n_dec": d,
        "feat_shape": tuple(feat_shape),
        "img_size": img_size,
    }
    return params, meta


def generator_forward(params, meta, feat):
    x = feat
    for op, i in meta["plan"]:
        if op == "conv_norm_relu":
            x = jnp.maximum(inorm(params, conv(params, x, f"ge{i}"), f"gen{i}"), 0.0)
        elif op == "down":
            x = jnp.maximum(
                inorm(params, conv(params, x, f"ge{i}", stride=2), f"gen{i}"), 0.0)
        elif op == "up_enc":
            x = upsample2(x)
            x = jnp.maximum(inorm(params, conv(params, x, f"ge{i}"), f"gen{i}"), 0.0)
    for r in range(meta["n_res"]):
        y = jnp.maximum(inorm(params, conv(params, x, f"gr{r}a"), f"grn{r}a"), 0.0)
        y = inorm(params, conv(params, y, f"gr{r}b"), f"grn{r}b")
        x = jnp.maximum(x + y, 0.0)
    for d in range(meta["n_dec"]):
        x = upsample2(x)
        x = jnp.maximum(inorm(params, conv(params, x, f"gd{d}"), f"gdn{d}"), 0.0)
    return jax.nn.sigmoid(conv(params, x, "gout"))


# ---------------------------------------------------------------------------
# Discriminator
# ---------------------------------------------------------------------------

def init_discriminator(
    feat_shape: Tuple[int, int, int],
    img_size: int = 32,
    base: int = 32,
    seed: int = 1,
):
    h, w, c = feat_shape
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    # image tower: downsample image to the feature spatial size
    n_down = 0
    cur = img_size
    cur_c = 3
    while cur > max(h, 4):
        _init_conv(rng, params, f"di{n_down}", 4, 4, cur_c, base)
        cur_c = base
        cur //= 2
        n_down += 1
    # joint tower after concat with condition
    joint_c = cur_c + c if h == cur else cur_c + c  # same spatial by constr.
    n_joint = 0
    cj = joint_c
    while cur > 2:
        _init_conv(rng, params, f"dj{n_joint}", 4, 4, cj, base * 2)
        _init_norm(rng, params, f"djn{n_joint}", base * 2)
        cj = base * 2
        cur //= 2
        n_joint += 1
    fan_in = cj * cur * cur
    params["dd_w"] = (rng.standard_normal((fan_in, 1)) / np.sqrt(fan_in)).astype(
        np.float32)
    params["dd_b"] = np.zeros((1,), np.float32)
    meta = {"n_down": n_down, "n_joint": n_joint, "feat_shape": tuple(feat_shape)}
    return params, meta


def discriminator_forward(params, meta, img, feat):
    x = img
    for i in range(meta["n_down"]):
        x = lrelu(conv(params, x, f"di{i}", stride=2))
    # align condition to x's spatial dims (deep features may be smaller)
    fh = feat.shape[1]
    xh = x.shape[1]
    f = feat
    while f.shape[1] < xh:
        f = upsample2(f)
    while f.shape[1] > xh:
        f = f[:, ::2, ::2, :]
    x = jnp.concatenate([x, f], axis=-1)
    for i in range(meta["n_joint"]):
        x = lrelu(inorm(params, conv(params, x, f"dj{i}", stride=2), f"djn{i}"))
    x = x.reshape(x.shape[0], -1)
    return x @ params["dd_w"] + params["dd_b"]  # logits


# ---------------------------------------------------------------------------
# From-scratch Adam + GAN training
# ---------------------------------------------------------------------------

def adam_init(params):
    return (
        {k: np.zeros_like(v) for k, v in params.items()},
        {k: np.zeros_like(v) for k, v in params.items()},
    )


def adam_update(params, grads, m, v, t, lr=2e-4, b1=0.5, b2=0.999, eps=1e-8):
    """Paper uses lr=2e-4; b1=0.5 is the standard DCGAN choice."""
    out = {}
    f32 = jnp.float32
    for k in params:
        m[k] = (b1 * m[k] + (1 - b1) * grads[k]).astype(f32)
        v[k] = (b2 * v[k] + (1 - b2) * grads[k] ** 2).astype(f32)
        mhat = m[k] / (1 - b1**t)
        vhat = v[k] / (1 - b2**t)
        out[k] = (params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)).astype(f32)
    return out, m, v


def bce_logits(logits, target):
    # numerically stable binary cross entropy on logits
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * target + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def train_cgan(
    feats: np.ndarray,
    imgs: np.ndarray,
    steps: int = 300,
    batch: int = 16,
    l1_weight: float = 50.0,
    seed: int = 0,
    verbose: bool = False,
):
    """Train the c-GAN adversary on (Θ(X), X) pairs.

    Returns (g_params, g_meta, history).  An L1 reconstruction term is
    added to the generator loss (standard for conditional image-to-image
    GANs; it accelerates convergence at this small scale without changing
    what is/ isn't reconstructible).
    """
    feat_shape = feats.shape[1:]
    img_size = imgs.shape[1]
    gp, gmeta = init_generator(feat_shape, img_size, seed=seed)
    dp, dmeta = init_discriminator(feat_shape, img_size, seed=seed + 1)
    gm, gv = adam_init(gp)
    dm, dv = adam_init(dp)

    def g_loss(gp_, dp_, f, x):
        fake = generator_forward(gp_, gmeta, f)
        adv = bce_logits(discriminator_forward(dp_, dmeta, fake, f), 1.0)
        return adv + l1_weight * jnp.mean(jnp.abs(fake - x))

    def d_loss(dp_, gp_, f, x):
        fake = generator_forward(gp_, gmeta, f)
        lr_ = bce_logits(discriminator_forward(dp_, dmeta, x, f), 1.0)
        lf_ = bce_logits(discriminator_forward(dp_, dmeta, fake, f), 0.0)
        return lr_ + lf_

    g_grad = jax.jit(jax.value_and_grad(g_loss))
    d_grad = jax.jit(jax.value_and_grad(d_loss))

    rng = np.random.default_rng(seed)
    hist = []
    n = feats.shape[0]
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, batch)
        f = jnp.asarray(feats[idx])
        x = jnp.asarray(imgs[idx])
        dl, dg = d_grad(dp, gp, f, x)
        dp, dm, dv = adam_update(dp, dg, dm, dv, t)
        gl, gg = g_grad(gp, dp, f, x)
        gp, gm, gv = adam_update(gp, gg, gm, gv, t)
        if t % 50 == 0 or t == 1:
            hist.append({"step": t, "g_loss": float(gl), "d_loss": float(dl)})
            if verbose:
                print(f"  step {t}: g={float(gl):.3f} d={float(dl):.3f}")
    return gp, gmeta, hist


def reconstruct(gp, gmeta, feats):
    return np.asarray(generator_forward(gp, gmeta, jnp.asarray(feats)))
