"""Algorithm 1 driver: per-layer adversary → SSIM table + artifacts.

Reproduces the paper's privacy evaluation (Figs 7 & 8): for every
candidate partition layer p, train/run an adversary that reconstructs the
input from Θ_p(X) and score reconstructions with mean SSIM against the
real images.  Two adversaries:

- ``inversion``  (default, every layer): direct feature inversion [25].
- ``cgan``       (selected layers): the paper's conditional GAN (§V-A),
  whose trained generator is additionally exported as an HLO artifact so
  the Rust coordinator can run reconstructions natively during
  `origami partition-search`.

Outputs under ``artifacts/privacy/``:
- ``ssim_by_layer.json``    — the Fig 8 data (per-layer mean SSIM).
- ``recon_l{p:02d}.ppm``    — Fig 7-style real/reconstructed strips.
- ``cgan_gen_p{p:02d}.hlo.txt`` + entries in the json — Rust-loadable
  generators (feature map → image).

Usage:
    python -m compile.privacy_experiment --out ../artifacts \
        [--model vgg16-32] [--layers 1,2,...] [--cgan-layers 2,3,6]
        [--inv-steps 120] [--cgan-steps 250] [--n-train 192] [--n-val 12]
"""

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from . import data
from .aot import to_hlo_text
from . import cgan
from .inversion import features_at_ref, invert
from .kernels import mean_ssim
from .model import build_vgg, partition_candidates


def write_ppm(path: str, rows: np.ndarray) -> None:
    """Dump an image strip as binary PPM (no PIL dependency needed)."""
    img = (np.clip(rows, 0, 1) * 255).astype(np.uint8)
    h, w, _ = img.shape
    with open(path, "wb") as f:
        f.write(f"P6\n{w} {h}\n255\n".encode())
        f.write(img.tobytes())


def strip(real: np.ndarray, fake: np.ndarray, k: int = 8) -> np.ndarray:
    """Two-row strip: real images on top, reconstructions below."""
    k = min(k, real.shape[0])
    top = np.concatenate(list(real[:k]), axis=1)
    bot = np.concatenate(list(fake[:k]), axis=1)
    return np.concatenate([top, bot], axis=0)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="vgg16-32")
    ap.add_argument("--layers", default="")
    ap.add_argument("--cgan-layers", default="2,3,4,6")
    ap.add_argument("--inv-steps", type=int, default=120)
    ap.add_argument("--cgan-steps", type=int, default=250)
    ap.add_argument("--n-train", type=int, default=192)
    ap.add_argument("--n-val", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    pdir = os.path.join(out_dir, "privacy")
    os.makedirs(pdir, exist_ok=True)

    m = build_vgg(args.model)
    layers = (
        [int(v) for v in args.layers.split(",") if v]
        or partition_candidates(m)
    )
    cgan_layers = [int(v) for v in args.cgan_layers.split(",") if v]

    train_x, val_x = data.train_val_split(
        args.n_train, args.n_val, size=m.image, seed=args.seed)

    results = {"model": args.model, "layers": []}
    for p in layers:
        t0 = time.time()
        row = {"layer": p, "kind": m.layer(p).kind}
        val_f = np.asarray(features_at_ref(m, jnp.asarray(val_x), p))

        recon, feat_loss = invert(m, val_f, p, steps=args.inv_steps)
        ssim_inv = float(mean_ssim(jnp.asarray(val_x), jnp.asarray(recon)))
        row["ssim_inversion"] = ssim_inv
        row["inv_feat_loss"] = feat_loss
        write_ppm(os.path.join(pdir, f"recon_inv_l{p:02d}.ppm"),
                  strip(val_x, recon))

        if p in cgan_layers:
            train_f = np.asarray(features_at_ref(m, jnp.asarray(train_x), p))
            gp, gmeta, hist = cgan.train_cgan(
                train_f, train_x, steps=args.cgan_steps, seed=args.seed)
            fake = cgan.reconstruct(gp, gmeta, val_f)
            row["ssim_cgan"] = float(
                mean_ssim(jnp.asarray(val_x), jnp.asarray(fake)))
            row["cgan_history"] = hist
            write_ppm(os.path.join(pdir, f"recon_cgan_l{p:02d}.ppm"),
                      strip(val_x, fake))
            # Export the trained generator for Rust-side partition search.
            import jax

            lowered = jax.jit(
                lambda f: (cgan.generator_forward(gp, gmeta, f),)
            ).lower(jax.ShapeDtypeStruct(val_f.shape, jnp.float32))
            gen_file = f"cgan_gen_p{p:02d}.hlo.txt"
            with open(os.path.join(pdir, gen_file), "w") as f:
                f.write(to_hlo_text(lowered))
            row["generator_artifact"] = f"privacy/{gen_file}"
            row["generator_input_shape"] = list(val_f.shape)

        results["layers"].append(row)
        print(f"[privacy] layer {p:2d} ({row['kind']:5s}): "
              f"ssim_inv={ssim_inv:.3f}"
              + (f" ssim_cgan={row['ssim_cgan']:.3f}" if "ssim_cgan" in row else "")
              + f"  ({time.time() - t0:.0f}s)")

    with open(os.path.join(pdir, "ssim_by_layer.json"), "w") as f:
        json.dump(results, f, indent=1)
    print(f"[privacy] wrote {os.path.join(pdir, 'ssim_by_layer.json')}")


if __name__ == "__main__":
    main()
