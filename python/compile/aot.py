"""AOT export: lower every stage to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); the Rust coordinator then
loads ``artifacts/manifest.json`` and compiles each ``.hlo.txt`` on its
embedded PJRT CPU client.  Python never runs on the request path.

HLO text — not ``lowered.compiler_ir("hlo").as_hlo_proto().serialize()`` —
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published ``xla`` 0.1.6
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts \
        [--models vgg16-32,vgg19-32] [--batches 1,8] [--golden]
"""

import argparse
import hashlib
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data
from .model import build_vgg, model_manifest_entry, stage_fns
from .vgg import forward_full

DEFAULT_MODELS = ["vgg16-32", "vgg19-32"]
DEFAULT_BATCHES = [1, 8]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # weight tensors as `constant({...})`, which the text parser on the
    # Rust side silently reads back as zeros.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # metadata fields grew new attributes (source_end_line, …) that the
    # 0.5.1-era text parser rejects — strip them.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_stage(fn, arg_specs):
    args = [
        jax.ShapeDtypeStruct(shape, jnp.float32 if dt == "f32" else jnp.float64)
        for shape, dt in arg_specs
    ]
    return jax.jit(fn).lower(*args)


def export_model(m_name: str, batches, out_dir: str, manifest: dict) -> int:
    model = build_vgg(m_name)
    entry = model_manifest_entry(model)
    entry["stages"] = []
    count = 0
    for batch in batches:
        stages = stage_fns(model, batch)
        bdir = os.path.join(out_dir, m_name, f"b{batch}")
        os.makedirs(bdir, exist_ok=True)
        for name, (fn, arg_specs) in sorted(stages.items()):
            path = os.path.join(bdir, f"{name}.hlo.txt")
            rel = os.path.relpath(path, out_dir)
            lowered = lower_stage(fn, arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            out_aval = lowered.out_info[0]
            entry["stages"].append(
                {
                    "stage": name,
                    "batch": batch,
                    "file": rel,
                    "inputs": [
                        {"shape": list(s), "dtype": d} for s, d in arg_specs
                    ],
                    "output": {
                        "shape": list(out_aval.shape),
                        "dtype": "f32",
                    },
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                }
            )
            count += 1
    manifest["models"].append(entry)
    return count


def export_golden(m_name: str, out_dir: str) -> None:
    """Golden vectors for Rust integration tests: input image → logits."""
    model = build_vgg(m_name)
    x = data.make_images(1, size=model.image, seed=7)
    logits = np.asarray(forward_full(model, jnp.asarray(x)))[0]
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    with open(os.path.join(gdir, f"{m_name}_golden.json"), "w") as f:
        json.dump(
            {
                "model": m_name,
                "input": [float(v) for v in x.reshape(-1)],
                "input_shape": list(x.shape),
                "logits": [float(v) for v in logits],
            },
            f,
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--batches", default=",".join(map(str, DEFAULT_BATCHES)))
    ap.add_argument("--golden", action="store_true", default=True)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    batches = [int(b) for b in args.batches.split(",")]

    manifest = {
        "format": 1,
        "generated_unix": int(time.time()),
        "jax": jax.__version__,
        "models": [],
    }
    t0 = time.time()
    total = 0
    for m_name in models:
        n = export_model(m_name, batches, out_dir, manifest)
        print(f"[aot] {m_name}: {n} stages lowered")
        total += n
        if args.golden:
            export_golden(m_name, out_dir)
            print(f"[aot] {m_name}: golden vectors written")

    # Metadata-only entries for the full 224-scale models: Table I/II and
    # the memory/recovery analytics need layer shapes + parameter sizes at
    # paper scale, but not (slow-to-lower, slow-to-compile) artifacts.
    for m_name in ("vgg16", "vgg19"):
        if m_name not in models:
            entry = model_manifest_entry(build_vgg(m_name))
            entry["stages"] = []
            entry["metadata_only"] = True
            manifest["models"].append(entry)
            print(f"[aot] {m_name}: metadata-only entry (224 scale)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {total} artifacts + manifest to {out_dir} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
