"""Direct input-inversion adversary (Mahendran & Vedaldi [25], paper §IV).

Given the observable Θ(X), find X' minimizing ||Θ(X') − Θ(X)||² + TV(X')
by gradient descent on the input.  This is the classical feature-inversion
attack the paper cites as the adversary's underlying objective; it is far
cheaper than the c-GAN and agrees with it on the *ordering* of partition
layers, so the SSIM-by-layer sweep (Fig 8) defaults to it with the c-GAN
validating selected layers.
"""

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .vgg import VggModel


def features_at_ref(m: VggModel, x, p: int):
    """Θ(X) via the pure-jnp oracle ops (mathematically identical to the
    Pallas path — pytest pins them together — but differentiable without
    tracing through the interpreter and ~10x faster under grad)."""
    for spec in m.layers[:p]:
        if spec.kind == "conv":
            x = kref.conv2d_ref(x, jnp.asarray(m.weights[spec.name]),
                                jnp.asarray(m.biases[spec.name]))
            if spec.has_relu:
                x = kref.relu_ref(x)
        elif spec.kind == "pool":
            x = kref.maxpool2x2_ref(x)
        elif spec.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif spec.kind == "dense":
            x = x @ jnp.asarray(m.weights[spec.name]) + jnp.asarray(
                m.biases[spec.name])
            if spec.has_relu:
                x = kref.relu_ref(x)
        elif spec.kind == "softmax":
            x = jax.nn.softmax(x, axis=-1)
    return x


def _tv(x):
    """Total-variation prior: natural-image smoothness regularizer."""
    dh = jnp.abs(x[:, 1:, :, :] - x[:, :-1, :, :]).mean()
    dw = jnp.abs(x[:, :, 1:, :] - x[:, :, :-1, :]).mean()
    return dh + dw


def invert(
    m: VggModel,
    target_feats: np.ndarray,
    p: int,
    steps: int = 150,
    lr: float = 0.05,
    tv_weight: float = 1e-3,
    seed: int = 0,
) -> Tuple[np.ndarray, float]:
    """Reconstruct inputs from layer-p feature maps.

    Returns (reconstructions NHWC in [0,1], final feature loss).
    Optimizes in logit space so the box constraint is implicit.
    """
    n = target_feats.shape[0]
    tgt = jnp.asarray(target_feats)
    tnorm = jnp.mean(tgt**2) + 1e-8

    def loss(z):
        x = jax.nn.sigmoid(z)
        f = features_at_ref(m, x, p)
        return jnp.mean((f - tgt) ** 2) / tnorm + tv_weight * _tv(x)

    grad = jax.jit(jax.value_and_grad(loss))
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(0, 0.1, (n, m.image, m.image, 3)).astype(np.float32))
    # Adam on the input
    mt = jnp.zeros_like(z)
    vt = jnp.zeros_like(z)
    b1, b2, eps = 0.9, 0.999, 1e-8
    last = np.inf
    for t in range(1, steps + 1):
        l, g = grad(z)
        mt = b1 * mt + (1 - b1) * g
        vt = b2 * vt + (1 - b2) * g**2
        mhat = mt / (1 - b1**t)
        vhat = vt / (1 - b2**t)
        z = z - lr * mhat / (jnp.sqrt(vhat) + eps)
        last = float(l)
    return np.asarray(jax.nn.sigmoid(z)), last
