"""Procedural structured-image dataset (ImageNet stand-in, DESIGN.md §2).

The privacy experiments need images with enough spatial structure that an
adversary *can* reconstruct them from shallow feature maps (edges, shapes,
color fields) and with per-image variability so reconstruction from deep
maps is genuinely hard.  We composite random geometric scenes: a gradient
background, 2-5 filled shapes (rectangles / circles / stripes), and mild
sensor noise.  Everything is seeded and shape-parametric.
"""

from typing import Tuple

import numpy as np


def _gradient(rng: np.random.Generator, size: int) -> np.ndarray:
    c0 = rng.uniform(0.0, 1.0, 3)
    c1 = rng.uniform(0.0, 1.0, 3)
    axis = rng.integers(0, 2)
    t = np.linspace(0.0, 1.0, size)
    ramp = t[:, None] if axis == 0 else t[None, :]
    ramp = np.broadcast_to(ramp, (size, size))[..., None]
    img = c0[None, None, :] * (1 - ramp) + c1[None, None, :] * ramp
    return np.ascontiguousarray(img, dtype=np.float32)


def _add_rect(rng, img):
    s = img.shape[0]
    x0, y0 = rng.integers(0, s - 4, 2)
    w, h = rng.integers(3, max(4, s // 2), 2)
    color = rng.uniform(0, 1, 3)
    img[y0 : min(s, y0 + h), x0 : min(s, x0 + w)] = color
    return img


def _add_circle(rng, img):
    s = img.shape[0]
    cx, cy = rng.uniform(2, s - 2, 2)
    r = rng.uniform(2, s / 3)
    color = rng.uniform(0, 1, 3)
    yy, xx = np.mgrid[0:s, 0:s]
    mask = (xx - cx) ** 2 + (yy - cy) ** 2 <= r * r
    img[mask] = color
    return img


def _add_stripes(rng, img):
    s = img.shape[0]
    period = int(rng.integers(2, max(3, s // 4)))
    phase = int(rng.integers(0, period))
    color = rng.uniform(0, 1, 3)
    axis = rng.integers(0, 2)
    idx = (np.arange(s) + phase) % period < max(1, period // 2)
    if axis == 0:
        img[idx, :] = 0.5 * img[idx, :] + 0.5 * color
    else:
        img[:, idx] = 0.5 * img[:, idx] + 0.5 * color
    return img


_SHAPES = (_add_rect, _add_circle, _add_stripes)


def make_images(n: int, size: int = 32, seed: int = 0) -> np.ndarray:
    """Generate ``n`` structured images, NHWC float32 in [0, 1]."""
    rng = np.random.default_rng(seed)
    out = np.empty((n, size, size, 3), np.float32)
    for i in range(n):
        img = _gradient(rng, size)
        for _ in range(int(rng.integers(2, 6))):
            img = _SHAPES[rng.integers(0, len(_SHAPES))](rng, img)
        img = img + rng.normal(0, 0.02, img.shape)
        out[i] = np.clip(img, 0.0, 1.0)
    return out


def train_val_split(
    n_train: int, n_val: int, size: int = 32, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Disjoint train/val batches (different seeds → different scenes)."""
    return (
        make_images(n_train, size=size, seed=seed),
        make_images(n_val, size=size, seed=seed + 10_000),
    )
