"""Partition-aware stage functions — the exact computations that get AOT
lowered to HLO artifacts for the Rust coordinator.

Stage menu per model (DESIGN.md §3):

- ``layer{i}_lin_open``   — f32 linear part of conv/dense layer i
                            (bias included).  Used by Baseline2 (enclave
                            executes it on the trusted CPU) and Split/x.
- ``layer{i}_lin_blind``  — mod-2^24 linear part on blinded input (no
                            bias).  Offloaded to the untrusted device by
                            Slalom/Privacy and Origami tier-1.  The same
                            artifact, run on the raw blinding factors r,
                            yields the precomputed unblinding factors.
- ``tail_p{p}``           — layers p+1..end in the open (ReLU/pool/softmax
                            fused in).  Origami tier-2 / Split/x offload.
- ``head_p{p}``           — layers 1..p in the open: produces Θ(X), the
                            tensor the privacy adversary observes.
- ``full_open``           — whole network (non-private baseline and
                            correctness reference).

Batch size is baked per artifact (PJRT executables are shape-specialized);
the coordinator's dynamic batcher pads to the artifact batch.
"""

from typing import Callable, Dict, List, Tuple

import numpy as np

from .vgg import (
    VggModel,
    apply_layer_open,
    apply_linear_blinded,
    apply_linear_open,
    build_vgg,
    forward_full,
    forward_range,
)

# Sequence indices (paper numbering, pools counted) of partition points we
# export tails/heads for.  Covers Fig 4 (conv-counted 4/6/8 -> seq 5/8/11),
# Fig 9/10 (Split/6, /8, /10), Origami's p=6 and the SSIM sweep layers.
PARTITIONS_32 = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]
PARTITIONS_224 = [5, 6, 8, 10, 11]


def partition_candidates(m: VggModel) -> List[int]:
    return PARTITIONS_32 if m.image == 32 else PARTITIONS_224


def linear_layers(m: VggModel) -> List[int]:
    """Indices of layers with a linear part (conv + dense)."""
    return [l.index for l in m.layers if l.kind in ("conv", "dense")]


def stage_fns(
    m: VggModel, batch: int
) -> Dict[str, Tuple[Callable, List[Tuple[Tuple[int, ...], str]]]]:
    """All stage functions for a model at a fixed batch size.

    Returns ``{stage_name: (fn, [(input_shape, dtype), ...])}`` — exactly
    what aot.py lowers and what manifest.json records.
    """
    img = (batch, m.image, m.image, m.in_channels)
    stages: Dict[str, Tuple[Callable, List[Tuple[Tuple[int, ...], str]]]] = {}

    for idx in linear_layers(m):
        spec = m.layer(idx)
        in_shape = (batch,) + spec.in_shape

        def lin_open(x, _spec=spec):
            return (apply_linear_open(m, _spec, x),)

        def lin_blind(x, _spec=spec):
            return (apply_linear_blinded(m, _spec, x),)

        stages[f"layer{idx:02d}_lin_open"] = (lin_open, [(in_shape, "f32")])
        stages[f"layer{idx:02d}_lin_blind"] = (lin_blind, [(in_shape, "f32")])

    for p in partition_candidates(m):
        spec = m.layer(p)
        feat_shape = (batch,) + spec.out_shape

        def tail(x, _p=p):
            return (forward_range(m, x, _p + 1, len(m.layers)),)

        def head(x, _p=p):
            return (forward_range(m, x, 1, _p),)

        stages[f"tail_p{p:02d}"] = (tail, [(feat_shape, "f32")])
        stages[f"head_p{p:02d}"] = (head, [(img, "f32")])

    def full(x):
        return (forward_full(m, x),)

    stages["full_open"] = (full, [(img, "f32")])
    return stages


def model_manifest_entry(m: VggModel) -> dict:
    """Static layer metadata the Rust side needs for EPC accounting,
    scheduling and cost attribution."""
    return {
        "name": m.name,
        "image": m.image,
        "in_channels": m.in_channels,
        "layers": [
            {
                "index": l.index,
                "kind": l.kind,
                "name": l.name,
                "in_shape": list(l.in_shape),
                "out_shape": list(l.out_shape),
                "has_relu": l.has_relu,
                "flops": l.flops,
                "params_bytes": l.params_bytes,
                "bias": (
                    [float(v) for v in m.biases[l.name]]
                    if l.name in m.biases
                    else []
                ),
            }
            for l in m.layers
        ],
        "partitions": partition_candidates(m),
    }


def reference_logits(m: VggModel, x: np.ndarray) -> np.ndarray:
    """Convenience for tests: open-domain full forward as numpy."""
    return np.asarray(forward_full(m, x))


__all__ = [
    "PARTITIONS_224",
    "PARTITIONS_32",
    "build_vgg",
    "linear_layers",
    "model_manifest_entry",
    "partition_candidates",
    "reference_logits",
    "stage_fns",
]
