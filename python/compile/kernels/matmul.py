"""Tiled Pallas matmul — the MXU-shaped GEMM every linear stage lowers to.

Hardware adaptation (paper targeted CUDA GEMMs on a GTX 1080 Ti): instead
of threadblock/shared-memory tiling we tile for VMEM with BlockSpecs and
accumulate over a K-grid dimension into the revisited output block — the
Pallas idiom for an MXU systolic matmul.  Block shapes default to
(128, 128, 128) (three f32 tiles = 192 KiB, comfortably double-bufferable
in ~16 MiB VMEM) and shrink automatically for small operands.

Two public entry points:

- ``matmul(x, w)``      — f32 GEMM for open-tier stages.
- ``matmul_mod(x, w)``  — exact integer GEMM in f64 with a final
  reduction mod 2^24, used by blinded linear stages.  f64's 53-bit
  mantissa keeps ``sum_k x*w`` exact for |x| < 2^24, |w| < 2^8,
  K < 2^21 — far beyond any VGG layer.  (On a real TPU this would be a
  two-limb f32 kernel; on the CPU PJRT client f64 is exact and simple.)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blind import MOD_P

_DEF_BLOCK = 128


def _pick_block(dim: int, pref: int = _DEF_BLOCK) -> int:
    """Largest divisor of ``dim`` that is <= pref (grid dims must divide)."""
    b = min(pref, dim)
    while dim % b != 0:
        b -= 1
    return b


def _mm_kernel(x_ref, w_ref, o_ref, *, nsteps: int):
    """Grid = (M/bm, N/bn, K/bk); o block revisited across the K axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _mm_mod_kernel(x_ref, w_ref, o_ref, *, nsteps: int):
    """Mod-domain variant: exact f64 accumulate, reduce mod 2^24 at the end.

    The partial sums stay exact in f64 (see module docstring); only the
    final K step folds the accumulator into [0, 2^24) so the artifact's
    output is f32-exact for the Rust side.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _reduce():
        o_ref[...] = jnp.mod(o_ref[...], MOD_P)


def _tiled_matmul(x, w, *, kernel, out_dtype, block=_DEF_BLOCK):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {w.shape}"
    bm, bk, bn = _pick_block(m, block), _pick_block(k, block), _pick_block(n, block)
    nsteps = k // bk
    grid = (m // bm, n // bn, nsteps)
    return pl.pallas_call(
        functools.partial(kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(x, w)


def matmul(x, w, *, block: int = _DEF_BLOCK):
    """f32 tiled Pallas GEMM: ``x @ w`` with VMEM-sized blocks."""
    return _tiled_matmul(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        kernel=_mm_kernel,
        out_dtype=jnp.float32,
        block=block,
    )


def matmul_mod(x, w, *, block: int = _DEF_BLOCK):
    """Exact mod-2^24 GEMM over fixed-point operands (blinded domain).

    ``x`` holds blinded activations in [0, 2^24) (f32-exact integers),
    ``w`` holds quantized weights in [-2^8, 2^8].  Returns f32 integers in
    [0, 2^24).
    """
    out = _tiled_matmul(
        x.astype(jnp.float64),
        w.astype(jnp.float64),
        kernel=_mm_mod_kernel,
        out_dtype=jnp.float64,
        block=block,
    )
    return out.astype(jnp.float32)
