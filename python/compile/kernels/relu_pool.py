"""Non-linear stage kernels: ReLU and 2x2 max-pool (and the fusion).

In Origami these ops run *inside the enclave* for tier-1 layers (the Rust
coordinator implements the same arithmetic natively) and *in the open* for
tier-2 layers, where they appear in the offloaded tail artifacts via these
Pallas kernels.  Both are element-wise / window-local VPU streams; the
pool kernel processes one image block per grid step and reduces the 2x2
windows with a reshape-max, the TPU-friendly layout for stride-2 pooling.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relu_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0)


def _maxpool_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, H, W, C)
    _, h, w, c = x.shape
    o_ref[...] = x.reshape(1, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def _relu_maxpool_kernel(x_ref, o_ref):
    x = jnp.maximum(x_ref[...], 0.0)
    _, h, w, c = x.shape
    o_ref[...] = x.reshape(1, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def relu(x):
    """Element-wise ReLU as a Pallas kernel (any shape)."""
    shape = x.shape
    flat = x.reshape(1, -1)
    out = pl.pallas_call(
        _relu_kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(flat)
    return out.reshape(shape)


def _pool_call(kernel, x):
    n, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"pool needs even H,W, got {x.shape}"
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(x)


def maxpool2x2(x):
    """2x2 stride-2 max pool over NHWC."""
    return _pool_call(_maxpool_kernel, x)


def relu_maxpool2x2(x):
    """Fused ReLU + 2x2 max pool (the VGG block epilogue)."""
    return _pool_call(_relu_maxpool_kernel, x)
