"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: pytest (plus hypothesis sweeps)
asserts each kernel against its oracle over randomized shapes, dtypes and
values.  They are intentionally written with stock jax/lax ops only — no
Pallas — so a bug cannot be shared between kernel and oracle.
"""

import jax.numpy as jnp
from jax import lax

from .blind import MOD_P, SCALE_X, SCALE_XW


def matmul_ref(x, w):
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def matmul_mod_ref(x, w):
    y = jnp.matmul(x.astype(jnp.float64), w.astype(jnp.float64))
    return jnp.mod(y, MOD_P).astype(jnp.float32)


def conv2d_ref(x, w, b=None, *, stride: int = 1, padding: str = "SAME"):
    y = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b if b is not None else y


def conv2d_mod_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    y = lax.conv_general_dilated(
        x.astype(jnp.float64),
        w.astype(jnp.float64),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jnp.mod(y, MOD_P).astype(jnp.float32)


def quantize_blind_ref(x, r):
    q = jnp.round(x.astype(jnp.float32) * SCALE_X)
    return jnp.mod(q + r, MOD_P)


def unblind_dequantize_ref(y_b, r_u):
    d = jnp.mod(y_b.astype(jnp.float32) - r_u, MOD_P)
    centered = jnp.where(d >= MOD_P / 2, d - MOD_P, d)
    return centered / SCALE_XW


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def maxpool2x2_ref(x):
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def relu_maxpool2x2_ref(x):
    return maxpool2x2_ref(relu_ref(x))


def ssim_map_ref(x, y, *, win: int = 8):
    c1 = (0.01 * 1.0) ** 2
    c2 = (0.03 * 1.0) ** 2
    n, h, w, c = x.shape
    gh, gw = h // win, w // win
    xw = x.reshape(n, gh, win, gw, win, c).transpose(0, 1, 3, 2, 4, 5)
    yw = y.reshape(n, gh, win, gw, win, c).transpose(0, 1, 3, 2, 4, 5)
    xw = xw.reshape(n, gh, gw, win * win, c).astype(jnp.float32)
    yw = yw.reshape(n, gh, gw, win * win, c).astype(jnp.float32)
    mx = xw.mean(axis=3)
    my = yw.mean(axis=3)
    vx = xw.var(axis=3)
    vy = yw.var(axis=3)
    cov = (xw * yw).mean(axis=3) - mx * my
    lum = (2 * mx * my + c1) / (mx**2 + my**2 + c1)
    struct = (2 * cov + c2) / (vx + vy + c2)
    return lum * struct


def mean_ssim_ref(x, y, *, win: int = 8):
    return jnp.mean(ssim_map_ref(x, y, win=win))
