"""SSIM (structural similarity) as a windowed-statistics Pallas kernel.

The paper scores an adversary's reconstructions with SSIM (Fig. 8).  We
compute SSIM over non-overlapping ``win``x``win`` windows (the paper's
"average SSIM"; the Gaussian-window variant changes constants, not the
ordering across partition layers, which is what the experiment needs).
One kernel invocation computes the per-window mean/variance/covariance
statistics and the SSIM value — a local reduction ideal for a VPU block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_C1 = (0.01 * 1.0) ** 2  # K1=0.01, dynamic range 1.0
_C2 = (0.03 * 1.0) ** 2  # K2=0.03


def _ssim_kernel(x_ref, y_ref, o_ref, *, win: int):
    x = x_ref[...].astype(jnp.float32)  # (1, win, win, C)
    y = y_ref[...].astype(jnp.float32)
    n = float(win * win)
    mx = jnp.sum(x, axis=(1, 2), keepdims=True) / n
    my = jnp.sum(y, axis=(1, 2), keepdims=True) / n
    dx, dy = x - mx, y - my
    vx = jnp.sum(dx * dx, axis=(1, 2), keepdims=True) / n
    vy = jnp.sum(dy * dy, axis=(1, 2), keepdims=True) / n
    cov = jnp.sum(dx * dy, axis=(1, 2), keepdims=True) / n
    lum = (2.0 * mx * my + _C1) / (mx * mx + my * my + _C1)
    struct = (2.0 * cov + _C2) / (vx + vy + _C2)
    o_ref[...] = lum * struct  # (1, 1, 1, C) — keepdims preserved the rank


def ssim_map(x, y, *, win: int = 8):
    """Per-window SSIM over NHWC images in [0,1] → (N, H/win, W/win, C)."""
    n, h, w, c = x.shape
    assert h % win == 0 and w % win == 0, f"{(h, w)} not divisible by {win}"
    gh, gw = h // win, w // win
    out = pl.pallas_call(
        functools.partial(_ssim_kernel, win=win),
        grid=(n, gh, gw),
        in_specs=[
            pl.BlockSpec((1, win, win, c), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, win, win, c), lambda i, j, k: (i, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, c), lambda i, j, k: (i, j, k, 0)),
        out_shape=jax.ShapeDtypeStruct((n, gh, gw, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
    return out


def mean_ssim(x, y, *, win: int = 8):
    """Scalar mean SSIM between two image batches (the Fig. 8 metric)."""
    return jnp.mean(ssim_map(x, y, win=win))
