"""Slalom-style cryptographic blinding arithmetic as Pallas kernels.

The paper offloads linear layers to an untrusted device after *additively
blinding* fixed-point activations inside the enclave (Sec. III-C):

    quantize:   q = round(x * 2^fx)                       (integers)
    blind:      b = (q + r) mod P         r ~ Uniform[0, P)   (one-time pad
                                          over the additive group Z_P)
    offload:    y_b = W_q . b  mod P      (linear, so noise stays linear)
    unblind:    y_q = (y_b - W_q . r) mod P, centered into [-P/2, P/2)
    dequantize: y = y_q / 2^(fx+fw)

With P = 2^24 every value is exactly representable in f32, which is the
whole trick: the untrusted device does plain float linear algebra yet the
arithmetic is exact modular integer math.  Additive blinding with uniform
``r`` over Z_P is information-theoretically hiding (a one-time pad), so
the offloaded tensor leaks nothing; decodability requires the *true*
quantized result to fit in the centered range, i.e. |y| < 2^(23-fx-fw) —
an activation-range invariant the Rust enclave asserts at run time.

Both hot loops are bandwidth-bound element-wise streams; blocks are sized
to a VMEM-resident (8,128)-multiple lane tile (the VPU layout), the TPU
analogue of the CUDA grid-stride loops Slalom used.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed-point format (Slalom uses 2^8 scaling and p ~ 2^24; we use the
# full additive group Z_{2^24} since only additive blinding is needed).
FRAC_BITS_X = 8
FRAC_BITS_W = 8
SCALE_X = float(1 << FRAC_BITS_X)
SCALE_W = float(1 << FRAC_BITS_W)
SCALE_XW = SCALE_X * SCALE_W
MOD_P = float(1 << 24)

_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES  # one VPU tile of f32


def _pad_to_tiles(flat):
    n = flat.shape[0]
    rows = max(1, -(-n // _LANES))
    rows += (-rows) % _SUBLANES
    padded = jnp.zeros((rows * _LANES,), flat.dtype).at[:n].set(flat)
    return padded.reshape(rows, _LANES), n


def _rows_block(rows: int) -> int:
    """Block height: a multiple of the sublane count dividing ``rows``."""
    b = min(rows, 512)
    while rows % b != 0:
        b -= _SUBLANES if b > _SUBLANES else 1
        if b <= _SUBLANES:
            return _SUBLANES if rows % _SUBLANES == 0 else rows
    return b


def _quantize_blind_kernel(x_ref, r_ref, o_ref):
    q = jnp.round(x_ref[...] * SCALE_X)
    o_ref[...] = jnp.mod(q + r_ref[...], MOD_P)


def _unblind_dequantize_kernel(y_ref, ru_ref, o_ref):
    d = jnp.mod(y_ref[...] - ru_ref[...], MOD_P)
    centered = jnp.where(d >= MOD_P / 2, d - MOD_P, d)
    o_ref[...] = centered / SCALE_XW


def _elementwise(kernel, out_dtype, *tensors):
    """Run an element-wise kernel over flattened, lane-tiled operands."""
    shape = tensors[0].shape
    flats = [t.reshape(-1).astype(jnp.float32) for t in tensors]
    tiled, n = _pad_to_tiles(flats[0])
    rest = [_pad_to_tiles(f)[0] for f in flats[1:]]
    rows = tiled.shape[0]
    br = _rows_block(rows)
    grid = (rows // br,)
    spec = pl.BlockSpec((br, _LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * (1 + len(rest)),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), out_dtype),
        interpret=True,
    )(tiled, *rest)
    return out.reshape(-1)[:n].reshape(shape)


def quantize_blind(x, r):
    """Fused quantize→blind: ``(round(x·2^fx) + r) mod 2^24`` (f32 integers).

    ``r`` must be uniform integers in [0, 2^24) drawn from the enclave's
    private PRNG stream; the result is safe to hand to an untrusted device.
    """
    return _elementwise(_quantize_blind_kernel, jnp.float32, x, r)


def unblind_dequantize(y_b, r_u):
    """Fused unblind→dequantize.

    ``y_b`` is the untrusted device's mod-2^24 linear output, ``r_u`` the
    precomputed unblinding factors ``(W_q · r) mod 2^24``.  Returns real
    activations ``y`` (f32).
    """
    return _elementwise(_unblind_dequantize_kernel, jnp.float32, y_b, r_u)


def quantize_weights(w):
    """Quantize weights to fixed point: ``round(w · 2^fw)`` as f32 integers.

    Values are clamped to (-2^15, 2^15) so products with blinded
    activations stay exact in the f64 accumulate of ``matmul_mod``.
    """
    q = jnp.round(jnp.asarray(w, jnp.float32) * SCALE_W)
    return jnp.clip(q, -(2.0**15) + 1, 2.0**15 - 1)
