"""Layer-1 Pallas kernels (interpret=True) and their pure-jnp oracles.

Every kernel here is the compute hot-spot of one stage of the Origami
pipeline and lowers into the same HLO as the surrounding L2 jax code:

- ``matmul``      — tiled MXU-shaped matrix multiply (f32 / f64-exact
                    mod-domain variant used by blinded linear stages)
- ``conv2d``      — im2col + tiled matmul convolution
- ``quantize_blind`` / ``unblind_dequantize``
                  — Slalom-style fixed-point blinding arithmetic mod 2^24
- ``relu``, ``maxpool2x2``, ``relu_maxpool2x2``
                  — non-linear stages for open-tier artifacts
- ``ssim_map``    — windowed structural-similarity statistics (privacy
                    metric of the paper's Fig. 8)

All kernels run under ``interpret=True`` so the lowered HLO executes on the
CPU PJRT client the Rust coordinator embeds (real-TPU lowering would emit
Mosaic custom-calls the CPU plugin cannot run).
"""

from .blind import (
    FRAC_BITS_W,
    FRAC_BITS_X,
    MOD_P,
    SCALE_W,
    SCALE_X,
    SCALE_XW,
    quantize_blind,
    quantize_weights,
    unblind_dequantize,
)
from .conv import conv2d, conv2d_mod
from .matmul import matmul, matmul_mod
from .relu_pool import maxpool2x2, relu, relu_maxpool2x2
from .ssim import mean_ssim, ssim_map

__all__ = [
    "FRAC_BITS_W",
    "FRAC_BITS_X",
    "MOD_P",
    "SCALE_W",
    "SCALE_X",
    "SCALE_XW",
    "conv2d",
    "conv2d_mod",
    "matmul",
    "matmul_mod",
    "maxpool2x2",
    "mean_ssim",
    "quantize_blind",
    "quantize_weights",
    "relu",
    "relu_maxpool2x2",
    "ssim_map",
    "unblind_dequantize",
]
