"""Convolution as im2col + the tiled Pallas GEMM.

The paper's convolutions (the bulk of VGG compute) are offloaded as matrix
multiplications (Sec. III-C: "compute intensive convolutions (basically
matrix multiplications)").  We make that literal: patches are gathered
into an im2col matrix (the HBM→VMEM schedule a CUDA kernel would express
with shared-memory staging) and the product runs on the same MXU-shaped
Pallas GEMM as the dense layers, in both the open (f32) and blinded
(mod-2^24) domains.
"""

import jax.numpy as jnp
from jax import lax

from .matmul import matmul, matmul_mod


def _im2col(x, kh: int, kw: int, stride: int, padding: str):
    """NHWC → (N·OH·OW, KH·KW·C) patch matrix.

    Uses ``conv_general_dilated_patches`` so the gather lowers to one
    XLA op; the channel-major patch order is transposed to (kh, kw, c) to
    match HWIO weight layout.
    """
    n, h, w, c = x.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, OH, OW, C*KH*KW) with channel-major ordering (c, kh, kw)
    oh, ow = patches.shape[1], patches.shape[2]
    patches = patches.reshape(n, oh, ow, c, kh * kw)
    patches = jnp.swapaxes(patches, 3, 4)  # (..., kh*kw, c)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d(x, w, b=None, *, stride: int = 1, padding: str = "SAME"):
    """Open-domain conv: f32 im2col GEMM (+ bias).  x: NHWC, w: HWIO."""
    kh, kw, _, co = w.shape
    cols, (n, oh, ow) = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(kh * kw * w.shape[2], co)
    y = matmul(cols, wmat).reshape(n, oh, ow, co)
    if b is not None:
        y = y + b
    return y


def conv2d_mod(x_b, w_q, *, stride: int = 1, padding: str = "SAME"):
    """Blinded-domain conv: exact mod-2^24 im2col GEMM.

    ``x_b`` holds blinded fixed-point activations in [0, 2^24); ``w_q``
    quantized integer weights (HWIO).  Bias is *not* added here — in the
    blinded domain the enclave folds the (quantized) bias in after
    unblinding, keeping the offloaded computation purely linear.

    Note: SAME padding inserts zeros, which in the blinded domain are
    *unblinded* zeros; the Rust enclave therefore blinds with ``r`` drawn
    for the padded geometry too (factors cover the im2col of the padded
    tensor), matching how Slalom handles padding.
    """
    kh, kw, _, co = w_q.shape
    cols, (n, oh, ow) = _im2col(x_b, kh, kw, stride, padding)
    wmat = w_q.reshape(kh * kw * w_q.shape[2], co)
    return matmul_mod(cols, wmat).reshape(n, oh, ow, co)
