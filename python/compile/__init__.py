"""Build-time compile path: L2 jax model + L1 Pallas kernels + AOT export.

Nothing in this package runs on the request path — ``aot.py`` lowers the
stages to HLO text once and the Rust coordinator executes them via PJRT.
"""

import jax

# The blinded-domain GEMM accumulates exactly in f64 (53-bit mantissa)
# before reducing mod 2^24 — see kernels/matmul.py.  x64 must be enabled
# before any tracing happens.
jax.config.update("jax_enable_x64", True)
