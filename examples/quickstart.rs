//! Quickstart: one private inference through the Origami pipeline.
//!
//! ```bash
//! make artifacts                       # once: AOT-lower the model
//! cargo run --release --example quickstart
//! ```
//!
//! What happens, end to end (all Rust, Python never runs here):
//! 1. a client encrypts an image for its attested enclave session;
//! 2. the enclave decrypts it, quantizes + additively blinds each tier-1
//!    feature map (one-time pad mod 2^24) and offloads the linear ops to
//!    the untrusted device;
//! 3. the enclave unblinds with precomputed factors, applies bias/ReLU;
//! 4. past the privacy partition (layer 6), the rest of the network runs
//!    uninterrupted in the open on the device;
//! 5. probabilities return; the ledger shows where every microsecond went.

use origami::config::Config;
use origami::enclave::cost::Ledger;
use origami::launcher::{encrypt_request, synth_images, Stack};
use origami::util::stats::fmt_ms;

fn main() -> anyhow::Result<()> {
    let config = Config::default(); // vgg16-32, origami/6, cpu offload
    let stack = Stack::load(&config)?;
    let model = stack.model(&config.model)?;
    println!(
        "loaded {} ({} layers, {} exported stages)",
        model.name,
        model.num_layers(),
        model.stages.len()
    );

    let mut strategy = stack.build_strategy(&config)?;
    println!(
        "strategy {} ready: enclave requirement {:.1} KB",
        strategy.name(),
        strategy.enclave_requirement_bytes() as f64 / 1024.0
    );

    // Client side: synthesize an "X-ray" and encrypt it for session 0.
    let image = &synth_images(1, model.image, model.in_channels, 7)[0];
    let ciphertext = encrypt_request(&config, 0, image);

    // Warm-up (artifact compilation happens lazily on first use).
    strategy.infer(&ciphertext, 1, &[0], &mut Ledger::new())?;

    // The measured private inference.
    let mut ledger = Ledger::new();
    let probs = strategy.infer(&ciphertext, 1, &[0], &mut ledger)?;

    let (top, p) = probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!("\nprediction: class {top} (p={p:.4})");
    println!(
        "inference cost: {} simulated ({}% actually measured on this machine)",
        fmt_ms(ledger.grand_total_ms()),
        (ledger.measured_fraction() * 100.0).round()
    );
    println!("breakdown:");
    for (name, ms) in ledger.breakdown() {
        println!("  {name:<16} {}", fmt_ms(ms));
    }
    Ok(())
}
