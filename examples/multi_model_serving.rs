//! Multi-model serving: two Origami deployments sharing one tier-2 lane
//! fabric, with queue-depth autoscaling — end to end on the hermetic
//! reference backend (no artifacts required).
//!
//! ```bash
//! cargo run --release --example multi_model_serving
//! ```
//!
//! What happens:
//! 1. a `sim16` Origami/2 pool (the hot tenant) and a `sim8` Origami/6
//!    pool (the cold tenant) register in one [`Deployment`]: each model
//!    keeps its own tier-1 enclave shards and pad domains, but both
//!    models' open tails drain through a single shared [`LaneFabric`]
//!    with a cpu+gpu lane cycle and weighted-fair popping;
//! 2. a request burst drives the queue-depth autoscaler: tier-1 worker
//!    counts and the fabric's lane count grow under backlog and shrink
//!    back to their floors once drained;
//! 3. every response is compared bit-for-bit against the model's serial
//!    single-worker path, and per-tenant / per-lane accounting is
//!    printed.
//!
//! [`Deployment`]: origami::coordinator::Deployment
//! [`LaneFabric`]: origami::coordinator::LaneFabric

use origami::config::{Config, ModelSpec};
use origami::enclave::cost::Ledger;
use origami::launcher::{
    build_strategy_with, encrypt_request, executor_for, start_deployment_from_config,
    synth_images,
};
use origami::util::stats::fmt_ms;

fn serial_reference(cfg: &Config, sessions: &[u64], images: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let (executor, model) = executor_for(cfg).expect("reference stack");
    let mut strategy = build_strategy_with(executor, model, cfg).expect("strategy");
    sessions
        .iter()
        .zip(images)
        .map(|(&s, img)| {
            strategy
                .infer(&encrypt_request(cfg, s, img), 1, &[s], &mut Ledger::new())
                .expect("serial inference")
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let base = Config {
        model: "sim16".into(),
        models: "sim16=origami/2*2,sim8=origami/6".into(),
        workers: 1,
        min_workers: 1,
        max_workers: 4,
        lanes: 1,
        min_lanes: 1,
        max_lanes: 4,
        lane_devices: "cpu,gpu".into(),
        autoscale: true,
        autoscale_tick_ms: 2,
        max_batch: 4,
        max_delay_ms: 1.0,
        pool_epochs: 32,
        occupancy_flush: true,
        ..Config::default()
    };
    let specs = ModelSpec::parse_list(&base.models)?;
    println!("deploying {} tenants over one shared lane fabric…", specs.len());
    let dep = start_deployment_from_config(&base, &specs)?;

    // Workloads: hot sim16 traffic + a trickle of sim8.
    let (n_hot, n_cold) = (48usize, 8usize);
    let cfg_hot = specs[0].apply(&base);
    let cfg_cold = specs[1].apply(&base);
    let hot_sessions: Vec<u64> = (0..n_hot as u64).collect();
    let cold_sessions: Vec<u64> = (0..n_cold as u64).map(|i| 100_000 + i).collect();
    let hot_images = synth_images(n_hot, 16, 3, cfg_hot.seed);
    let cold_images = synth_images(n_cold, 8, 3, cfg_cold.seed);
    let hot_expected = serial_reference(&cfg_hot, &hot_sessions, &hot_images);
    let cold_expected = serial_reference(&cfg_cold, &cold_sessions, &cold_images);

    let t = std::time::Instant::now();
    let mut replies = Vec::new();
    for i in 0..n_hot.max(n_cold) {
        if i < n_hot {
            let s = hot_sessions[i];
            let ct = encrypt_request(&cfg_hot, s, &hot_images[i]);
            replies.push(("sim16", i, dep.submit("sim16", ct, s).map_err(|e| anyhow::anyhow!("{e}"))?));
        }
        if i < n_cold {
            let s = cold_sessions[i];
            let ct = encrypt_request(&cfg_cold, s, &cold_images[i]);
            replies.push(("sim8", i, dep.submit("sim8", ct, s).map_err(|e| anyhow::anyhow!("{e}"))?));
        }
    }
    let peak_workers = dep.active_workers("sim16");
    let peak_lanes = dep.lane_count();

    let mut identical = 0usize;
    for (model, i, reply) in replies {
        let resp = reply
            .recv()
            .ok_or_else(|| anyhow::anyhow!("{model} req {i}: reply channel closed"))?;
        anyhow::ensure!(resp.error.is_none(), "{model} req {i}: {:?}", resp.error);
        let expected = if model == "sim16" {
            &hot_expected[i]
        } else {
            &cold_expected[i]
        };
        anyhow::ensure!(
            &resp.probs == expected,
            "{model} request {i} diverged from its serial path"
        );
        identical += 1;
    }
    let wall = t.elapsed().as_secs_f64() * 1e3;

    println!(
        "served {identical} requests in {} — every output bit-identical to its \
         model's serial path",
        fmt_ms(wall)
    );
    println!(
        "mid-burst scale observed: sim16 workers={peak_workers} fabric lanes={peak_lanes}"
    );

    let m = dep.shutdown();
    println!("\nper-tenant fabric accounting:");
    for (name, t) in &m.fabric.tenants {
        println!(
            "  {name:<6} batches={:<4} requests={:<4} tier2 {}  total {}",
            t.batches,
            t.requests,
            fmt_ms(t.tier2_sim_ms),
            fmt_ms(t.sim_ms_total),
        );
    }
    println!("\nper-lane ledgers (device-aware):");
    for (i, busy) in m.fabric.lane_sim_ms.iter().enumerate() {
        println!(
            "  lane {i} [{}] busy {} ({} batches)",
            m.fabric.lane_device[i].name(),
            fmt_ms(*busy),
            m.fabric.lane_batches[i],
        );
    }
    println!(
        "\nautoscale: fabric peak {} lanes ({} grow / {} shrink); sim16 pool peak {} \
         workers ({} grow / {} shrink)",
        m.fabric.peak_lanes,
        m.fabric.grow_events,
        m.fabric.shrink_events,
        m.models["sim16"].peak_workers,
        m.models["sim16"].grow_events,
        m.models["sim16"].shrink_events,
    );
    Ok(())
}
