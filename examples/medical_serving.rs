//! End-to-end serving driver (the repo's headline validation run).
//!
//! Scenario from the paper's motivation (§III-A): a health-care provider
//! sends private medical images to a cloud classification service.  This
//! driver stands the whole stack up — router, dynamic batcher, worker
//! threads each owning a PJRT client + enclave + factor pools — fires an
//! open-loop Poisson stream of encrypted requests at it, verifies every
//! answer against the non-private reference, and reports latency and
//! throughput per strategy.  Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example medical_serving -- \
//!     [--requests 96] [--rate 40] [--strategies origami,slalom,baseline2]
//! ```

use origami::config::Config;
use origami::coordinator::Router;
use origami::launcher::{encrypt_request, start_engine_from_config, synth_images, Stack};
use origami::util::cli::Args;
use origami::util::json::{self, Value};
use origami::util::stats::{fmt_ms, Summary};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 96)?;
    let rate = args.f64_or("rate", 40.0)?;
    let strategies = args.str_list_or("strategies", &["origami/6", "slalom", "baseline2", "open"]);
    let base = Config::from_args(&args)?;

    // Reference logits for verification (non-private full model).
    let stack = Stack::load(&base)?;
    let model = stack.model(&base.model)?;
    let images = synth_images(requests, model.image, model.in_channels, 42);
    let sample_bytes = stack.sample_bytes(&base.model)?;
    let batches = stack.artifact_batches(&base.model)?;
    let reference: Vec<Vec<f32>> = {
        let mut cfg = base.clone();
        cfg.strategy = "open".into();
        let mut s = stack.build_strategy(&cfg)?;
        images
            .iter()
            .map(|img| {
                let ct = encrypt_request(&base, 0, img);
                s.infer(&ct, 1, &[0], &mut Default::default()).unwrap()
            })
            .collect()
    };
    println!(
        "medical-serving workload: {requests} encrypted images @ {rate} req/s, \
         model {}, verifying every response\n",
        base.model
    );

    let mut report_rows: Vec<Value> = Vec::new();
    for strategy in &strategies {
        let mut cfg = base.clone();
        cfg.strategy = strategy.clone();
        cfg.workers = args.usize_or("workers", 2)?;
        let engine = start_engine_from_config(cfg.clone(), sample_bytes, batches.clone())?;
        let mut router = Router::new();
        router.register(&base.model, engine, sample_bytes);

        // Open-loop Poisson arrivals; all under session 0 (one attested
        // batch channel), verified against the open reference.
        let router = std::sync::Arc::new(router);
        let mut rng = origami::util::rng::Rng::new(7);
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for img in images.iter() {
            let ct = encrypt_request(&cfg, 0, img);
            let r = router.clone();
            let model_name = base.model.clone();
            handles.push(std::thread::spawn(move || {
                r.infer_blocking(&model_name, ct, 0)
            }));
            std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rate)));
        }
        let mut lat = Summary::new();
        let mut sim = Summary::new();
        let mut wrong = 0usize;
        let mut failed = 0usize;
        for (i, h) in handles.into_iter().enumerate() {
            match h.join().unwrap() {
                Ok(resp) if resp.error.is_none() => {
                    lat.record(resp.latency_ms);
                    sim.record(resp.sim_ms);
                    let diff = resp
                        .probs
                        .iter()
                        .zip(&reference[i])
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    if diff > 0.05 {
                        wrong += 1;
                    }
                }
                _ => failed += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let served = requests - failed;
        println!(
            "{strategy:<12} {served}/{requests} ok, {wrong} mismatched | \
             {:.1} req/s | latency p50 {} p95 {} p99 {} | sim/req p50 {}",
            served as f64 / wall,
            fmt_ms(lat.p50()),
            fmt_ms(lat.p95()),
            fmt_ms(lat.p99()),
            fmt_ms(sim.p50()),
        );
        report_rows.push(json::obj(vec![
            ("strategy", json::s(strategy)),
            ("served", json::num(served as f64)),
            ("mismatched", json::num(wrong as f64)),
            ("throughput_rps", json::num(served as f64 / wall)),
            ("latency_p50_ms", json::num(lat.p50())),
            ("latency_p95_ms", json::num(lat.p95())),
            ("latency_p99_ms", json::num(lat.p99())),
            ("sim_per_req_p50_ms", json::num(sim.p50())),
        ]));
        std::sync::Arc::try_unwrap(router)
            .map_err(|_| anyhow::anyhow!("router leak"))?
            .shutdown();
        anyhow::ensure!(wrong == 0, "{strategy}: {wrong} responses diverged!");
        anyhow::ensure!(failed == 0, "{strategy}: {failed} requests failed!");
    }

    let out = json::obj(vec![
        ("workload", json::s("medical_serving")),
        ("requests", json::num(requests as f64)),
        ("rate_rps", json::num(rate)),
        ("model", json::s(&base.model)),
        ("rows", Value::Arr(report_rows)),
    ]);
    json::to_file(std::path::Path::new("bench_results/medical_serving.json"), &out)?;
    println!("\nwrote bench_results/medical_serving.json — all responses verified ✓");
    Ok(())
}
