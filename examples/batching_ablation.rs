//! Ablation: dynamic-batcher policy sweep (max-batch × max-delay).
//!
//! DESIGN.md's coordinator calls out the batching policy as a design
//! choice; this example quantifies it.  For each (max_batch, max_delay)
//! cell we drive the Origami engine with the same Poisson request stream
//! and report throughput and p95 latency — the classic trade-off surface
//! a deployment tunes.
//!
//! ```bash
//! cargo run --release --example batching_ablation -- [--requests 48] [--rate 60]
//! ```

use origami::config::Config;
use origami::launcher::{encrypt_request, start_engine_from_config, synth_images, Stack};
use origami::util::cli::Args;
use origami::util::json::{self, Value};
use origami::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let requests = args.usize_or("requests", 48)?;
    let rate = args.f64_or("rate", 60.0)?;
    let base = Config::from_args(&args)?;

    let stack = Stack::load(&base)?;
    let model = stack.model(&base.model)?;
    let sample_bytes = stack.sample_bytes(&base.model)?;
    let batches = stack.artifact_batches(&base.model)?;
    let images = synth_images(requests, model.image, model.in_channels, 5);

    println!(
        "batching ablation: {requests} reqs @ {rate}/s, strategy {}\n",
        base.strategy
    );
    println!(
        "{:>9} {:>10} | {:>10} {:>12} {:>12} {:>10}",
        "max_batch", "delay_ms", "req/s", "p50_ms", "p95_ms", "mean_bsz"
    );
    let mut rows: Vec<Value> = Vec::new();
    for &max_batch in &[1usize, 4, 8] {
        for &delay in &[0.0f64, 2.0, 8.0] {
            let mut cfg = base.clone();
            cfg.workers = 1;
            cfg.max_batch = max_batch;
            cfg.max_delay_ms = delay;
            let engine = start_engine_from_config(cfg.clone(), sample_bytes, batches.clone())?;
            let engine = std::sync::Arc::new(engine);
            let mut rng = origami::util::rng::Rng::new(99);
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for (i, img) in images.iter().enumerate() {
                let ct = encrypt_request(&cfg, 0, img);
                let eng = engine.clone();
                let m = cfg.model.clone();
                handles.push(std::thread::spawn(move || eng.infer_blocking(&m, ct, 0)));
                let _ = i;
                std::thread::sleep(std::time::Duration::from_secs_f64(rng.exp(rate)));
            }
            let mut lat = Summary::new();
            let mut failed = 0;
            for h in handles {
                match h.join().unwrap() {
                    Ok(r) if r.error.is_none() => lat.record(r.latency_ms),
                    _ => failed += 1,
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let engine = std::sync::Arc::try_unwrap(engine)
                .map_err(|_| anyhow::anyhow!("engine leak"))?;
            let metrics = engine.shutdown();
            anyhow::ensure!(failed == 0, "{failed} requests failed");
            let rps = requests as f64 / wall;
            println!(
                "{:>9} {:>10.1} | {:>10.1} {:>12.2} {:>12.2} {:>10.2}",
                max_batch,
                delay,
                rps,
                lat.p50(),
                lat.p95(),
                metrics.batch_size.mean()
            );
            rows.push(json::obj(vec![
                ("max_batch", json::num(max_batch as f64)),
                ("max_delay_ms", json::num(delay)),
                ("throughput_rps", json::num(rps)),
                ("latency_p50_ms", json::num(lat.p50())),
                ("latency_p95_ms", json::num(lat.p95())),
                ("mean_batch", json::num(metrics.batch_size.mean())),
            ]));
        }
    }
    json::to_file(
        std::path::Path::new("bench_results/batching_ablation.json"),
        &json::obj(vec![("rows", Value::Arr(rows))]),
    )?;
    println!("\nwrote bench_results/batching_ablation.json");
    Ok(())
}
