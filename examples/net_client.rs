//! Attested network client: the full front-door session lifecycle over
//! a loopback TCP socket, against the hermetic `sim16` model.
//!
//! ```bash
//! cargo run --release --example net_client
//! ```
//!
//! What happens, end to end:
//! 1. a server thread deploys `sim16` behind the attested front door
//!    (`NetServer` on an ephemeral loopback port);
//! 2. the client connects, sends an attestation challenge, verifies the
//!    enclave's MACed report (measurement + challenge + freshness) and
//!    the session grant riding under the derived session key;
//! 3. it encrypts an image under the granted session word (AES-CTR
//!    keystream keyed by session id + epoch) and runs an inference;
//! 4. it refreshes the session — the keystream epoch bumps, so the same
//!    image encrypts to a *different* ciphertext — and infers again;
//! 5. both answers must be bit-identical: the epoch changes the wire
//!    bytes, never the math.

use std::sync::Arc;

use origami::config::{Config, ModelSpec};
use origami::coordinator::NetClient;
use origami::launcher::{
    encrypt_request, net_options_from_config, start_deployment_from_config, synth_images,
};

fn main() -> anyhow::Result<()> {
    let config = Config {
        model: "sim16".into(),
        strategy: "origami/6".into(),
        workers: 2,
        listen: "127.0.0.1:0".into(),
        ..Config::default()
    };
    let spec = ModelSpec::parse(&config.model)?;
    let dep = Arc::new(start_deployment_from_config(&config, &[spec])?);
    let opts = net_options_from_config(&config);
    let server = origami::coordinator::NetServer::start(dep.clone(), opts.clone())?;
    let addr = server.local_addr();
    println!("front door on {addr} (session ttl {} ms)", dep.sessions().ttl_ms());

    // --- attested handshake -----------------------------------------
    let mut client = NetClient::connect(
        &addr,
        &config.model,
        &opts.measurement,
        &opts.platform_key,
        0xC4A11E46E, // fresh challenge
    )?;
    println!(
        "attested: session {} epoch {} (report ttl {} ms)",
        client.session(),
        client.epoch(),
        client.report().ttl_ms
    );

    // --- inference under the session keystream ----------------------
    let image = &synth_images(1, 16, 3, config.seed)[0];
    let ct0 = encrypt_request(&config, client.session_word(), image);
    let first = client.infer(&ct0).map_err(|e| anyhow::anyhow!("{e}"))?;
    let top = first
        .probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, p)| (i, *p))
        .unwrap_or((0, 0.0));
    println!(
        "inference: class {} (p={:.4}) in {:.2} ms",
        top.0, top.1, first.latency_ms
    );

    // --- refresh: new keystream epoch, identical math ---------------
    let epoch = client.refresh().map_err(|e| anyhow::anyhow!("{e}"))?;
    let ct1 = encrypt_request(&config, client.session_word(), image);
    anyhow::ensure!(ct0 != ct1, "epoch bump must change the ciphertext");
    let second = client.infer(&ct1).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        first.probs == second.probs,
        "outputs must be bit-identical across epochs"
    );
    println!("refreshed to epoch {epoch}: new keystream, bit-identical answer");

    // --- revoke and shut down ---------------------------------------
    let existed = client.revoke().map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(existed, "revocation should find the live session");
    println!("session revoked; shutting down");
    server.shutdown();
    Arc::try_unwrap(dep)
        .map_err(|_| anyhow::anyhow!("deployment still referenced"))?
        .shutdown();
    Ok(())
}
