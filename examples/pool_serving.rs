//! Pool serving: the sharded multi-worker pool with pipelined Origami
//! tiers, end to end on the hermetic reference backend (no artifacts
//! required).
//!
//! ```bash
//! cargo run --release --example pool_serving
//! ```
//!
//! What happens:
//! 1. a serial baseline (1 worker, no tier pipelining) serves M
//!    encrypted requests — the classic demo loop;
//! 2. a 4-worker pool serves the *same* requests: sessions shard by
//!    affinity (`session % 4`), each worker's enclave draws its blinding
//!    pads from a disjoint keyspace, and inside every worker batch k+1's
//!    blinded tier-1 overlaps batch k's open tier-2, with idle tier-2
//!    lanes stealing tails from busy shards;
//! 3. outputs are compared bit-for-bit, and throughput is reported on
//!    both the wall clock and the simulated-cost timeline (independent
//!    enclave/device lanes per worker — deterministic on any host).

use origami::config::Config;
use origami::coordinator::PoolMetrics;
use origami::launcher::{encrypt_request, start_pool_from_config, synth_images};
use origami::util::stats::fmt_ms;

fn serve(
    cfg: &Config,
    images: &[Vec<f32>],
) -> anyhow::Result<(Vec<Vec<f32>>, f64, PoolMetrics)> {
    let pool = start_pool_from_config(cfg.clone())?;
    let t = std::time::Instant::now();
    let replies: Vec<_> = images
        .iter()
        .enumerate()
        .map(|(i, img)| {
            let session = i as u64;
            pool.submit(&cfg.model, encrypt_request(cfg, session, img), session)
        })
        .collect::<Result<_, _>>()?;
    let mut outputs = Vec::with_capacity(replies.len());
    for (i, r) in replies.into_iter().enumerate() {
        let resp = r
            .recv()
            .ok_or_else(|| anyhow::anyhow!("request {i}: reply channel closed"))?;
        anyhow::ensure!(resp.error.is_none(), "request {i}: {:?}", resp.error);
        outputs.push(resp.probs);
    }
    let wall_ms = t.elapsed().as_secs_f64() * 1e3;
    Ok((outputs, wall_ms, pool.shutdown()))
}

fn main() -> anyhow::Result<()> {
    let requests = 64usize;
    let base = Config {
        model: "sim8".into(),
        strategy: "origami/6".into(),
        max_batch: 4,
        max_delay_ms: 1.0,
        pool_epochs: 32,
        ..Config::default()
    };
    println!(
        "pool serving demo: model={} strategy={} requests={requests} (reference backend)",
        base.model, base.strategy
    );
    let images = synth_images(requests, 8, 3, base.seed);

    // 1 worker, tiers serialized — the old coordinator demo loop.
    let serial_cfg = Config {
        workers: 1,
        pipeline: false,
        ..base.clone()
    };
    let (serial_out, serial_wall, serial_m) = serve(&serial_cfg, &images)?;
    println!(
        "\nserial   (1 worker, no pipeline): wall {} | sim total {} | {} batches",
        fmt_ms(serial_wall),
        fmt_ms(serial_m.sim_ms_total),
        serial_m.batches
    );

    // 4 workers, pipelined tiers, work-stealing tier-2 lanes.
    let pool_cfg = Config {
        workers: 4,
        pipeline: true,
        ..base
    };
    let (pool_out, pool_wall, pool_m) = serve(&pool_cfg, &images)?;
    println!(
        "pooled   (4 workers, pipelined) : wall {} | sim makespan {} | {} batches, {} tier-2 steals",
        fmt_ms(pool_wall),
        fmt_ms(pool_m.simulated_makespan_ms()),
        pool_m.batches,
        pool_m.stolen_batches
    );

    // Outputs must be bit-identical: the pool reorders when work happens,
    // never what is computed.
    anyhow::ensure!(
        serial_out == pool_out,
        "pooled outputs diverged from the serial path"
    );
    println!("\n✓ per-request outputs bit-identical to the single-worker serial path");
    anyhow::ensure!(pool_m.affinity_held(), "session affinity violated");
    println!("✓ session affinity held across {} workers", pool_m.tier1_sim_ms.len());

    // Throughput: simulated-cost timeline (deterministic) + wall clock.
    let sim_speedup = serial_m.sim_ms_total / pool_m.simulated_makespan_ms();
    let wall_speedup = serial_wall / pool_wall;
    println!(
        "\nthroughput: simulated-cost speedup {sim_speedup:.2}x \
         (wall-clock {wall_speedup:.2}x on this machine)"
    );
    for (w, (t1, t2)) in pool_m
        .tier1_sim_ms
        .iter()
        .zip(&pool_m.tier2_sim_ms)
        .enumerate()
    {
        println!(
            "  worker {w}: tier-1 lane busy {} | tier-2 lane busy {}",
            fmt_ms(*t1),
            fmt_ms(*t2)
        );
    }
    anyhow::ensure!(
        sim_speedup >= 1.3,
        "4-worker pool must clear 1.3x on the simulated-cost path (got {sim_speedup:.2}x)"
    );
    println!("✓ ≥1.3x acceptance bar cleared");
    Ok(())
}
