//! Algorithm 1, natively: pick the privacy partition point.
//!
//! Loads the offline privacy table (SSIM per layer, from the inversion
//! and c-GAN adversaries trained by `python -m compile.privacy_experiment`)
//! and — where trained generator artifacts exist — *re-runs the c-GAN
//! adversary inside the Rust coordinator*: head artifact computes Θ(X) on
//! fresh images, the exported generator reconstructs X', and Rust scores
//! SSIM(X, X').  Demonstrates the full cross-language loop: the privacy
//! audit itself needs no Python at run time.
//!
//! ```bash
//! cargo run --release --example partition_search
//! ```

use origami::config::Config;
use origami::enclave::cost::Ledger;
use origami::launcher::{synth_images, Stack};
use origami::privacy::adversary::{GeneratorRunner, PrivacyTable};
use origami::privacy::{mean_ssim, search_partition};
use origami::runtime::Device;

fn main() -> anyhow::Result<()> {
    let config = Config::default();
    let stack = Stack::load(&config)?;
    let model = stack.model(&config.model)?;
    let table = PrivacyTable::load(&config.artifacts)?;
    println!(
        "offline privacy table: model {}, {} layers measured",
        table.model,
        table.layers.len()
    );

    // 1. Re-run the trained c-GAN generators natively where available.
    let images = synth_images(4, model.image, model.in_channels, 1234);
    for row in &table.layers {
        let Some(_) = row.generator_artifact.as_ref() else {
            continue;
        };
        let gen = GeneratorRunner::load(&stack.client, &table, row.layer)?;
        let n_val = gen.input_shape[0];
        // Θ(X) via the open head artifact; heads are exported at batch
        // 1/8 while the generator wants the privacy-run's n_val — run
        // per-sample and concatenate.
        let mut batch = Vec::new();
        let mut feats = Vec::new();
        let mut ledger = Ledger::new();
        for i in 0..n_val {
            let img = &images[i % images.len()];
            batch.extend_from_slice(img);
            let f = stack.executor.run(
                &model.name,
                &format!("head_p{:02}", row.layer),
                1,
                &[img],
                Device::UntrustedCpu,
                &mut ledger,
            )?;
            feats.extend_from_slice(&f.data);
        }
        let recon = gen.reconstruct(&stack.client, &feats)?;
        let s = mean_ssim(
            &batch,
            &recon,
            n_val,
            model.image,
            model.image,
            model.in_channels,
        );
        println!(
            "  layer {:>2}: native c-GAN reconstruction SSIM {:.3} \
             (offline table said {:.3})",
            row.layer,
            s,
            row.ssim_cgan.unwrap_or(f64::NAN)
        );
    }

    // 2. Algorithm 1 over the worst-case adversary scores.
    let outcome = search_partition(&table, 0.2)?;
    println!("\ntrace (layer, worst-case ssim):");
    for (l, s) in &outcome.trace {
        println!("  {l:>2}  {s:.3}");
    }
    for (p, why) in &outcome.rejected {
        println!("rejected candidate p={p}: {why}");
    }
    println!(
        "\nAlgorithm 1 selects p = {} → deploy with `--strategy origami/{}`",
        outcome.partition, outcome.partition
    );
    Ok(())
}
