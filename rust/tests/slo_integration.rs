//! Latency-SLO acceptance: tail-batch splitting and SLO-aware
//! autoscaling, pinned by the deterministic serving-simulation harness.
//!
//! Three layers of coverage:
//!
//! 1. **Bit-equality on the live fabric** — a hot tenant's batch-8 tails
//!    are split into chunks on a single shared lane; every reply (hot
//!    and cold) must stay bit-identical to its model's serial path, and
//!    identical to the unsplit run.  (Real threads; structural asserts
//!    only, no wall-clock latency assertions.)
//! 2. **Latency behavior on the simulated timeline** — the discrete-
//!    event replay (`origami::harness::sim`, same `FairClock` +
//!    `AutoscalePolicy::decide` code as production) shows the cold
//!    tenant's p95 meeting its SLO *only* when splitting is on, at equal
//!    total work.
//! 3. **Autoscaler flap regression** — an oscillating trace around the
//!    thresholds churns `scale_to` at most once per cooldown window,
//!    for both the depth and the p95 policies.

mod common;

use common::sim::{assert_replies, submit_interleaved, tenant_load};
use origami::config::Config;
use origami::coordinator::{AutoscalePolicy, Deployment, ScaleMode, ScaleSignals, Stage};
use origami::harness::sim::{replay, SimConfig, Trace};
use origami::launcher::{deploy_from_config, fabric_options_from_config};

fn hot_config() -> Config {
    Config {
        model: "sim16".into(),
        // tail-heavy partition: everything past layer 2 is open tier-2
        strategy: "origami/2".into(),
        workers: 1,
        max_batch: 8,
        // generous window: a burst submitted up front always coalesces
        // into full batch-8 tails
        max_delay_ms: 200.0,
        pool_epochs: 16,
        pipeline: true,
        ..Config::default()
    }
}

fn cold_config() -> Config {
    Config {
        model: "sim8".into(),
        strategy: "origami/6".into(),
        workers: 1,
        max_batch: 1,
        max_delay_ms: 0.0,
        pool_epochs: 16,
        pipeline: true,
        ..Config::default()
    }
}

/// One shared lane, hot batch-8 tails + cold singles; returns the final
/// fabric metrics after asserting every reply bit-identical to serial.
fn run_shared_lane(split_chunk: usize) -> origami::coordinator::FabricMetrics {
    let hot = tenant_load(hot_config(), 16, 0, 2);
    let cold = tenant_load(cold_config(), 4, 1, 2);
    let mut base = hot.cfg.clone();
    base.lanes = 1;
    base.lane_devices = "cpu".into();
    base.split_tail_chunk = split_chunk;
    let dep = Deployment::builder(fabric_options_from_config(&base).unwrap()).build();
    deploy_from_config(&dep, &hot.cfg, 1.0).unwrap();
    deploy_from_config(&dep, &cold.cfg, 1.0).unwrap();

    // hot burst first (coalesces into batch-8 tails), cold rides behind
    let mut pending = submit_interleaved(&dep, &[&hot]);
    pending.extend(submit_interleaved(&dep, &[&cold]));
    assert_replies(pending, &[&hot, &cold]);

    // telemetry recorded every request end-to-end, per tenant.  Lanes
    // record after replying, so poll briefly before the exact asserts.
    let hub = dep.telemetry();
    let t_hot = hub.get("sim16").expect("hot telemetry");
    let t_cold = hub.get("sim8").expect("cold telemetry");
    for _ in 0..500 {
        if t_hot.window_count(Stage::EndToEnd) >= 16
            && t_cold.window_count(Stage::EndToEnd) >= 4
        {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(t_hot.window_count(Stage::EndToEnd), 16);
    assert_eq!(t_cold.window_count(Stage::EndToEnd), 4);
    assert!(t_hot.window_count(Stage::QueueWait) > 0, "queue waits recorded");
    assert!(t_hot.percentile(Stage::EndToEnd, 95.0) > 0.0);

    let m = dep.shutdown();
    assert_eq!(m.fabric.tenants["sim16"].requests, 16);
    assert_eq!(m.fabric.tenants["sim8"].requests, 4);
    assert_eq!(m.fabric.errors, 0);
    m.fabric
}

#[test]
fn split_tails_stay_bit_identical_on_a_shared_lane() {
    // splitting on: batch-8 hot tails must actually split…
    let split = run_shared_lane(2);
    assert!(
        split.split_tasks >= 1,
        "no tail was split (split_tasks = {})",
        split.split_tasks
    );
    assert!(
        split.split_subtasks >= 2 * split.split_tasks,
        "splits must produce ≥ 2 chunks each"
    );
    // …and the chunk batches all land on the one lane's ledger
    assert!(split.makespan_ms() > 0.0);

    // splitting off: same workload, no splits — and since BOTH runs are
    // asserted bit-identical to the serial references request by
    // request, the split outputs are bit-identical to the unsplit ones.
    let unsplit = run_shared_lane(0);
    assert_eq!(unsplit.split_tasks, 0);
    assert_eq!(unsplit.split_subtasks, 0);
    assert_eq!(
        split.tenants["sim16"].requests,
        unsplit.tenants["sim16"].requests
    );
    // splitting multiplies the number of tail batches served
    assert!(
        split.tenants["sim16"].batches > unsplit.tenants["sim16"].batches,
        "split run must finish more (smaller) tail batches: {} vs {}",
        split.tenants["sim16"].batches,
        unsplit.tenants["sim16"].batches
    );
}

#[test]
fn cold_tenant_p95_meets_slo_only_with_splitting() {
    // One lane; a hot tenant ships a 12-request, 12 ms tail every 15 ms
    // (80% utilization), the cold tenant one 1 ms request per period,
    // arriving 4 ms into the hot tail.  Cold SLO: 5 ms.
    const SLO_MS: f64 = 5.0;
    let mut trace = Trace::new();
    trace.push_periodic("hot", 0.0, 15.0, 20, 12, 12.0);
    trace.push_periodic("cold", 4.0, 15.0, 20, 1, 1.0);
    let cfg = |chunk: usize| SimConfig {
        weights: vec![("hot".into(), 1.0), ("cold".into(), 1.0)],
        lanes: 1,
        split_chunk: chunk,
        ..SimConfig::default()
    };

    let unsplit = replay(&cfg(0), &trace);
    let split = replay(&cfg(1), &trace);
    assert_eq!(unsplit.count(None), split.count(None), "equal traffic");

    let cold_unsplit = unsplit.p95(Some("cold"));
    let cold_split = split.p95(Some("cold"));
    // unsplit: the cold request waits out the remaining 8 ms of the hot
    // tail + 1 ms service → 9 ms, every period
    assert_eq!(cold_unsplit, 9.0);
    assert!(
        cold_unsplit > SLO_MS,
        "without splitting the cold tenant must blow its {SLO_MS} ms SLO"
    );
    // split: the fair clock admits the cold chunk after at most one
    // 1 ms hot chunk → 1 ms latency, every period
    assert_eq!(cold_split, 1.0);
    assert!(
        cold_split <= SLO_MS,
        "with splitting the cold tenant must meet its {SLO_MS} ms SLO"
    );

    // the hot tenant's completion is not starved: its tail finishes one
    // cold-chunk (1 ms) later per period, and total work is conserved
    assert_eq!(unsplit.p95(Some("hot")), 12.0);
    assert_eq!(split.p95(Some("hot")), 13.0);
    assert_eq!(unsplit.end_ms, split.end_ms, "same total work, same finish");
}

/// Drive `policy.decide` over a scripted oscillating trace with the
/// deployment's cooldown bookkeeping; returns the ticks at which a
/// scale event fired.
fn scale_events_over(
    policy: &AutoscalePolicy,
    ticks: u64,
    signals_at: impl Fn(u64, usize) -> ScaleSignals,
) -> Vec<u64> {
    let mut active = 2usize;
    let mut last: Option<u64> = None;
    let mut events = Vec::new();
    for tick in 1..=ticks {
        let mut s = signals_at(tick, active);
        s.active = active;
        s.ticks_since_scale = last.map(|l| tick - l);
        if let Some(n) = policy.decide(&s) {
            let n = n.clamp(1, 4);
            if n != active {
                active = n;
                last = Some(tick);
                events.push(tick);
            }
        }
    }
    events
}

fn base_signals() -> ScaleSignals {
    ScaleSignals {
        depth: 0,
        active: 2,
        p95_ms: None,
        window_samples: 0,
        slo_ms: None,
        ticks_since_scale: None,
        epc_headroom_workers: None,
        cost_multiplier: 1.0,
    }
}

#[test]
fn autoscaler_never_flaps_faster_than_the_cooldown_window() {
    const COOLDOWN: u64 = 3;
    const TICKS: u64 = 42;

    // depth policy: depth oscillates far above high and down to zero on
    // alternating ticks — the worst flapping trace
    let depth_policy = AutoscalePolicy {
        cooldown_ticks: COOLDOWN,
        ..AutoscalePolicy::default()
    };
    let events = scale_events_over(&depth_policy, TICKS, |tick, _active| {
        let mut s = base_signals();
        s.depth = if tick % 2 == 1 { 100 } else { 0 };
        s
    });
    assert!(
        events.len() >= 2,
        "the oscillation must still drive (rate-limited) scaling"
    );
    for pair in events.windows(2) {
        assert!(
            pair[1] - pair[0] >= COOLDOWN,
            "depth policy churned twice inside one cooldown window: {events:?}"
        );
    }

    // p95 policy: p95 oscillates across the SLO (and its shrink margin)
    // every tick
    let slo_policy = AutoscalePolicy {
        mode: ScaleMode::SloP95,
        cooldown_ticks: COOLDOWN,
        min_window_samples: 1,
        ..AutoscalePolicy::default()
    };
    let events = scale_events_over(&slo_policy, TICKS, |tick, _active| {
        let mut s = base_signals();
        s.slo_ms = Some(20.0);
        s.window_samples = 100;
        s.p95_ms = Some(if tick % 2 == 1 { 25.0 } else { 5.0 });
        s
    });
    assert!(events.len() >= 2, "p95 oscillation must still drive scaling");
    for pair in events.windows(2) {
        assert!(
            pair[1] - pair[0] >= COOLDOWN,
            "p95 policy churned twice inside one cooldown window: {events:?}"
        );
    }

    // without the hysteresis (cooldown 0) the same depth trace flaps
    // every tick — the regression this test pins
    let flappy = AutoscalePolicy {
        cooldown_ticks: 0,
        ..AutoscalePolicy::default()
    };
    let events = scale_events_over(&flappy, 8, |tick, _active| {
        let mut s = base_signals();
        s.depth = if tick % 2 == 1 { 100 } else { 0 };
        s
    });
    assert!(
        events.len() >= 6,
        "cooldown 0 must reproduce the flapping baseline: {events:?}"
    );
}
