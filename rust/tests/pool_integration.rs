//! Worker-pool integration: M concurrent sessions sharded across N
//! workers must produce outputs bit-identical to the serial
//! single-worker path, hold session affinity, and never share a blinding
//! pad across workers.
//!
//! Workloads and bit-equality checks come from the deterministic
//! serving-simulation harness (`tests/common/sim.rs`): seeded tenant
//! loads with precomputed serial references replace the ad-hoc replay
//! loops this file used to carry.
//!
//! Runs hermetically on the pure-Rust reference backend (`sim8`) — no
//! artifacts, no PJRT — so it executes in every CI environment.

mod common;

use common::sim::{drive_pool, tenant_load, TenantLoad};
use origami::config::Config;
use origami::launcher::{executor_for, start_pool_from_config};
use origami::strategies::StrategyCtx;

fn sim_config(workers: usize, pipeline: bool) -> Config {
    Config {
        model: "sim8".into(),
        strategy: "origami/6".into(),
        workers,
        max_batch: 4,
        max_delay_ms: 2.0,
        pool_epochs: 32,
        pipeline,
        ..Config::default()
    }
}

/// Seeded workload with serial references (sessions 0..n, stride 1).
fn load(n: usize) -> TenantLoad {
    tenant_load(sim_config(1, true), n, 0, 1)
}

#[test]
fn pooled_outputs_bit_identical_to_single_worker() {
    let m = 24;
    let load = load(m);

    for workers in [1usize, 4] {
        for pipeline in [false, true] {
            let cfg = sim_config(workers, pipeline);
            let pool = start_pool_from_config(cfg.clone()).expect("pool starts");
            assert_eq!(pool.worker_count(), workers);
            // drive_pool asserts bit-equality against the serial path
            // for every reply
            let got = drive_pool(&pool, &load);
            assert_eq!(got.len(), m, "workers={workers}, pipeline={pipeline}");
            let metrics = pool.shutdown();
            assert_eq!(metrics.requests, m as u64);
            assert_eq!(metrics.errors, 0);
            assert!(metrics.batches >= (m / cfg.max_batch) as u64);
        }
    }
}

#[test]
fn session_affinity_held_across_the_pool() {
    let workers = 4;
    let cfg = sim_config(workers, true);
    let pool = start_pool_from_config(cfg).expect("pool starts");
    let m = 32;
    let _ = drive_pool(&pool, &load(m));
    let metrics = pool.shutdown();

    assert!(metrics.affinity_held(), "a session ran tier-1 on 2 workers");
    let mut covered = 0;
    for (w, set) in metrics.sessions_per_worker.iter().enumerate() {
        assert!(
            set.iter().all(|s| (s % workers as u64) as usize == w),
            "worker {w} served a foreign shard: {set:?}"
        );
        assert!(!set.is_empty(), "worker {w} starved");
        covered += set.len();
    }
    assert_eq!(covered, m, "every session's tier-1 is accounted for");
    // tier-2 lanes actually ran (pipelined mode) and their accounting is
    // consistent with the two-tier split
    assert!(metrics.tier1_sim_ms.iter().sum::<f64>() > 0.0);
    assert!(metrics.tier2_sim_ms.iter().sum::<f64>() > 0.0);
}

#[test]
fn no_blinding_pad_reuse_across_workers() {
    // The pool assigns each worker a distinct blind_domain; equal domains
    // must regenerate identical pads (determinism) and distinct domains
    // disjoint ones (no OTP reuse when two workers serve the same epoch).
    let factors_for = |domain: u64| {
        let mut cfg = sim_config(1, true);
        cfg.blind_domain = domain;
        let (executor, model) = executor_for(&cfg).expect("reference stack");
        let mut ctx = StrategyCtx::new(executor, model, cfg).expect("ctx");
        ctx.with_enclave(1 << 20).expect("enclave");
        let fs = ctx.factors.as_ref().expect("factor stream");
        (fs.factors(1, 0, 512), fs.factors(2, 5, 512))
    };
    let (a_l1, a_l2) = factors_for(0);
    let (a2_l1, _) = factors_for(0);
    let (b_l1, b_l2) = factors_for(1);
    assert_eq!(a_l1, a2_l1, "same domain regenerates the same pad");
    assert_ne!(a_l1, b_l1, "worker 0 and worker 1 pads must be disjoint");
    assert_ne!(a_l2, b_l2, "disjoint across layers/epochs too");
}

#[test]
fn pool_simulated_speedup_scales_with_workers() {
    // On the simulated-cost timeline (independent enclave + device lanes
    // per worker) 4 balanced shards must clear the 1.3x acceptance bar
    // over the serial single-worker cost by a wide margin.
    let workers = 4;
    let cfg = sim_config(workers, true);
    let pool = start_pool_from_config(cfg).expect("pool starts");
    let _ = drive_pool(&pool, &load(48));
    let metrics = pool.shutdown();
    let speedup = metrics.simulated_speedup();
    assert!(
        speedup >= 1.3,
        "4-worker pool speedup {speedup:.2}x below the 1.3x bar \
         (total {:.2}ms, makespan {:.2}ms)",
        metrics.sim_ms_total,
        metrics.simulated_makespan_ms()
    );
}
