//! EPC-aware co-scheduling acceptance: the residency ledger, the
//! packer's reclaim, typed grow denials and leak-free release, end to
//! end on a live [`Deployment`].
//!
//! Strategy doubles with an explicit gate pin queue states
//! deterministically (a blocked worker makes backlog growth monotone),
//! so grow/deny/reclaim decisions are exercised without wall-clock
//! races; the footprint tests pin the `sim224` memory analytics the
//! launcher charges the ledger with.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use origami::config::Config;
use origami::coordinator::scheduler::{BatchScheduler, Tier2Finisher};
use origami::coordinator::{
    AdmissionError, AdmissionLimits, AutoscalePolicy, DeploySpec, Deployment, EpcOptions,
    FabricOptions, PoolOptions,
};
use origami::enclave::cost::{Cat, CostModel, Ledger};
use origami::launcher::worker_epc_bytes_from_config;
use origami::model::partition::PartitionPlan;
use origami::runtime::{Device, ReferenceBackend, StageExecutor};
use origami::strategies::memory::enclave_requirement;
use origami::strategies::Strategy;

/// Deterministic strategy double: while the gate is closed, `infer`
/// blocks, so backlog behind it only grows.
struct Gate {
    open: Arc<AtomicBool>,
}

impl Strategy for Gate {
    fn name(&self) -> String {
        "gate".into()
    }

    fn setup(&mut self) -> Result<()> {
        Ok(())
    }

    fn infer(
        &mut self,
        _ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        while !self.open.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        ledger.add_measured(Cat::DeviceCompute, 100_000);
        Ok((0..batch)
            .map(|i| sessions.get(i).copied().unwrap_or(0) as f32)
            .collect())
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        0
    }
}

fn gate_sched(
    open: Arc<AtomicBool>,
) -> impl Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static {
    move |_band, _domain| {
        Ok(BatchScheduler::new(
            Box::new(Gate { open: open.clone() }),
            8,
            vec![1],
        ))
    }
}

fn ref_finisher() -> impl Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static {
    |_lane| {
        let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
        Ok(Tier2Finisher::new(
            Arc::new(StageExecutor::reference(rb, CostModel::default())),
            "sim8",
            Device::UntrustedCpu,
        ))
    }
}

/// One shard, batch-1, no pipelining, with an explicit EPC footprint.
fn epc_pool(workers: usize, max_workers: usize, worker_epc_bytes: u64) -> PoolOptions {
    PoolOptions {
        workers,
        min_workers: 1,
        max_workers,
        max_batch: 1,
        max_delay_ms: 0.0,
        pipeline: false,
        worker_epc_bytes,
        ..PoolOptions::default()
    }
}

fn epc_deployment(usable: u64) -> Deployment {
    Deployment::builder(FabricOptions::default())
        .policy(AutoscalePolicy {
            high_depth_per_worker: 1,
            low_depth_per_worker: 0,
            cooldown_ticks: 0,
            ..AutoscalePolicy::default()
        })
        .epc(Some(EpcOptions {
            usable_bytes: usable,
            overcommit: 1.0,
        }))
        .build()
}

#[test]
fn deploy_fails_up_front_when_the_initial_fleet_cannot_fit() {
    let dep = epc_deployment(100);
    dep.deploy_model(
        DeploySpec::new("a", 8).pool(epc_pool(1, 1, 60)),
        gate_sched(Arc::new(AtomicBool::new(true))),
        ref_finisher(),
    )
    .unwrap();
    let ledger = dep.epc_ledger().unwrap();
    assert_eq!(ledger.charged_bytes(), 60);

    // a second 60 B tenant cannot fit its initial worker: the deploy
    // fails with the EPC reason and leaves no residue — no fabric
    // tenant, no charge, and the first tenant keeps serving
    let err = dep
        .deploy_model(
            DeploySpec::new("b", 8).pool(epc_pool(1, 1, 60)),
            gate_sched(Arc::new(AtomicBool::new(true))),
            ref_finisher(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("overcommit usable EPC"), "{err}");
    assert_eq!(ledger.charged_bytes(), 60, "failed deploy left a charge");
    assert_eq!(dep.models(), vec!["a".to_string()]);

    let reply = dep.submit("a", vec![0u8; 8], 7).expect("tenant a serves");
    assert_eq!(reply.recv().unwrap().probs[0], 7.0);
    dep.shutdown();
    assert_eq!(ledger.charged_bytes(), 0, "shutdown released the fleet");
}

#[test]
fn overcommitting_grows_are_denied_and_surfaced_in_shed_hints() {
    // 100 B budget, 40 B/worker, ceiling 4: worker 2 fits (80 B), the
    // third (120 B) must be denied — and a shed after that denial tells
    // the client the tenant is EPC-limited.
    let open = Arc::new(AtomicBool::new(false));
    let dep = epc_deployment(100);
    dep.deploy_model(
        DeploySpec::new("hot", 8)
            .admission(AdmissionLimits {
                shed_depth: 6,
                ..AdmissionLimits::default()
            })
            .pool(epc_pool(1, 4, 40)),
        gate_sched(open.clone()),
        ref_finisher(),
    )
    .unwrap();
    let ledger = dep.epc_ledger().unwrap();
    assert_eq!(ledger.charged_bytes(), 40);

    // gate closed: 6 submits build a monotone backlog
    let mut replies = Vec::new();
    for s in 0..6u64 {
        replies.push(dep.submit("hot", vec![0u8; 8], s).expect("admitted"));
    }
    // tick 1: depth > 1×1 → grow to 2 (charged).  tick 2+: grow to 3
    // needs 40 B with only 20 B free and nobody to reclaim from →
    // denied, recorded, pool unchanged.
    for _ in 0..3 {
        dep.autoscale_tick();
    }
    assert_eq!(dep.active_workers("hot"), 2, "EPC caps the pool at 2");
    assert_eq!(ledger.charged_bytes(), 80);
    let snap = dep.scale_snapshot("hot").unwrap();
    assert!(snap.epc_denied >= 1, "denials must be recorded: {snap:?}");
    assert!(snap.epc_limited, "the tenant is EPC-limited right now");

    // a shed while EPC-limited says so — the client can tell "scale-out
    // is coming" apart from "the box is full"
    let mut shed = None;
    for s in 100..110u64 {
        match dep.submit("hot", vec![0u8; 8], s) {
            Ok(r) => replies.push(r),
            Err(e @ AdmissionError::Shed { .. }) => {
                shed = Some(e);
                break;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    let shed = shed.expect("backlog past the threshold must shed");
    match &shed {
        AdmissionError::Shed { epc_limited, .. } => assert!(epc_limited),
        other => panic!("expected Shed, got {other:?}"),
    }
    assert!(
        shed.to_string().contains("EPC-limited"),
        "shed hint must mention EPC exhaustion: {shed}"
    );

    // drain and shut down: every admitted request completes, and the
    // ledger releases every worker (the leak regression)
    open.store(true, Ordering::SeqCst);
    for r in replies {
        let resp = r.recv().expect("reply");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let metrics = dep.shutdown();
    assert_eq!(ledger.charged_bytes(), 0, "retire/shutdown leaked a charge");
    assert!(metrics.models["hot"].grow_events >= 1);
}

#[test]
fn packer_reclaims_idle_workers_to_fund_a_hot_grow() {
    // 100 B budget.  `b-idle` parks 2×30 B with no traffic; `a-hot`
    // (30 B, backlogged) wants a second worker: 30 B needed, 10 B free
    // → the packer reclaims one idle worker, then the grow charges.
    // (Tenant names are chosen so the deterministic sorted tick order
    // evaluates the hot pool first — the reclaim path, not the idle
    // pool's own shrink, must fund the grow.)
    let hot_gate = Arc::new(AtomicBool::new(false));
    let dep = epc_deployment(100);
    dep.deploy_model(
        DeploySpec::new("a-hot", 8).pool(epc_pool(1, 2, 30)),
        gate_sched(hot_gate.clone()),
        ref_finisher(),
    )
    .unwrap();
    dep.deploy_model(
        DeploySpec::new("b-idle", 8).weight(2.0).pool(epc_pool(2, 2, 30)),
        gate_sched(Arc::new(AtomicBool::new(true))),
        ref_finisher(),
    )
    .unwrap();
    let ledger = dep.epc_ledger().unwrap();
    assert_eq!(ledger.charged_bytes(), 90);

    let mut replies = Vec::new();
    for s in 0..6u64 {
        replies.push(dep.submit("a-hot", vec![0u8; 8], s).expect("admitted"));
    }
    dep.autoscale_tick();

    assert_eq!(dep.active_workers("a-hot"), 2, "grow funded by reclaim");
    assert_eq!(dep.active_workers("b-idle"), 1, "one idle worker donated");
    assert_eq!(ledger.charged_bytes(), 90, "2×30 hot + 1×30 idle");
    let idle_snap = dep.scale_snapshot("b-idle").unwrap();
    assert_eq!(idle_snap.epc_reclaimed, 1);
    let hot_snap = dep.scale_snapshot("a-hot").unwrap();
    assert_eq!(hot_snap.epc_denied, 0, "the grow was funded, not denied");
    assert!(!hot_snap.epc_limited);

    hot_gate.store(true, Ordering::SeqCst);
    for r in replies {
        assert!(r.recv().expect("reply").error.is_none());
    }
    dep.shutdown();
    assert_eq!(ledger.charged_bytes(), 0, "no charge survives shutdown");
}

#[test]
fn usable_epc_math_and_sim224_footprint_are_pinned() {
    // usable EPC: the paper's ~93 of 128 MB, same ratio at every scale
    let paper = Config::paper_scale();
    assert_eq!(paper.epc_bytes, 128 * 1024 * 1024);
    assert_eq!(
        paper.usable_epc_bytes(),
        (paper.epc_bytes as f64 * 0.727) as u64
    );
    let usable_mb = paper.usable_epc_bytes() as f64 / (1024.0 * 1024.0);
    assert!((92.0..94.0).contains(&usable_mb), "{usable_mb}");

    // the launcher's per-worker footprint is exactly the Table-I
    // analytics on the real sim224 geometry (origami/6, batch 4)
    let cfg = Config {
        model: "sim224".into(),
        strategy: "origami/6".into(),
        max_batch: 4,
        ..Config::paper_scale()
    };
    let footprint = worker_epc_bytes_from_config(&cfg).unwrap();
    let (_, model) = origami::launcher::executor_for(&cfg).unwrap();
    let plan = PartitionPlan::origami(&model, 6);
    let req = enclave_requirement(&model, &plan, cfg.lazy_dense_bytes, 4);
    assert_eq!(footprint, req.total());
    // base 15 MB + ~6.1 MB blinding + ~6.1 MB features (+ biases)
    let mb = footprint as f64 / (1024.0 * 1024.0);
    assert!((26.0..30.0).contains(&mb), "sim224 footprint {mb} MB");
    // exactly three sim224 workers pack into paper-scale usable EPC —
    // the geometry Fig 18's packing claim rests on
    assert_eq!(paper.usable_epc_bytes() / footprint, 3);

    // no enclave, no charge
    let open = Config {
        strategy: "open".into(),
        ..cfg.clone()
    };
    assert_eq!(worker_epc_bytes_from_config(&open).unwrap(), 0);
    // unknown strategies fail loudly rather than charging nothing
    let bad = Config {
        strategy: "quantum".into(),
        ..cfg
    };
    assert!(worker_epc_bytes_from_config(&bad).is_err());

    // the plan dispatch accepts exactly the names strategies::build
    // accepts (the two tables live side by side; this pins the sync)
    for s in ["baseline2", "split/6", "slalom", "origami/6", "origami", "open"] {
        assert!(
            origami::strategies::partition_plan_for(&model, s, 6).is_ok(),
            "servable strategy `{s}` must have a partition plan"
        );
    }
}
