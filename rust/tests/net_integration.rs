//! Loopback end-to-end tests for the attested network front door.
//!
//! Everything runs over real TCP sockets on 127.0.0.1 with ephemeral
//! ports: the attested handshake, the session-keyed inference path, the
//! expiry → refresh → resume lifecycle, and the typed wire denials the
//! admission gate produces under per-tenant rate limits.  The hermetic
//! `simN` models keep the suite artifact-free and deterministic.

use std::sync::Arc;

use origami::config::{Config, ModelSpec};
use origami::coordinator::{Deny, DenyCode, NetClient, NetError, NetOptions, NetServer};
use origami::launcher::{
    encrypt_request, net_options_from_config, start_deployment_from_config, synth_images,
};

/// A sim-model serving config with the front door enabled on an
/// ephemeral loopback port.
fn net_config(model: &str, session_ttl_ms: u64) -> Config {
    Config {
        model: model.into(),
        strategy: "origami/6".into(),
        workers: 1,
        listen: "127.0.0.1:0".into(),
        session_ttl_ms,
        ..Config::default()
    }
}

fn start(config: &Config) -> (Arc<origami::coordinator::Deployment>, NetServer, NetOptions) {
    let specs = if config.models.trim().is_empty() {
        vec![ModelSpec::parse(&config.model).expect("model spec")]
    } else {
        ModelSpec::parse_list(&config.models).expect("model specs")
    };
    let dep = Arc::new(start_deployment_from_config(config, &specs).expect("deployment"));
    let opts = net_options_from_config(config);
    let server = NetServer::start(dep.clone(), opts.clone()).expect("net server");
    (dep, server, opts)
}

fn teardown(dep: Arc<origami::coordinator::Deployment>, server: NetServer) {
    server.shutdown();
    match Arc::try_unwrap(dep) {
        Ok(d) => {
            d.shutdown();
        }
        Err(_) => panic!("deployment still referenced after server shutdown"),
    }
}

fn image_for(config: &Config) -> Vec<f32> {
    let size: usize = config.model.trim_start_matches("sim").parse().expect("sim model");
    synth_images(1, size.clamp(4, 224), 3, config.seed)[0].clone()
}

fn expect_denied(r: Result<origami::coordinator::WireInference, NetError>) -> Deny {
    match r {
        Err(NetError::Denied(d)) => d,
        other => panic!("expected a wire denial, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// handshake + bit-identity vs the in-process path
// ---------------------------------------------------------------------

#[test]
fn attested_loopback_matches_in_process_inference() {
    let config = net_config("sim16", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    let mut client = NetClient::connect(
        &addr,
        "sim16",
        &opts.measurement,
        &opts.platform_key,
        0xDEC0DE,
    )
    .expect("attested handshake");
    assert_eq!(client.epoch(), 0, "fresh sessions start at epoch 0");
    assert_eq!(client.session_ttl_ms(), 600_000);
    assert!(client.report().ttl_ms > 0, "report carries a lifetime");

    let image = image_for(&config);
    let ct = encrypt_request(&config, client.session_word(), &image);
    let over_wire = client.infer(&ct).expect("wire inference");
    assert_eq!(over_wire.probs.len(), 10);
    assert!(over_wire.latency_ms >= 0.0);
    assert!(over_wire.batch >= 1);

    // Same plaintext through the in-process API, under a different
    // (implicit) session: the session changes only the keystream, so
    // the probabilities must match bit for bit.
    let in_proc_session = 7u64;
    let ct2 = encrypt_request(&config, in_proc_session, &image);
    let in_proc = dep
        .infer_blocking("sim16", ct2, in_proc_session)
        .expect("in-process inference");
    assert!(in_proc.error.is_none(), "in-process path errored: {:?}", in_proc.error);
    assert_eq!(
        over_wire.probs, in_proc.probs,
        "network path must be bit-identical to the in-process path"
    );

    // Refresh bumps the keystream epoch: same image, different bytes on
    // the wire, identical answer.
    let old_word = client.session_word();
    let epoch = client.refresh().expect("refresh");
    assert_eq!(epoch, 1);
    assert_ne!(client.session_word(), old_word);
    let ct3 = encrypt_request(&config, client.session_word(), &image);
    assert_ne!(ct, ct3, "epoch bump must change the ciphertext");
    let again = client.infer(&ct3).expect("post-refresh inference");
    assert_eq!(again.probs, over_wire.probs);

    // Revocation tears the session down; the next request is told to
    // re-attest (not refresh).
    assert!(client.revoke().expect("revoke"), "live session should exist");
    let deny = expect_denied(client.infer(&ct3));
    assert_eq!(deny.code, DenyCode::SessionExpired);
    assert!(!deny.refreshable, "revoked sessions must not be refreshable");
    assert!(deny.message.contains("re-attest"), "got: {}", deny.message);

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// attestation rejections: wrong enclave, stale evidence
// ---------------------------------------------------------------------

#[test]
fn handshake_rejects_wrong_measurement_and_stale_reports() {
    let config = net_config("sim8", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    // A client expecting a different enclave must refuse the report.
    let wrong = [0xABu8; 32];
    match NetClient::connect(&addr, "sim8", &wrong, &opts.platform_key, 1) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("measurement"), "got: {msg}")
        }
        other => panic!("wrong measurement must fail attestation, got {other:?}"),
    }

    // A tampered platform key breaks the report MAC.
    match NetClient::connect(&addr, "sim8", &opts.measurement, b"not-the-platform-key", 2) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("MAC") || msg.contains("challenge"), "got: {msg}")
        }
        other => panic!("wrong platform key must fail attestation, got {other:?}"),
    }

    // A server issuing zero-lifetime reports produces evidence that is
    // stale the instant it is signed; the client must reject it.
    let stale_opts = NetOptions {
        listen: "127.0.0.1:0".into(),
        attest_ttl_ms: 0,
        ..NetOptions::default()
    };
    let stale_server = NetServer::start(dep.clone(), stale_opts.clone()).expect("stale server");
    match NetClient::connect(
        &stale_server.local_addr(),
        "sim8",
        &stale_opts.measurement,
        &stale_opts.platform_key,
        3,
    ) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("stale"), "got: {msg}")
        }
        other => panic!("stale report must fail attestation, got {other:?}"),
    }
    stale_server.shutdown();

    // The healthy front door still admits a correct client afterwards.
    let mut ok = NetClient::connect(&addr, "sim8", &opts.measurement, &opts.platform_key, 4)
        .expect("honest client");
    let image = image_for(&config);
    let ct = encrypt_request(&config, ok.session_word(), &image);
    assert_eq!(ok.infer(&ct).expect("inference").probs.len(), 10);

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// expiry mid-stream → typed denial with a refresh hint → resume
// ---------------------------------------------------------------------

#[test]
fn expiry_mid_stream_then_refresh_resumes_with_identical_output() {
    let config = net_config("sim8", 250);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    let mut client =
        NetClient::connect(&addr, "sim8", &opts.measurement, &opts.platform_key, 0xFEED)
            .expect("attested handshake");
    assert_eq!(client.session_ttl_ms(), 250);

    let image = image_for(&config);
    let ct0 = encrypt_request(&config, client.session_word(), &image);
    let first = client.infer(&ct0).expect("inference before expiry");

    // Outlive the session TTL on the same connection.  Attested
    // sessions expire in place (they are never silently recycled), so
    // the denial carries the refresh hint over the wire.
    std::thread::sleep(std::time::Duration::from_millis(600));
    let deny = expect_denied(client.infer(&ct0));
    assert_eq!(deny.code, DenyCode::SessionExpired);
    assert!(
        deny.refreshable,
        "expired attested session must advertise refreshability: {deny:?}"
    );

    // Refresh bumps the epoch and re-arms the deadline; the request
    // must be re-encrypted under the new session word to decrypt
    // correctly, and the answer is bit-identical.
    let epoch = client.refresh().expect("refresh after expiry");
    assert_eq!(epoch, 1);
    let ct1 = encrypt_request(&config, client.session_word(), &image);
    assert_ne!(ct0, ct1);
    let resumed = client.infer(&ct1).expect("inference after refresh");
    assert_eq!(
        resumed.probs, first.probs,
        "resume after refresh must not perturb the math"
    );

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// control-frame authentication: a bare session id steers nothing
// ---------------------------------------------------------------------

/// Raw client → server frame types and the denied reply, hardcoded to
/// pin the wire format byte-for-byte (`u32 LE length ‖ type ‖ payload`).
const RAW_REFRESH: u8 = 0x03;
const RAW_REVOKE: u8 = 0x04;
const RAW_DENIED: u8 = 0x83;
const RAW_REVOKED: u8 = 0x85;

fn raw_roundtrip(addr: &std::net::SocketAddr, ty: u8, payload: &[u8]) -> (u8, Vec<u8>) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("raw connect");
    let mut frame = ((payload.len() + 1) as u32).to_le_bytes().to_vec();
    frame.push(ty);
    frame.extend_from_slice(payload);
    s.write_all(&frame).expect("raw frame write");
    let mut head = [0u8; 5];
    s.read_exact(&mut head).expect("reply head");
    let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    let mut body = vec![0u8; len - 1];
    s.read_exact(&mut body).expect("reply body");
    (head[4], body)
}

#[test]
fn forged_control_frames_cannot_steer_another_tenants_session() {
    let config = net_config("sim8", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    let mut victim =
        NetClient::connect(&addr, "sim8", &opts.measurement, &opts.platform_key, 0xA11CE)
            .expect("victim handshake");
    let image = image_for(&config);
    let ct = encrypt_request(&config, victim.session_word(), &image);
    let first = victim.infer(&ct).expect("victim inference");

    // An attacker who learned the victim's bare session id — but holds
    // no attested session key — sends REFRESH and REVOKE from a fresh,
    // never-attested connection.  Both must be refused: an accepted
    // REVOKE is a cross-tenant DoS, and an accepted REFRESH bumps the
    // victim's keystream epoch so its next submit silently decrypts
    // under the wrong session word.
    let mut forged = victim.session().to_le_bytes().to_vec();
    forged.extend_from_slice(&[0u8; 32]);
    let (ty, body) = raw_roundtrip(&addr, RAW_REFRESH, &forged);
    assert_eq!(ty, RAW_DENIED, "forged REFRESH must be denied");
    assert_eq!(body[0], DenyCode::Unauthorized as u8, "typed Unauthorized");
    let (ty, body) = raw_roundtrip(&addr, RAW_REVOKE, &forged);
    assert_eq!(ty, RAW_DENIED, "forged REVOKE must be denied");
    assert_eq!(body[0], DenyCode::Unauthorized as u8, "typed Unauthorized");

    // The victim's epoch never moved and its session still serves: the
    // same ciphertext (old session word) still decrypts to the same
    // answer.
    let again = victim.infer(&ct).expect("victim unaffected by forgeries");
    assert_eq!(again.probs, first.probs);

    // Probing an id that was never established reveals nothing — and
    // with random 48-bit ids there is no sequence to walk anyway.
    let mut probe = (victim.session() ^ 0x0000_1234_5678_9ABC)
        .to_le_bytes()
        .to_vec();
    probe.extend_from_slice(&[0u8; 32]);
    let (ty, body) = raw_roundtrip(&addr, RAW_REVOKE, &probe);
    assert_eq!(ty, RAW_REVOKED);
    assert_eq!(body, vec![0u8], "absent sessions report not-found, nothing more");

    // The real holder of the session key can still do both.
    assert_eq!(victim.refresh().expect("authentic refresh"), 1);
    let ct1 = encrypt_request(&config, victim.session_word(), &image);
    assert_eq!(victim.infer(&ct1).expect("post-refresh").probs, first.probs);
    assert!(victim.revoke().expect("authentic revoke"));

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// HELLO hygiene: no session state for unknown models
// ---------------------------------------------------------------------

#[test]
fn hello_for_unknown_model_mints_no_session_state() {
    let config = net_config("sim8", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    match NetClient::connect(&addr, "sim99", &opts.measurement, &opts.platform_key, 5) {
        Err(NetError::Denied(d)) => {
            assert_eq!(d.code, DenyCode::UnknownModel);
            assert!(d.message.contains("sim99"), "got: {}", d.message);
        }
        other => panic!("unknown-model HELLO must be denied, got {other:?}"),
    }
    assert_eq!(
        dep.sessions().len(),
        0,
        "a refused HELLO must not grow the session table"
    );

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// evidence freshness is judged on the client's clock
// ---------------------------------------------------------------------

#[test]
fn client_clock_rejects_aged_evidence() {
    let config = net_config("sim8", 600_000);
    let (dep, server, _opts) = start(&config);

    // A door issuing short-lived evidence: fresh handshakes pass...
    let short = NetOptions {
        listen: "127.0.0.1:0".into(),
        attest_ttl_ms: 5_000,
        ..NetOptions::default()
    };
    let door = NetServer::start(dep.clone(), short.clone()).expect("short-ttl server");
    NetClient::connect(
        &door.local_addr(),
        "sim8",
        &short.measurement,
        &short.platform_key,
        8,
    )
    .expect("immediate evidence is fresh");

    // ...but the same evidence aged past its TTL must read as stale on
    // the client's own clock, even though the server stamped it with
    // its own (self-consistent) issue time.  The old self-referential
    // check (now = issued_at) called every ttl > 0 report fresh forever.
    match NetClient::connect_assuming_age(
        &door.local_addr(),
        "sim8",
        &short.measurement,
        &short.platform_key,
        9,
        6_000,
    ) {
        Err(NetError::Attestation(msg)) => assert!(msg.contains("stale"), "got: {msg}"),
        other => panic!("aged evidence must fail freshness, got {other:?}"),
    }
    door.shutdown();

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// a stalled half-frame cannot wedge server shutdown
// ---------------------------------------------------------------------

#[test]
fn half_sent_frame_does_not_wedge_shutdown() {
    use std::io::Write;
    let config = net_config("sim8", 600_000);
    let (dep, server, _opts) = start(&config);
    let addr = server.local_addr();

    // A peer sends 3 bytes of the 5-byte frame head, then stalls with
    // the socket held open.  Its connection thread is now mid-frame;
    // shutdown must still complete (the stop flag interrupts the read).
    let mut stall = std::net::TcpStream::connect(addr).expect("stall connect");
    stall.write_all(&[9, 0, 0]).expect("partial head");
    std::thread::sleep(std::time::Duration::from_millis(200));

    let t0 = std::time::Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(5),
        "shutdown blocked on a stalled mid-frame peer for {:?}",
        t0.elapsed()
    );
    drop(stall);
    match Arc::try_unwrap(dep) {
        Ok(d) => {
            d.shutdown();
        }
        Err(_) => panic!("deployment still referenced after server shutdown"),
    }
}

// ---------------------------------------------------------------------
// per-tenant rate limits: typed wire denials with backoff hints
// ---------------------------------------------------------------------

#[test]
fn rate_limited_tenants_receive_retry_hints_over_the_wire() {
    // Three tenants, each with a one-token bucket refilling at 0.2 rps
    // (one token per five seconds, so wall-clock jitter cannot refill
    // it mid-test): the first request per tenant is admitted, the
    // second is denied with a backoff hint — independently per tenant.
    let config = Config {
        models: "sim8:rps=0.2,sim9:rps=0.2,sim10:rps=0.2".into(),
        strategy: "origami/6".into(),
        workers: 1,
        admission_burst: 1.0,
        listen: "127.0.0.1:0".into(),
        session_ttl_ms: 600_000,
        ..Config::default()
    };
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    for (i, model) in ["sim8", "sim9", "sim10"].iter().enumerate() {
        let mut client = NetClient::connect(
            &addr,
            model,
            &opts.measurement,
            &opts.platform_key,
            100 + i as u64,
        )
        .expect("attested handshake");

        let size: usize = model.trim_start_matches("sim").parse().unwrap();
        let image = synth_images(1, size, 3, config.seed)[0].clone();
        let ct = encrypt_request(&config, client.session_word(), &image);
        let ok = client.infer(&ct).expect("first request within budget");
        assert_eq!(ok.probs.len(), 10);

        let deny = expect_denied(client.infer(&ct));
        assert_eq!(deny.code, DenyCode::RateLimited, "tenant {model}: {deny:?}");
        let hint = deny
            .retry_after_ms
            .unwrap_or_else(|| panic!("tenant {model}: rate denial must carry a hint"));
        assert!(hint >= 1, "tenant {model}: hint should be meaningful, got {hint}");
        assert!(!deny.refreshable, "rate denials are not session problems");
    }

    // The loop above already proves isolation: each tenant's first
    // request was admitted even after its neighbours exhausted theirs.
    assert_eq!(dep.models().len(), 3, "three tenants deployed");

    teardown(dep, server);
}
