//! Loopback end-to-end tests for the attested network front door.
//!
//! Everything runs over real TCP sockets on 127.0.0.1 with ephemeral
//! ports: the attested handshake, the session-keyed inference path, the
//! expiry → refresh → resume lifecycle, and the typed wire denials the
//! admission gate produces under per-tenant rate limits.  The hermetic
//! `simN` models keep the suite artifact-free and deterministic.

use std::sync::Arc;

use origami::config::{Config, ModelSpec};
use origami::coordinator::{Deny, DenyCode, NetClient, NetError, NetOptions, NetServer};
use origami::launcher::{
    encrypt_request, net_options_from_config, start_deployment_from_config, synth_images,
};

/// A sim-model serving config with the front door enabled on an
/// ephemeral loopback port.
fn net_config(model: &str, session_ttl_ms: u64) -> Config {
    Config {
        model: model.into(),
        strategy: "origami/6".into(),
        workers: 1,
        listen: "127.0.0.1:0".into(),
        session_ttl_ms,
        ..Config::default()
    }
}

fn start(config: &Config) -> (Arc<origami::coordinator::Deployment>, NetServer, NetOptions) {
    let specs = if config.models.trim().is_empty() {
        vec![ModelSpec::parse(&config.model).expect("model spec")]
    } else {
        ModelSpec::parse_list(&config.models).expect("model specs")
    };
    let dep = Arc::new(start_deployment_from_config(config, &specs).expect("deployment"));
    let opts = net_options_from_config(config);
    let server = NetServer::start(dep.clone(), opts.clone()).expect("net server");
    (dep, server, opts)
}

fn teardown(dep: Arc<origami::coordinator::Deployment>, server: NetServer) {
    server.shutdown();
    match Arc::try_unwrap(dep) {
        Ok(d) => {
            d.shutdown();
        }
        Err(_) => panic!("deployment still referenced after server shutdown"),
    }
}

fn image_for(config: &Config) -> Vec<f32> {
    let size: usize = config.model.trim_start_matches("sim").parse().expect("sim model");
    synth_images(1, size.clamp(4, 224), 3, config.seed)[0].clone()
}

fn expect_denied(r: Result<origami::coordinator::WireInference, NetError>) -> Deny {
    match r {
        Err(NetError::Denied(d)) => d,
        other => panic!("expected a wire denial, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// handshake + bit-identity vs the in-process path
// ---------------------------------------------------------------------

#[test]
fn attested_loopback_matches_in_process_inference() {
    let config = net_config("sim16", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    let mut client = NetClient::connect(
        &addr,
        "sim16",
        &opts.measurement,
        &opts.platform_key,
        0xDEC0DE,
    )
    .expect("attested handshake");
    assert_eq!(client.epoch(), 0, "fresh sessions start at epoch 0");
    assert_eq!(client.session_ttl_ms(), 600_000);
    assert!(client.report().ttl_ms > 0, "report carries a lifetime");

    let image = image_for(&config);
    let ct = encrypt_request(&config, client.session_word(), &image);
    let over_wire = client.infer(&ct).expect("wire inference");
    assert_eq!(over_wire.probs.len(), 10);
    assert!(over_wire.latency_ms >= 0.0);
    assert!(over_wire.batch >= 1);

    // Same plaintext through the in-process API, under a different
    // (implicit) session: the session changes only the keystream, so
    // the probabilities must match bit for bit.
    let in_proc_session = 7u64;
    let ct2 = encrypt_request(&config, in_proc_session, &image);
    let in_proc = dep
        .infer_blocking("sim16", ct2, in_proc_session)
        .expect("in-process inference");
    assert!(in_proc.error.is_none(), "in-process path errored: {:?}", in_proc.error);
    assert_eq!(
        over_wire.probs, in_proc.probs,
        "network path must be bit-identical to the in-process path"
    );

    // Refresh bumps the keystream epoch: same image, different bytes on
    // the wire, identical answer.
    let old_word = client.session_word();
    let epoch = client.refresh().expect("refresh");
    assert_eq!(epoch, 1);
    assert_ne!(client.session_word(), old_word);
    let ct3 = encrypt_request(&config, client.session_word(), &image);
    assert_ne!(ct, ct3, "epoch bump must change the ciphertext");
    let again = client.infer(&ct3).expect("post-refresh inference");
    assert_eq!(again.probs, over_wire.probs);

    // Revocation tears the session down; the next request is told to
    // re-attest (not refresh).
    assert!(client.revoke().expect("revoke"), "live session should exist");
    let deny = expect_denied(client.infer(&ct3));
    assert_eq!(deny.code, DenyCode::SessionExpired);
    assert!(!deny.refreshable, "revoked sessions must not be refreshable");
    assert!(deny.message.contains("re-attest"), "got: {}", deny.message);

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// attestation rejections: wrong enclave, stale evidence
// ---------------------------------------------------------------------

#[test]
fn handshake_rejects_wrong_measurement_and_stale_reports() {
    let config = net_config("sim8", 600_000);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    // A client expecting a different enclave must refuse the report.
    let wrong = [0xABu8; 32];
    match NetClient::connect(&addr, "sim8", &wrong, &opts.platform_key, 1) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("measurement"), "got: {msg}")
        }
        other => panic!("wrong measurement must fail attestation, got {other:?}"),
    }

    // A tampered platform key breaks the report MAC.
    match NetClient::connect(&addr, "sim8", &opts.measurement, b"not-the-platform-key", 2) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("MAC") || msg.contains("challenge"), "got: {msg}")
        }
        other => panic!("wrong platform key must fail attestation, got {other:?}"),
    }

    // A server issuing zero-lifetime reports produces evidence that is
    // stale the instant it is signed; the client must reject it.
    let stale_opts = NetOptions {
        listen: "127.0.0.1:0".into(),
        attest_ttl_ms: 0,
        ..NetOptions::default()
    };
    let stale_server = NetServer::start(dep.clone(), stale_opts.clone()).expect("stale server");
    match NetClient::connect(
        &stale_server.local_addr(),
        "sim8",
        &stale_opts.measurement,
        &stale_opts.platform_key,
        3,
    ) {
        Err(NetError::Attestation(msg)) => {
            assert!(msg.contains("stale"), "got: {msg}")
        }
        other => panic!("stale report must fail attestation, got {other:?}"),
    }
    stale_server.shutdown();

    // The healthy front door still admits a correct client afterwards.
    let mut ok = NetClient::connect(&addr, "sim8", &opts.measurement, &opts.platform_key, 4)
        .expect("honest client");
    let image = image_for(&config);
    let ct = encrypt_request(&config, ok.session_word(), &image);
    assert_eq!(ok.infer(&ct).expect("inference").probs.len(), 10);

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// expiry mid-stream → typed denial with a refresh hint → resume
// ---------------------------------------------------------------------

#[test]
fn expiry_mid_stream_then_refresh_resumes_with_identical_output() {
    let config = net_config("sim8", 250);
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    let mut client =
        NetClient::connect(&addr, "sim8", &opts.measurement, &opts.platform_key, 0xFEED)
            .expect("attested handshake");
    assert_eq!(client.session_ttl_ms(), 250);

    let image = image_for(&config);
    let ct0 = encrypt_request(&config, client.session_word(), &image);
    let first = client.infer(&ct0).expect("inference before expiry");

    // Outlive the session TTL on the same connection.  Attested
    // sessions expire in place (they are never silently recycled), so
    // the denial carries the refresh hint over the wire.
    std::thread::sleep(std::time::Duration::from_millis(600));
    let deny = expect_denied(client.infer(&ct0));
    assert_eq!(deny.code, DenyCode::SessionExpired);
    assert!(
        deny.refreshable,
        "expired attested session must advertise refreshability: {deny:?}"
    );

    // Refresh bumps the epoch and re-arms the deadline; the request
    // must be re-encrypted under the new session word to decrypt
    // correctly, and the answer is bit-identical.
    let epoch = client.refresh().expect("refresh after expiry");
    assert_eq!(epoch, 1);
    let ct1 = encrypt_request(&config, client.session_word(), &image);
    assert_ne!(ct0, ct1);
    let resumed = client.infer(&ct1).expect("inference after refresh");
    assert_eq!(
        resumed.probs, first.probs,
        "resume after refresh must not perturb the math"
    );

    teardown(dep, server);
}

// ---------------------------------------------------------------------
// per-tenant rate limits: typed wire denials with backoff hints
// ---------------------------------------------------------------------

#[test]
fn rate_limited_tenants_receive_retry_hints_over_the_wire() {
    // Three tenants, each with a one-token bucket refilling at 0.2 rps
    // (one token per five seconds, so wall-clock jitter cannot refill
    // it mid-test): the first request per tenant is admitted, the
    // second is denied with a backoff hint — independently per tenant.
    let config = Config {
        models: "sim8:rps=0.2,sim9:rps=0.2,sim10:rps=0.2".into(),
        strategy: "origami/6".into(),
        workers: 1,
        admission_burst: 1.0,
        listen: "127.0.0.1:0".into(),
        session_ttl_ms: 600_000,
        ..Config::default()
    };
    let (dep, server, opts) = start(&config);
    let addr = server.local_addr();

    for (i, model) in ["sim8", "sim9", "sim10"].iter().enumerate() {
        let mut client = NetClient::connect(
            &addr,
            model,
            &opts.measurement,
            &opts.platform_key,
            100 + i as u64,
        )
        .expect("attested handshake");

        let size: usize = model.trim_start_matches("sim").parse().unwrap();
        let image = synth_images(1, size, 3, config.seed)[0].clone();
        let ct = encrypt_request(&config, client.session_word(), &image);
        let ok = client.infer(&ct).expect("first request within budget");
        assert_eq!(ok.probs.len(), 10);

        let deny = expect_denied(client.infer(&ct));
        assert_eq!(deny.code, DenyCode::RateLimited, "tenant {model}: {deny:?}");
        let hint = deny
            .retry_after_ms
            .unwrap_or_else(|| panic!("tenant {model}: rate denial must carry a hint"));
        assert!(hint >= 1, "tenant {model}: hint should be meaningful, got {hint}");
        assert!(!deny.refreshable, "rate denials are not session problems");
    }

    // The loop above already proves isolation: each tenant's first
    // request was admitted even after its neighbours exhausted theirs.
    assert_eq!(dep.models().len(), 3, "three tenants deployed");

    teardown(dep, server);
}
