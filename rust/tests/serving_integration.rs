//! Serving-stack integration: router → batcher → workers → strategy,
//! end to end over real artifacts.

mod common;

use common::{golden, max_abs_diff, test_stack};
use origami::coordinator::Router;
use origami::launcher::{encrypt_request, start_engine_from_config};

#[test]
fn engine_serves_concurrent_requests_correctly() {
    let Some((stack, mut config)) = test_stack() else { return };
    config.strategy = "origami/6".into();
    config.workers = 1;
    config.max_batch = 8;
    config.max_delay_ms = 5.0;
    let sample_bytes = stack.sample_bytes(&config.model).unwrap();
    let batches = stack.artifact_batches(&config.model).unwrap();
    let engine = start_engine_from_config(config.clone(), sample_bytes, batches).unwrap();

    let g = golden("vgg16-32").unwrap();
    // batched requests share the first request's session/epoch keystream,
    // so submit them all under session 0 (one attested batch channel).
    let replies: Vec<_> = (0..12)
        .map(|_| {
            let ct = encrypt_request(&config, 0, &g.input);
            engine.submit("vgg16-32", ct, 0).unwrap()
        })
        .collect();
    for (i, r) in replies.into_iter().enumerate() {
        let resp = r.recv().expect("reply arrives");
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
        assert!(
            max_abs_diff(&resp.probs, &g.logits) < 0.05,
            "req {i} diverged"
        );
        assert!(resp.latency_ms > 0.0);
    }
    let metrics = engine.shutdown();
    assert_eq!(metrics.requests, 12);
    assert!(metrics.batches >= 2, "12 reqs / max 8 → ≥2 batches");
    assert!(metrics.batch_size.mean() > 1.0, "batching actually batched");
}

#[test]
fn router_routes_and_rejects() {
    let Some((stack, mut config)) = test_stack() else { return };
    config.strategy = "open".into();
    config.workers = 1;
    config.max_delay_ms = 1.0;
    let sample_bytes = stack.sample_bytes(&config.model).unwrap();
    let batches = stack.artifact_batches(&config.model).unwrap();
    let engine = start_engine_from_config(config.clone(), sample_bytes, batches).unwrap();

    let mut router = Router::new();
    router.register("vgg16-32", engine, sample_bytes);
    assert_eq!(router.models(), vec!["vgg16-32".to_string()]);

    let g = golden("vgg16-32").unwrap();
    let ct = encrypt_request(&config, 0, &g.input);
    let resp = router.infer_blocking("vgg16-32", ct, 0).unwrap();
    assert!(resp.error.is_none());
    assert!(max_abs_diff(&resp.probs, &g.logits) < 1e-4);

    // admission checks
    assert!(router.submit("vgg19-32", vec![0u8; sample_bytes], 0).is_err());
    assert!(router.submit("vgg16-32", vec![0u8; 3], 0).is_err());
    router.shutdown();
}

#[test]
fn engine_reports_failures_not_hangs() {
    let Some((stack, mut config)) = test_stack() else { return };
    config.strategy = "origami/6".into();
    config.workers = 1;
    config.pool_epochs = 1;
    config.allow_factor_reuse = false; // strict OTP: later sessions fail
    let sample_bytes = stack.sample_bytes(&config.model).unwrap();
    let batches = stack.artifact_batches(&config.model).unwrap();
    let engine = start_engine_from_config(config.clone(), sample_bytes, batches).unwrap();
    let g = golden("vgg16-32").unwrap();

    let ok = engine
        .infer_blocking("vgg16-32", encrypt_request(&config, 0, &g.input), 0)
        .unwrap();
    assert!(ok.error.is_none());
    // session 5 is outside the 1-epoch pool → the strategy errors and the
    // response must carry the error rather than the engine hanging
    let bad = engine
        .infer_blocking("vgg16-32", encrypt_request(&config, 5, &g.input), 5)
        .unwrap();
    assert!(bad.error.is_some());
    engine.shutdown();
}
