//! Deterministic serving-simulation harness for the integration tests.
//!
//! Two layers:
//!
//! 1. **Seeded tenant workloads** ([`TenantLoad`], [`tenant_load`]):
//!    a model config + seeded sessions + synthetic images + the *serial*
//!    reference outputs (one strategy instance, batch-1, in order) every
//!    pooled/fabric execution must reproduce bit-for-bit.
//! 2. **Replay drivers** ([`drive_deployment`], [`drive_pool`],
//!    [`submit_interleaved`] / [`assert_replies`]): scripted submission
//!    orders against live deployments and pools, with bit-equality
//!    asserted on every reply.
//!
//! The pure simulated-timeline replay (SimClock, scripted arrival
//! traces, autoscale policy replay) lives in `origami::harness::sim`,
//! shared with the benches; this module re-exports its seed helper so
//! `make test-sim` pins one seed (`ORIGAMI_SIM_SEED`) across both.

use origami::config::Config;
use origami::coordinator::{Deployment, InferResponse, WorkerPool};
use origami::enclave::cost::Ledger;
use origami::launcher::{build_strategy_with, encrypt_request, executor_for, synth_images};
use origami::util::threadpool::Channel;

pub use origami::harness::sim::sim_seed;

/// One tenant's seeded workload and its serial reference outputs.
pub struct TenantLoad {
    pub cfg: Config,
    pub sessions: Vec<u64>,
    pub images: Vec<Vec<f32>>,
    /// Serial-path outputs, the bit-equality ground truth.
    pub expected: Vec<Vec<f32>>,
}

/// Build a seeded workload of `n` requests for `cfg`'s model (sessions
/// `base, base+stride, …`), computing the serial reference output for
/// each.  Deterministic: everything derives from `cfg.seed`.
pub fn tenant_load(cfg: Config, n: usize, session_base: u64, session_stride: u64) -> TenantLoad {
    let (executor, model) = executor_for(&cfg).expect("reference stack");
    let images = synth_images(n, model.image, model.in_channels, cfg.seed);
    let sessions: Vec<u64> = (0..n as u64)
        .map(|i| session_base + i * session_stride.max(1))
        .collect();
    let mut strategy = build_strategy_with(executor, model, &cfg).expect("strategy");
    let expected = images
        .iter()
        .zip(&sessions)
        .map(|(img, &session)| {
            let ct = encrypt_request(&cfg, session, img);
            strategy
                .infer(&ct, 1, &[session], &mut Ledger::new())
                .expect("serial inference")
        })
        .collect();
    TenantLoad {
        cfg,
        sessions,
        images,
        expected,
    }
}

impl TenantLoad {
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn model(&self) -> &str {
        &self.cfg.model
    }

    /// Encrypt request `i` under its session keystream.
    pub fn ciphertext(&self, i: usize) -> Vec<u8> {
        encrypt_request(&self.cfg, self.sessions[i], &self.images[i])
    }
}

/// A submitted-but-unread reply: (model, request index, channel).
pub type PendingReply = (String, usize, Channel<InferResponse>);

/// Submit every load's requests round-robin-interleaved across tenants
/// (request 0 of each load, then request 1 of each, …) — the scripted
/// multi-tenant arrival order the fabric tests replay.
pub fn submit_interleaved(dep: &Deployment, loads: &[&TenantLoad]) -> Vec<PendingReply> {
    let mut pending = Vec::new();
    let longest = loads.iter().map(|l| l.len()).max().unwrap_or(0);
    for i in 0..longest {
        for l in loads {
            if i < l.len() {
                let reply = dep
                    .submit(l.model(), l.ciphertext(i), l.sessions[i])
                    .unwrap_or_else(|e| panic!("{} request {i}: {e}", l.model()));
                pending.push((l.model().to_string(), i, reply));
            }
        }
    }
    pending
}

/// Collect every pending reply and assert it is error-free and
/// bit-identical to its load's serial reference.
pub fn assert_replies(pending: Vec<PendingReply>, loads: &[&TenantLoad]) {
    for (model, i, reply) in pending {
        let resp = reply
            .recv()
            .unwrap_or_else(|| panic!("{model} request {i}: reply channel closed"));
        assert!(resp.error.is_none(), "{model} request {i}: {:?}", resp.error);
        let expected = loads
            .iter()
            .find(|l| l.model() == model)
            .map(|l| &l.expected[i])
            .expect("reply for an unknown load");
        assert_eq!(
            &resp.probs, expected,
            "{model} request {i} diverged from the serial path"
        );
    }
}

/// Submit + collect in one go (fixed-capacity deployments).
pub fn drive_deployment(dep: &Deployment, loads: &[&TenantLoad]) {
    let pending = submit_interleaved(dep, loads);
    assert_replies(pending, loads);
}

/// Drive a single-model pool with a load (all requests submitted up
/// front, replies gathered after), asserting bit-equality throughout;
/// returns the outputs for callers that inspect them further.
pub fn drive_pool(pool: &WorkerPool, load: &TenantLoad) -> Vec<Vec<f32>> {
    let replies: Vec<_> = (0..load.len())
        .map(|i| {
            pool.submit(load.model(), load.ciphertext(i), load.sessions[i])
                .expect("submit")
        })
        .collect();
    replies
        .into_iter()
        .enumerate()
        .map(|(i, r)| {
            let resp = r.recv().expect("reply");
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(
                resp.probs, load.expected[i],
                "request {i} diverged from the serial path"
            );
            resp.probs
        })
        .collect()
}
