//! Shared helpers for the integration tests: artifact-gated stack
//! loaders (below) and the deterministic serving-simulation harness
//! ([`sim`]).  Each test binary compiles this module privately and uses
//! its own subset, so unused helpers are expected.
#![allow(dead_code)]

pub mod sim;

use std::path::PathBuf;

use origami::config::Config;
use origami::launcher::Stack;

/// Artifacts root for tests: $ORIGAMI_ARTIFACTS or <repo>/artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("ORIGAMI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Skip (return None) when artifacts haven't been built.
pub fn test_config() -> Option<Config> {
    let root = artifacts_root();
    if !root.join("manifest.json").exists() {
        eprintln!(
            "SKIP: no artifacts at {} — run `make artifacts`",
            root.display()
        );
        return None;
    }
    Some(Config {
        artifacts: root,
        ..Config::default()
    })
}

/// Build a stack or skip.
pub fn test_stack() -> Option<(Stack, Config)> {
    let config = test_config()?;
    let stack = Stack::load(&config).expect("stack loads");
    Some((stack, config))
}

/// Golden vectors exported by aot.py.
#[allow(dead_code)]
pub struct Golden {
    pub input: Vec<f32>,
    pub input_shape: Vec<usize>,
    pub logits: Vec<f32>,
}

pub fn golden(model: &str) -> Option<Golden> {
    let path = artifacts_root().join("golden").join(format!("{model}_golden.json"));
    if !path.exists() {
        return None;
    }
    let doc = origami::util::json::from_file(&path).ok()?;
    Some(Golden {
        input: doc
            .req("input")
            .ok()?
            .as_f64_vec()
            .ok()?
            .into_iter()
            .map(|v| v as f32)
            .collect(),
        input_shape: doc.req("input_shape").ok()?.as_usize_vec().ok()?,
        logits: doc
            .req("logits")
            .ok()?
            .as_f64_vec()
            .ok()?
            .into_iter()
            .map(|v| v as f32)
            .collect(),
    })
}

pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
