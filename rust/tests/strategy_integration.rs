//! Strategy integration: every execution strategy produces (nearly) the
//! same probabilities as the open reference on the same encrypted input,
//! costs land in the right ledger categories, and the paper's qualitative
//! orderings hold at 32 scale.

mod common;

use common::{golden, max_abs_diff, test_stack};
use origami::config::Config;
use origami::enclave::cost::{Cat, Ledger};
use origami::launcher::encrypt_request;

/// Blinded paths quantize activations to 2^-8 per layer; at 32 scale the
/// accumulated softmax deviation stays well under this.
const QUANT_TOL: f32 = 0.05;

fn run_strategy(config: &Config, strategy: &str) -> (Vec<f32>, Ledger) {
    let stack = origami::launcher::Stack::load(config).unwrap();
    let mut cfg = config.clone();
    cfg.strategy = strategy.to_string();
    let mut s = stack.build_strategy(&cfg).unwrap();
    let g = golden(&config.model).expect("golden vectors");
    let ct = encrypt_request(config, 0, &g.input);
    // warm once (artifact compile + first-exec autotune), then measure
    let mut warm = Ledger::new();
    let _ = s.infer(&ct, 1, &[0], &mut warm).unwrap();
    let mut ledger = Ledger::new();
    let probs = s.infer(&ct, 1, &[0], &mut ledger).unwrap();
    (probs, ledger)
}

#[test]
fn all_strategies_agree_with_golden() {
    let Some((_, config)) = test_stack() else { return };
    let g = golden("vgg16-32").unwrap();
    for strategy in ["open", "baseline2", "split/6", "slalom", "origami/6"] {
        let (probs, _) = run_strategy(&config, strategy);
        let tol = if strategy == "slalom" || strategy.starts_with("origami") {
            QUANT_TOL // fixed-point quantization in the blinded tier
        } else {
            1e-4
        };
        let diff = max_abs_diff(&probs, &g.logits);
        assert!(diff < tol, "{strategy}: diff {diff} (tol {tol})");
        // probabilities sum to 1
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "{strategy}: sum {sum}");
    }
}

#[test]
fn ledger_categories_match_strategy_structure() {
    let Some((_, config)) = test_stack() else { return };

    let (_, open) = run_strategy(&config, "open");
    assert_eq!(open.total_ns(Cat::Blind), 0);
    assert_eq!(open.total_ns(Cat::EnclaveCompute), 0);
    assert!(open.total_ns(Cat::DeviceCompute) > 0);

    let (_, b2) = run_strategy(&config, "baseline2");
    assert!(b2.total_ns(Cat::EnclaveCompute) > 0);
    assert_eq!(b2.total_ns(Cat::Blind), 0);
    assert_eq!(
        b2.total_ns(Cat::DeviceCompute),
        0,
        "baseline2 never touches the untrusted device"
    );
    assert!(b2.total_ns(Cat::Paging) > 0, "oversubscribed EPC must page");

    let (_, sl) = run_strategy(&config, "slalom");
    assert!(sl.total_ns(Cat::Blind) > 0);
    assert!(sl.total_ns(Cat::Unblind) > 0);
    assert!(sl.total_ns(Cat::DeviceCompute) > 0);
    assert_eq!(
        sl.total_ns(Cat::EnclaveCompute),
        0,
        "slalom offloads every linear op"
    );

    let (_, og) = run_strategy(&config, "origami/6");
    assert!(og.total_ns(Cat::Blind) > 0);
    assert!(og.total_ns(Cat::DeviceCompute) > 0);
    // origami blinds strictly less than slalom (only tier 1)
    assert!(
        og.total_ns(Cat::Blind) + og.total_ns(Cat::Unblind)
            < sl.total_ns(Cat::Blind) + sl.total_ns(Cat::Unblind),
        "origami must blind less than slalom"
    );
}

#[test]
fn paper_ordering_baseline_slowest_origami_beats_slalom() {
    let Some((_, config)) = test_stack() else { return };
    let (_, b2) = run_strategy(&config, "baseline2");
    let (_, sl) = run_strategy(&config, "slalom");
    let (_, og) = run_strategy(&config, "origami/6");
    let (b2_ms, sl_ms, og_ms) = (
        b2.grand_total_ms(),
        sl.grand_total_ms(),
        og.grand_total_ms(),
    );
    assert!(
        b2_ms > sl_ms && b2_ms > og_ms,
        "baseline2 ({b2_ms:.2}ms) must be slowest (slalom {sl_ms:.2}, origami {og_ms:.2})"
    );
    assert!(
        og_ms < sl_ms,
        "origami ({og_ms:.2}ms) must beat slalom ({sl_ms:.2}ms)"
    );
}

#[test]
fn memory_requirements_follow_table1_ordering() {
    let Some((_, config)) = test_stack() else { return };
    let stack = origami::launcher::Stack::load(&config).unwrap();
    let req = |strategy: &str| {
        let mut cfg = config.clone();
        cfg.strategy = strategy.into();
        stack.build_strategy(&cfg).unwrap().enclave_requirement_bytes()
    };
    let b2 = req("baseline2");
    let s6 = req("split/6");
    let s8 = req("split/8");
    let s10 = req("split/10");
    let sl = req("slalom");
    let og = req("origami/6");
    // Table I: baseline2 largest; splits grow with x; slalom==origami-ish
    assert!(b2 > s10 && s10 > s8 && s8 > s6, "{b2} {s10} {s8} {s6}");
    assert!(sl > s6, "blind buffers add over split/6");
    let rel = (sl as f64 - og as f64).abs() / sl as f64;
    assert!(rel < 0.15, "slalom {sl} vs origami {og} should be close");
}

#[test]
fn power_recovery_scales_with_enclave_size() {
    let Some((_, config)) = test_stack() else { return };
    let stack = origami::launcher::Stack::load(&config).unwrap();
    let recover = |strategy: &str| {
        let mut cfg = config.clone();
        cfg.strategy = strategy.into();
        let mut s = stack.build_strategy(&cfg).unwrap();
        // median of 3 cycles to de-noise
        let mut times: Vec<f64> = (0..3).map(|_| s.power_cycle().unwrap()).collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        times[1]
    };
    let b2 = recover("baseline2");
    let og = recover("origami/6");
    assert!(
        b2 > og,
        "baseline2 recovery ({b2:.3}ms) must exceed origami ({og:.3}ms)"
    );
}

#[test]
fn strict_otp_pool_exhaustion_fails_closed() {
    let Some((_, config)) = test_stack() else { return };
    let stack = origami::launcher::Stack::load(&config).unwrap();
    let mut cfg = config.clone();
    cfg.strategy = "origami/6".into();
    cfg.pool_epochs = 2;
    cfg.allow_factor_reuse = false;
    let mut s = stack.build_strategy(&cfg).unwrap();
    let g = golden("vgg16-32").unwrap();
    for session in 0..2u64 {
        let ct = encrypt_request(&cfg, session, &g.input);
        s.infer(&ct, 1, &[session], &mut Ledger::new()).unwrap();
    }
    let ct = encrypt_request(&cfg, 2, &g.input);
    let err = s.infer(&ct, 1, &[2], &mut Ledger::new()).unwrap_err();
    assert!(format!("{err:#}").contains("pool exhausted"), "{err:#}");
}

#[test]
fn batched_inference_matches_single() {
    let Some((_, config)) = test_stack() else { return };
    let stack = origami::launcher::Stack::load(&config).unwrap();
    let mut cfg = config.clone();
    cfg.strategy = "origami/6".into();
    let mut s = stack.build_strategy(&cfg).unwrap();
    let g = golden("vgg16-32").unwrap();
    // each sample is encrypted independently under its own session (the
    // batcher's contract), then concatenated
    let mut ct = Vec::new();
    let sessions: Vec<u64> = (0..8).collect();
    for &s_id in &sessions {
        ct.extend_from_slice(&encrypt_request(&cfg, s_id, &g.input));
    }
    let probs = s.infer(&ct, 8, &sessions, &mut Ledger::new()).unwrap();
    assert_eq!(probs.len(), 8 * g.logits.len());
    for i in 0..8 {
        let row = &probs[i * g.logits.len()..(i + 1) * g.logits.len()];
        assert!(max_abs_diff(row, &g.logits) < QUANT_TOL, "row {i}");
    }
}

#[test]
fn unknown_strategy_rejected() {
    let Some((_, config)) = test_stack() else { return };
    let stack = origami::launcher::Stack::load(&config).unwrap();
    let mut cfg = config.clone();
    cfg.strategy = "quantum".into();
    assert!(stack.build_strategy(&cfg).is_err());
}
