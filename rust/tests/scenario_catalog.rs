//! Hostile-scenario regression catalog: the arrival patterns and fault
//! sequences that historically break serving systems, each replayed
//! through the deterministic simulators in `origami::harness::sim` and
//! pinned by digest across rng seeds {2019, 1} and two tick cadences
//! (20 ms and 7 ms).
//!
//! The catalog covers:
//! - a **diurnal** day: quiet morning, overloaded midday peak, quiet
//!   evening — the autoscaler must grow through the peak and every
//!   offered request must complete;
//! - a **flash crowd**: a burst too fast for any scaling loop, absorbed
//!   by shed-to-degrade admission while a steady tenant keeps its
//!   latency;
//! - **crash-and-respawn chaos**: a member fails mid-traffic and a
//!   replacement joins mid-traffic, with zero compliant sessions lost —
//!   plus a live leg proving the *full serving path* (encrypted
//!   requests, blinded offload, real tier-2 tails) survives the
//!   respawn bit-identically, not just the blinding-domain bookkeeping;
//! - **attestation expiry mid-session**: a joiner whose handshake
//!   evidence falls outside the track's TTL window is denied with zero
//!   key material and zero serving impact;
//! - a **mixed fleet** of small tenants beside a paper-scale `sim224`
//!   tenant, packed into usable EPC with zero paging-storm ticks and
//!   every request served.
//!
//! Determinism discipline: the cluster replays consume the seed (join
//! challenges, link jitter) and the tick cadence, so their invariance
//! is a real theorem about the routing code.  The queueing replays take
//! no rng at all and fold only work-conserving outcomes (per-tenant
//! served counts, shed ledgers) — the digest grid then pins that no
//! cadence- or seed-shaped behavior leaks into what was served.

use std::collections::HashMap;
use std::sync::Arc;

use origami::config::Config;
use origami::coordinator::{AutoscalePolicy, ClusterOptions, ClusterRouter, Deployment, Frontend};
use origami::enclave::cost::Ledger;
use origami::harness::sim::{
    replay, replay_cluster, replay_epc_packing, sim_seed, ClusterEvent, ClusterEventKind,
    ClusterSimConfig, EpcSimConfig, EpcSimTenant, SimAdmission, SimConfig, SimNode, Trace,
};
use origami::launcher::{
    build_strategy_with, deploy_from_config, encrypt_request, executor_for,
    fabric_options_from_config, synth_images, worker_epc_bytes_from_config,
};

// ── the digest grid ─────────────────────────────────────────────────

/// FNV-1a accumulator for scenario outcomes (same constants as the
/// cluster replay's internal digest).
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x1000_0000_01b3);
        }
    }

    fn u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.write(s.as_bytes());
    }
}

/// Replay `scenario` at every (seed, tick cadence) grid point — the
/// pinned seeds plus whatever `ORIGAMI_SIM_SEED` CI injects — and
/// require one digest everywhere.
fn pinned_across_grid(name: &str, scenario: impl Fn(u64, f64) -> u64) {
    let base = scenario(2019, 20.0);
    for seed in [2019, 1, sim_seed()] {
        for tick_ms in [20.0, 7.0] {
            let got = scenario(seed, tick_ms);
            assert_eq!(
                got, base,
                "scenario `{name}` drifted at seed {seed}, tick {tick_ms} ms"
            );
        }
    }
}

// ── diurnal arrival cycle ───────────────────────────────────────────

/// Quiet morning, overloaded midday peak, quiet evening: the midday
/// block offers 4-request chunks costing 4 ms every 2 ms — twice what
/// one lane serves — so the depth autoscaler must grow mid-day and
/// every offered request must still complete.
fn diurnal_digest(_seed: u64, tick_ms: f64) -> u64 {
    let mut t = Trace::new();
    t.push_periodic("web", 0.0, 10.0, 30, 1, 2.0);
    t.push_periodic("web", 300.0, 2.0, 100, 4, 4.0);
    t.push_periodic("web", 520.0, 10.0, 30, 1, 2.0);
    // a steady background tenant runs across the whole day
    t.push_periodic("batch", 0.0, 20.0, 40, 1, 4.0);
    let r = replay(
        &SimConfig {
            weights: vec![("web".into(), 2.0), ("batch".into(), 1.0)],
            lanes: 1,
            max_lanes: 4,
            split_chunk: 2,
            policy: Some(AutoscalePolicy {
                tick_ms: tick_ms as u64,
                cooldown_ticks: 1,
                ..AutoscalePolicy::default()
            }),
            ..SimConfig::default()
        },
        &t,
    );
    assert!(
        r.peak_lanes > 1,
        "the midday peak must grow the lane fleet (peak {})",
        r.peak_lanes
    );
    assert!(r.scale_events >= 1);
    assert_eq!(r.count(Some("web")), 30 + 400 + 30, "every web request completes");
    assert_eq!(r.count(Some("batch")), 40, "every batch request completes");
    assert!(r.rejected.is_empty() && r.degraded.is_empty());
    // fold the work-conserving outcome only: lane counts and latencies
    // legitimately follow the tick cadence, what was served must not
    let mut d = Fnv::new();
    for (tenant, n) in r.served_by_tenant() {
        d.str(&tenant);
        d.u64(n as u64);
    }
    d.0
}

#[test]
fn diurnal_cycle_scales_and_conserves_every_request() {
    pinned_across_grid("diurnal", diurnal_digest);
}

// ── flash crowd ─────────────────────────────────────────────────────

/// 80 requests land in one instant — faster than any scaling loop can
/// react, so the fleet is fixed and shed-to-degrade admission is the
/// only defense.  Neither grid axis feeds this replay; the grid pins
/// exactly that, over the *full* sample set.
fn flash_crowd_digest(_seed: u64, _tick_ms: f64) -> u64 {
    let mut t = Trace::new();
    t.push_periodic("steady", 0.0, 5.0, 60, 1, 1.0);
    for _ in 0..80 {
        t.push(100.0, "crowd", 1, 2.0);
    }
    let r = replay(
        &SimConfig {
            weights: vec![("steady".into(), 1.0), ("crowd".into(), 1.0)],
            lanes: 2,
            split_chunk: 1,
            admission: vec![(
                "crowd".into(),
                SimAdmission {
                    rps: 0.0,
                    burst: 0.0,
                    inflight: 0,
                    shed_depth: 24,
                    degrade_ms: 6.0,
                },
            )],
            ..SimConfig::default()
        },
        &t,
    );
    assert_eq!(r.count(Some("steady")), 60);
    assert_eq!(
        r.count(Some("crowd")),
        80,
        "shed requests degrade to the cheaper tier, they are never dropped"
    );
    assert_eq!(
        r.degraded.get("crowd").copied().unwrap_or(0),
        56,
        "everything past the 24-deep queue degrades"
    );
    assert!(r.rejected.is_empty());
    let steady_p95 = r.p95(Some("steady"));
    assert!(
        steady_p95 < 10.0,
        "the steady tenant must keep its latency through the flash \
         (p95 {steady_p95:.2} ms)"
    );
    assert!(r.p95(Some("crowd")) > steady_p95);
    // a fixed fleet with no rng is exactly reproducible: fold every sample
    let mut samples: Vec<(String, u64, bool)> = r
        .samples
        .iter()
        .map(|s| (s.tenant.clone(), s.latency_ms.to_bits(), s.degraded))
        .collect();
    samples.sort();
    let mut d = Fnv::new();
    for (tenant, lat_bits, degraded) in samples {
        d.str(&tenant);
        d.u64(lat_bits);
        d.u64(degraded as u64);
    }
    d.0
}

#[test]
fn flash_crowd_sheds_to_degraded_tier_and_shields_the_steady_tenant() {
    pinned_across_grid("flash-crowd", flash_crowd_digest);
}

// ── crash-and-respawn chaos (replay) ────────────────────────────────

/// A member fails mid-traffic and a replacement joins mid-traffic.
/// The route plan keeps the dead member's entry as a tombstone, so the
/// replacement joins under a fresh node identity — exactly how a
/// production respawn mints a fresh incarnation.
fn chaos_digest(seed: u64, tick_ms: f64) -> u64 {
    let mut cfg = ClusterSimConfig::three_node(seed);
    cfg.tick_ms = tick_ms;
    // arrivals span [0, 320) ms: the crash and the respawn land mid-stream
    cfg.arrivals_per_session = 8;
    cfg.events.push(ClusterEvent {
        at_ms: 150.0,
        kind: ClusterEventKind::MarkFailing { node: 1 },
    });
    cfg.nodes.push(SimNode::new("node-d", "prod").skew(1.0));
    cfg.events.push(ClusterEvent {
        at_ms: 250.0,
        kind: ClusterEventKind::Join { node: 3 },
    });
    let r = replay_cluster(&cfg);
    assert_eq!(
        r.served,
        48 * 8,
        "every arrival is served across the crash and the respawn"
    );
    assert_eq!(r.lost, 0, "chaos must lose no compliant session");
    assert_eq!(r.isolated, 0);
    assert!(
        r.moved >= 1,
        "the failing member's pinned sessions must migrate to siblings"
    );
    assert_eq!((r.joins_ok, r.joins_denied), (3, 0));
    assert!(r.incarnations.contains_key("node-d"));
    let mut d = Fnv::new();
    d.u64(r.served);
    d.u64(r.isolated);
    d.u64(r.lost);
    d.u64(r.joins_ok);
    d.u64(r.joins_denied);
    for (node, inc) in &r.incarnations {
        d.str(node);
        d.u64(*inc);
    }
    d.u64(r.digest);
    d.0
}

#[test]
fn worker_crash_and_respawn_chaos_loses_no_compliant_session() {
    pinned_across_grid("chaos-crash-respawn", chaos_digest);
}

// ── attestation expiry mid-session ──────────────────────────────────

/// A joiner whose clock drifted 90 s ahead completes the handshake
/// with evidence that lands outside the track's 60 s attestation TTL:
/// the grant it receives is already expired on its own clock, so the
/// join aborts with zero key material and zero routing impact — the
/// in-flight sessions never notice.
fn attestation_expiry_digest(seed: u64, tick_ms: f64) -> u64 {
    let mut cfg = ClusterSimConfig::three_node(seed);
    cfg.tick_ms = tick_ms;
    cfg.arrivals_per_session = 8;
    cfg.nodes.push(SimNode::new("node-late", "prod").skew(90_000.0));
    cfg.events.push(ClusterEvent {
        at_ms: 200.0,
        kind: ClusterEventKind::Join { node: 3 },
    });
    let r = replay_cluster(&cfg);
    assert_eq!(
        (r.joins_ok, r.joins_denied),
        (2, 1),
        "evidence outside the attestation TTL must be refused"
    );
    assert!(
        !r.incarnations.contains_key("node-late"),
        "a denied join must leave no membership behind"
    );
    assert_eq!(r.served, 48 * 8, "serving continues unharmed");
    assert_eq!(r.lost, 0, "an expired-attestation join loses no session");
    assert_eq!(r.isolated, 0);
    assert_eq!(r.moved, 0, "nobody drains, nothing migrates");
    let mut d = Fnv::new();
    d.u64(r.served);
    d.u64(r.isolated);
    d.u64(r.lost);
    d.u64(r.joins_ok);
    d.u64(r.joins_denied);
    for (node, inc) in &r.incarnations {
        d.str(node);
        d.u64(*inc);
    }
    d.u64(r.digest);
    d.0
}

#[test]
fn attestation_expiry_mid_session_denies_the_join_and_loses_nothing() {
    pinned_across_grid("attestation-expiry", attestation_expiry_digest);
}

// ── mixed fleet: small tenants beside paper-scale sim224 ────────────

/// Two small tenants and one paper-scale `sim224` tenant share usable
/// EPC under the packer: overload everything, require zero paging-storm
/// ticks, residency inside the budget, and every request served — with
/// the served ledger identical to naive (un-packed) scaling, since
/// packing throttles capacity, never work.
fn mixed_fleet_digest(_seed: u64, tick_ms: f64) -> u64 {
    let big = Config {
        model: "sim224".into(),
        strategy: "origami/6".into(),
        max_batch: 4,
        ..Config::paper_scale()
    };
    let worker_bytes = worker_epc_bytes_from_config(&big).expect("sim224 memory analytics");
    let usable = big.usable_epc_bytes();
    let fit = (usable / worker_bytes) as usize;
    assert!(
        fit >= 2,
        "paper-scale EPC must hold at least two sim224 workers \
         ({worker_bytes} B each, {usable} B usable)"
    );
    let small_bytes = worker_bytes / 6;

    let mut t = Trace::new();
    t.push_periodic("sim224/a", 0.0, 2.0, 80, 2, 10.0);
    t.push_periodic("edge-a", 0.0, 4.0, 60, 1, 2.0);
    t.push_periodic("edge-b", 1.0, 4.0, 60, 1, 2.0);

    let mk = |packing: bool| EpcSimConfig {
        usable_bytes: usable,
        overcommit: 1.0,
        packing,
        tenants: vec![
            EpcSimTenant {
                name: "sim224/a".into(),
                worker_bytes,
                min_workers: 1,
                max_workers: fit,
                weight: 1.0,
            },
            EpcSimTenant {
                name: "edge-a".into(),
                worker_bytes: small_bytes,
                min_workers: 1,
                max_workers: 4,
                weight: 1.0,
            },
            EpcSimTenant {
                name: "edge-b".into(),
                worker_bytes: small_bytes,
                min_workers: 1,
                max_workers: 4,
                weight: 1.0,
            },
        ],
        policy: AutoscalePolicy {
            high_depth_per_worker: 2,
            low_depth_per_worker: 0,
            tick_ms: tick_ms as u64,
            cooldown_ticks: 1,
            ..AutoscalePolicy::default()
        },
    };
    let packed = replay_epc_packing(&mk(true), &t);
    let naive = replay_epc_packing(&mk(false), &t);

    assert_eq!(
        packed.storm_ticks, 0,
        "the packed mixed fleet must never enter the paging-storm regime"
    );
    assert!(
        packed.peak_resident_bytes <= usable,
        "packed residency exceeded usable EPC"
    );
    for (tenant, offered) in [("sim224/a", 160usize), ("edge-a", 60), ("edge-b", 60)] {
        assert_eq!(
            packed.served.get(tenant).copied().unwrap_or(0),
            offered,
            "tenant `{tenant}` must have every offered request served"
        );
    }
    assert_eq!(
        packed.served, naive.served,
        "packing throttles capacity, never work"
    );
    let mut d = Fnv::new();
    for (tenant, n) in &packed.served {
        d.str(tenant);
        d.u64(*n as u64);
    }
    d.u64(packed.storm_ticks);
    d.0
}

#[test]
fn mixed_fleet_of_small_and_sim224_tenants_packs_without_storms() {
    pinned_across_grid("mixed-fleet-epc", mixed_fleet_digest);
}

// ── live leg: the full serving path survives crash-and-respawn ──────

const MODEL: &str = "sim8";

fn model_config() -> Config {
    Config {
        model: MODEL.into(),
        strategy: "origami/6".into(),
        workers: 1,
        max_batch: 1, // batch == request: deterministic accounting
        max_delay_ms: 0.0,
        pool_epochs: 16,
        pipeline: true,
        ..Config::default()
    }
}

struct Workload {
    cfg: Config,
    sessions: Vec<u64>,
    images: Vec<Vec<f32>>,
    expected: Vec<Vec<f32>>,
}

/// `n` encrypted requests plus their serial-reference answers.
fn workload(n: usize, session_base: u64) -> anyhow::Result<Workload> {
    let cfg = model_config();
    let (_, m) = executor_for(&cfg)?;
    let images = synth_images(n, m.image, m.in_channels, cfg.seed);
    let sessions: Vec<u64> = (0..n as u64).map(|i| session_base + i).collect();
    let (executor, m) = executor_for(&cfg)?;
    let mut strategy = build_strategy_with(executor, m, &cfg)?;
    let expected = images
        .iter()
        .zip(&sessions)
        .map(|(img, &s)| {
            let ct = encrypt_request(&cfg, s, img);
            strategy.infer(&ct, 1, &[s], &mut Ledger::new())
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(Workload {
        cfg,
        sessions,
        images,
        expected,
    })
}

fn member(cfg: &Config) -> anyhow::Result<Deployment> {
    let dep = Deployment::builder(fabric_options_from_config(cfg)?)
        .sweep_every_ms(0)
        .build();
    deploy_from_config(&dep, cfg, 1.0)?;
    Ok(dep)
}

/// Serve request `i` of `load` through `front` and require the reply
/// bit-identical to the serial reference.
fn serve_one(front: &dyn Frontend, load: &Workload, i: usize) {
    let s = load.sessions[i];
    let ct = encrypt_request(&load.cfg, s, &load.images[i]);
    let resp = front.infer_blocking(MODEL, ct, s).expect("infer");
    assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
    assert_eq!(
        resp.probs, load.expected[i],
        "request {i} (session {s}) diverged from the serial reference"
    );
}

#[test]
fn full_serving_path_survives_crash_and_respawn() {
    let load = workload(12, 700_000).expect("workload");
    let router = ClusterRouter::new(ClusterOptions::default());
    for name in ["n1", "n2", "n3"] {
        router.add_node(name, "prod", Arc::new(member(&load.cfg).expect("member")));
    }
    for i in 0..load.sessions.len() {
        serve_one(&router, &load, i);
    }

    // crash the member holding the most pins — the worst case
    let mut pins: HashMap<String, usize> = HashMap::new();
    for &s in &load.sessions {
        if let Some(node) = router.pin_of(s) {
            *pins.entry(node).or_insert(0) += 1;
        }
    }
    let victim = pins
        .iter()
        .max_by_key(|(name, &n)| (n, std::cmp::Reverse((*name).clone())))
        .map(|(name, _)| name.clone())
        .expect("some node holds pins");
    let moved = router.kill(&victim);
    assert!(moved >= 1, "the victim's sessions must migrate");

    // every session serves again, bit-identical, on the survivors
    for i in 0..load.sessions.len() {
        serve_one(&router, &load, i);
    }
    for &s in &load.sessions {
        let node = router.pin_of(s).expect("session still pinned");
        assert_ne!(node, victim, "session {s} still pinned to the dead node");
    }

    // respawn: the route plan tombstones the dead name, so the
    // replacement joins under a fresh identity — the routing-layer face
    // of a production respawn's fresh incarnation — and the whole
    // serving path (encryption, blinding, tier-2 tails) runs through it
    router.add_node(
        "respawn-1",
        "prod",
        Arc::new(member(&load.cfg).expect("member")),
    );
    let probe = workload(48, 800_000).expect("probe workload");
    let mut on_new = 0usize;
    for i in 0..probe.sessions.len() {
        serve_one(&router, &probe, i);
        if router.pin_of(probe.sessions[i]).as_deref() == Some("respawn-1") {
            on_new += 1;
        }
    }
    assert!(
        on_new >= 1,
        "the respawned member must take a share of new sessions (got {on_new} of 48)"
    );

    // the pre-crash sessions keep serving bit-identically beside it
    for i in 0..load.sessions.len() {
        serve_one(&router, &load, i);
    }
    router.shutdown();
}
