//! Admission-control acceptance: per-tenant rate limits, in-flight
//! quotas, queue-depth shedding and the degrade tier, end to end on a
//! live [`Deployment`].
//!
//! Strategy doubles with an explicit gate pin queue states
//! deterministically (a blocked worker makes backlog growth monotone),
//! so the shed/quota paths are exercised without wall-clock races; the
//! launcher-path test drives real `sim8` inference and re-asserts
//! bit-equality with the serial reference under admission.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use common::sim::{drive_deployment, tenant_load};
use origami::config::Config;
use origami::coordinator::scheduler::{BatchScheduler, Tier2Finisher};
use origami::coordinator::{
    AdmissionError, AdmissionLimits, DeploySpec, Deployment, FabricOptions, PoolOptions,
    ShedPolicy,
};
use origami::enclave::cost::{Cat, CostModel, Ledger};
use origami::launcher::{deploy_from_config, fabric_options_from_config, DEGRADE_TENANT_SUFFIX};
use origami::runtime::{Device, ReferenceBackend, StageExecutor};
use origami::strategies::Strategy;

/// Deterministic strategy double: "probability" = session + marker.
/// While the gate is closed, `infer` blocks — queued work behind it can
/// only grow, which makes shed/quota states reproducible.
struct Gate {
    open: Arc<AtomicBool>,
    marker: f32,
}

impl Strategy for Gate {
    fn name(&self) -> String {
        "gate".into()
    }

    fn setup(&mut self) -> Result<()> {
        Ok(())
    }

    fn infer(
        &mut self,
        _ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        while !self.open.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        ledger.add_measured(Cat::DeviceCompute, 100_000);
        Ok((0..batch)
            .map(|i| sessions.get(i).copied().unwrap_or(0) as f32 + self.marker)
            .collect())
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        0
    }
}

fn open_gate() -> Arc<AtomicBool> {
    Arc::new(AtomicBool::new(true))
}

fn gate_sched(
    open: Arc<AtomicBool>,
    marker: f32,
) -> impl Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static {
    move |_band, _domain| {
        Ok(BatchScheduler::new(
            Box::new(Gate {
                open: open.clone(),
                marker,
            }),
            8,
            vec![1],
        ))
    }
}

fn ref_finisher() -> impl Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static {
    |_lane| {
        let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
        Ok(Tier2Finisher::new(
            Arc::new(StageExecutor::reference(rb, CostModel::default())),
            "sim8",
            Device::UntrustedCpu,
        ))
    }
}

/// One slow shard, batch-1, no pipelining: tier-1 is the whole request.
fn tiny_pool() -> PoolOptions {
    PoolOptions {
        workers: 1,
        max_batch: 1,
        max_delay_ms: 0.0,
        pipeline: false,
        ..PoolOptions::default()
    }
}

#[test]
fn shed_request_unbinds_its_session() {
    let open = Arc::new(AtomicBool::new(false));
    let dep = Deployment::builder(FabricOptions::default()).build();
    dep.deploy_model(
        DeploySpec::new("gated", 8)
            .admission(AdmissionLimits {
                shed_depth: 1,
                ..AdmissionLimits::default()
            })
            .shed_policy(ShedPolicy::Reject)
            .pool(tiny_pool()),
        gate_sched(open.clone(), 0.0),
        ref_finisher(),
    )
    .unwrap();
    dep.deploy_model(
        DeploySpec::new("other", 8).pool(tiny_pool()),
        gate_sched(open_gate(), 0.5),
        ref_finisher(),
    )
    .unwrap();

    // with the gate closed, backlog only grows: a shed must appear
    let mut admitted = Vec::new();
    let mut shed_session = None;
    for i in 0..32u64 {
        let session = 100 + i;
        match dep.submit("gated", vec![0u8; 8], session) {
            Ok(reply) => admitted.push((session, reply)),
            Err(AdmissionError::Shed {
                model, threshold, ..
            }) => {
                assert_eq!(model, "gated");
                assert_eq!(threshold, 1);
                shed_session = Some(session);
                break;
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    let shed_session = shed_session.expect("a blocked pool must eventually shed");
    assert!(!admitted.is_empty(), "something was admitted before the shed");

    // the shed session must not stay bound to `gated` (regression:
    // shedding after first-touch binding used to leak the binding)…
    let reply = dep
        .submit("other", vec![0u8; 8], shed_session)
        .expect("a shed session must be free to bind elsewhere");
    let resp = reply.recv().expect("other reply");
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.probs[0], shed_session as f32 + 0.5);

    // …while an *admitted* session stays bound as usual
    let bound = admitted[0].0;
    match dep.submit("other", vec![0u8; 8], bound) {
        Err(AdmissionError::SessionCollision { session, .. }) => assert_eq!(session, bound),
        Err(e) => panic!("expected a session collision, got {e}"),
        Ok(_) => panic!("expected a session collision, got an admitted request"),
    }

    // release the gate: every admitted request completes correctly
    open.store(true, Ordering::SeqCst);
    for (session, reply) in admitted {
        let resp = reply.recv().expect("gated reply");
        assert!(resp.error.is_none(), "session {session}: {:?}", resp.error);
        assert_eq!(resp.probs[0], session as f32);
    }
    let snap = dep.admission_snapshot("gated").unwrap();
    assert!(snap.shed >= 1);
    assert!(snap.admitted >= 1);
    assert_eq!(snap.degraded, 0);
    assert_eq!(snap.rate_limited, 0);
    dep.shutdown();
}

#[test]
fn quota_rejects_then_slots_release_on_completion() {
    let open = Arc::new(AtomicBool::new(false));
    let dep = Deployment::builder(FabricOptions::default()).build();
    dep.deploy_model(
        DeploySpec::new("quota", 8)
            .admission(AdmissionLimits {
                inflight: 2,
                ..AdmissionLimits::default()
            })
            .shed_policy(ShedPolicy::Reject)
            .pool(tiny_pool()),
        gate_sched(open.clone(), 0.0),
        ref_finisher(),
    )
    .unwrap();

    let r1 = dep.submit("quota", vec![0u8; 8], 1).unwrap();
    let r2 = dep.submit("quota", vec![0u8; 8], 2).unwrap();
    match dep.submit("quota", vec![0u8; 8], 3) {
        Err(AdmissionError::QuotaExceeded { model, limit, .. }) => {
            assert_eq!(model, "quota");
            assert_eq!(limit, 2);
        }
        Err(e) => panic!("expected a quota rejection, got {e}"),
        Ok(_) => panic!("expected a quota rejection, got an admitted request"),
    }

    open.store(true, Ordering::SeqCst);
    assert_eq!(r1.recv().expect("reply 1").probs[0], 1.0);
    assert_eq!(r2.recv().expect("reply 2").probs[0], 2.0);

    // permits release when the served requests drop (a hair after the
    // reply lands) — the quota-rejected session can then be admitted
    let mut reply3 = None;
    for _ in 0..2000 {
        match dep.submit("quota", vec![0u8; 8], 3) {
            Ok(r) => {
                reply3 = Some(r);
                break;
            }
            Err(AdmissionError::QuotaExceeded { .. }) => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    let resp = reply3.expect("in-flight slots never released").recv().unwrap();
    assert_eq!(resp.probs[0], 3.0);
    let snap = dep.admission_snapshot("quota").unwrap();
    assert_eq!(snap.admitted, 3);
    assert!(snap.quota_rejected >= 1);
    dep.shutdown();
}

#[test]
fn rate_limited_session_is_unbound_with_a_retry_hint() {
    let dep = Deployment::builder(FabricOptions::default()).build();
    dep.deploy_model(
        DeploySpec::new("limited", 8)
            .admission(AdmissionLimits {
                rps: 1.0,
                burst: 1.0,
                ..AdmissionLimits::default()
            })
            .shed_policy(ShedPolicy::Reject)
            .pool(tiny_pool()),
        gate_sched(open_gate(), 0.0),
        ref_finisher(),
    )
    .unwrap();
    dep.deploy_model(
        DeploySpec::new("other", 8).pool(tiny_pool()),
        gate_sched(open_gate(), 0.5),
        ref_finisher(),
    )
    .unwrap();

    let reply = dep.submit("limited", vec![0u8; 8], 10).unwrap();
    assert_eq!(reply.recv().expect("first reply").probs[0], 10.0);

    // the burst of 1 is spent; at 1 rps the next token is ~1 s away
    match dep.submit("limited", vec![0u8; 8], 20) {
        Err(e @ AdmissionError::RateLimited { .. }) => {
            let hint = e.retry_after_ms().unwrap();
            assert!(hint >= 1, "hint must point at the refill, got {hint}");
        }
        Err(e) => panic!("expected a rate limit, got {e}"),
        Ok(_) => panic!("expected a rate limit, got an admitted request"),
    }

    // the refused session binds cleanly elsewhere (no phantom binding)
    let reply = dep.submit("other", vec![0u8; 8], 20).unwrap();
    assert_eq!(reply.recv().expect("other reply").probs[0], 20.5);

    let snap = dep.admission_snapshot("limited").unwrap();
    assert_eq!(snap.admitted, 1);
    assert_eq!(snap.rate_limited, 1);
    dep.shutdown();
}

#[test]
fn degrade_routes_shed_requests_to_the_cheaper_tier() {
    let open = Arc::new(AtomicBool::new(false));
    let dep = Deployment::builder(FabricOptions::default()).build();
    dep.deploy_model(
        DeploySpec::new("svc", 8)
            .admission(AdmissionLimits {
                shed_depth: 1,
                ..AdmissionLimits::default()
            })
            .shed_policy(ShedPolicy::Degrade)
            .pool(tiny_pool()),
        gate_sched(open.clone(), 0.0),
        ref_finisher(),
    )
    .unwrap();
    // the cheaper tier: instant service, marker 0.25
    dep.deploy_model(
        DeploySpec::new("svc~cheap", 8).pool(tiny_pool()),
        gate_sched(open_gate(), 0.25),
        ref_finisher(),
    )
    .unwrap();
    dep.set_degrade("svc", "svc~cheap").unwrap();
    // degrade chains are refused ("svc" already degrades)
    assert!(dep.set_degrade("svc~cheap", "svc").is_err());

    let mut replies = Vec::new();
    for i in 0..8u64 {
        let session = 500 + i;
        let reply = dep.submit("svc", vec![0u8; 8], session).unwrap();
        replies.push((session, reply));
    }
    let snap = dep.admission_snapshot("svc").unwrap();
    assert!(snap.degraded >= 1, "the blocked pool must degrade overflow");
    assert_eq!(snap.shed, 0, "degrades are not counted as shed rejections");
    assert_eq!(snap.admitted + snap.degraded, 8, "every request was served");

    // every request gets exactly one reply: primary marker 0.0 once the
    // gate opens, degraded marker 0.25 straight from the cheap tier
    open.store(true, Ordering::SeqCst);
    let mut degraded_seen = 0u64;
    for (session, reply) in replies {
        let resp = reply.recv().expect("reply");
        assert!(resp.error.is_none(), "session {session}: {:?}", resp.error);
        let p = resp.probs[0];
        if p == session as f32 + 0.25 {
            degraded_seen += 1;
        } else {
            assert_eq!(p, session as f32, "session {session}: unexpected output");
        }
    }
    assert_eq!(degraded_seen, snap.degraded);
    dep.shutdown();
}

/// The launcher path: admission limits + degrade tier from a `Config`,
/// serving real `sim8` private inference — admitted requests stay
/// bit-identical to the serial reference.
#[test]
fn launcher_wires_admission_and_degrade_tier_from_config() {
    let cfg = Config {
        model: "sim8".into(),
        strategy: "origami/6".into(),
        workers: 1,
        max_batch: 2,
        max_delay_ms: 0.2,
        pool_epochs: 16,
        pipeline: true,
        rps: 1e6,
        admission_burst: 64.0,
        inflight: 256,
        shed_depth: 1000,
        shed_policy: "degrade".into(),
        degrade_strategy: "baseline2".into(),
        ..Config::default()
    };
    let dep = Deployment::builder(fabric_options_from_config(&cfg).unwrap()).build();
    deploy_from_config(&dep, &cfg, 1.0).unwrap();
    assert_eq!(
        dep.models(),
        vec![
            "sim8".to_string(),
            format!("sim8{}", DEGRADE_TENANT_SUFFIX),
        ],
        "degrade policy deploys the cheaper tier alongside the primary"
    );

    // generous limits: everything is admitted, outputs bit-identical
    let load = tenant_load(cfg.clone(), 10, 0, 1);
    drive_deployment(&dep, &[&load]);
    let snap = dep.admission_snapshot("sim8").unwrap();
    assert_eq!(snap.admitted, 10);
    assert_eq!(snap.rejected(), 0);
    assert_eq!(snap.degraded, 0);
    dep.shutdown();
}
