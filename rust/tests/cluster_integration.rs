//! Multi-node cluster acceptance: the track join protocol, the cluster
//! router's drain migration, partition isolation, and the session-TTL
//! sweeper — end to end on live [`Deployment`]s and on the multi-node
//! discrete-event replay.
//!
//! The replay tests drive the *production* `TrackRegistry` frames and
//! `RoutePlan` routing through `origami::harness::sim::replay_cluster`,
//! so CI exercises clock skew, link delay and partitions without ever
//! opening a socket.

use std::sync::Arc;

use anyhow::Result;
use origami::coordinator::scheduler::{BatchScheduler, Tier2Finisher};
use origami::coordinator::track::{accept_grant, join_request};
use origami::coordinator::{
    ClusterOptions, ClusterRouter, DeploySpec, Deployment, FabricOptions, PoolOptions,
    SessionTable, TrackError, TrackOptions, TrackRegistry, TRACK_DOMAIN_STRIDE,
};
use origami::enclave::cost::{Cat, CostModel, Ledger};
use origami::harness::sim::{
    replay_cluster, ClusterEvent, ClusterEventKind, ClusterSimConfig, SimNode,
};
use origami::runtime::{Device, ReferenceBackend, StageExecutor};
use origami::strategies::Strategy;

/// Deterministic strategy double: echoes each request's session id so
/// replies are attributable without real model weights.
struct Echo;

impl Strategy for Echo {
    fn name(&self) -> String {
        "echo".into()
    }

    fn setup(&mut self) -> Result<()> {
        Ok(())
    }

    fn infer(
        &mut self,
        _ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        ledger.add_measured(Cat::DeviceCompute, 1_000);
        Ok((0..batch)
            .map(|i| sessions.get(i).copied().unwrap_or(0) as f32)
            .collect())
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        0
    }
}

fn echo_sched() -> impl Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static {
    move |_band, _domain| Ok(BatchScheduler::new(Box::new(Echo), 8, vec![1]))
}

fn ref_finisher() -> impl Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static {
    |_lane| {
        let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
        Ok(Tier2Finisher::new(
            Arc::new(StageExecutor::reference(rb, CostModel::default())),
            "sim8",
            Device::UntrustedCpu,
        ))
    }
}

fn member_node() -> Arc<Deployment> {
    let dep = Deployment::builder(FabricOptions::default())
        .sweep_every_ms(0)
        .build();
    dep.deploy_model(
        DeploySpec::new("m", 8).pool(PoolOptions {
            workers: 1,
            min_workers: 1,
            max_workers: 1,
            max_batch: 1,
            max_delay_ms: 0.0,
            pipeline: false,
            ..PoolOptions::default()
        }),
        echo_sched(),
        ref_finisher(),
    )
    .unwrap();
    Arc::new(dep)
}

// ── crash-and-respawn: monotone incarnations, disjoint pad bands ────

#[test]
fn crash_and_respawn_rejoins_without_pad_reuse() {
    let reg = TrackRegistry::new(2019, TrackOptions::default());
    let genesis = reg.claim("prod", "node-a");
    let opts = TrackOptions::default();

    // first life: wire join
    let req = join_request(&opts, "prod", "node-b", 101, 1_000);
    let reply = reg.handle_join(&req, 1_000);
    let life1 = accept_grant(&opts, "prod", "node-b", 101, &reply, 1_000).unwrap();
    assert_eq!(life1.keys, genesis.keys);

    // crash: the registry retires the member; its incarnation is spent
    assert!(reg.retire("prod", "node-b"));

    // respawn: the rejoin mints a strictly higher incarnation
    let req = join_request(&opts, "prod", "node-b", 102, 2_000);
    let reply = reg.handle_join(&req, 2_000);
    let life2 = accept_grant(&opts, "prod", "node-b", 102, &reply, 2_000).unwrap();
    assert!(
        life2.incarnation > life1.incarnation,
        "respawn must not recycle incarnation {} (got {})",
        life1.incarnation,
        life2.incarnation
    );

    // and therefore the blinding bands of the two lives are disjoint:
    // the highest domain of life 1 sits strictly below the lowest of
    // life 2 — no pad stream the first life spent can ever be re-keyed
    let hi1 = life1
        .keys
        .blind_domain(life1.incarnation, (TRACK_DOMAIN_STRIDE - 1) as usize);
    let lo2 = life2.keys.blind_domain(life2.incarnation, 0);
    assert!(hi1 < lo2, "pad bands overlap: {hi1} vs {lo2}");
}

// ── partition/heal replay: deterministic across seeds and cadences ──

fn partition_heal_config(seed: u64, tick_ms: f64) -> ClusterSimConfig {
    let mut cfg = ClusterSimConfig::three_node(seed);
    cfg.tick_ms = tick_ms;
    // cut node-c off alone mid-stream, heal before the horizon
    cfg.events.push(ClusterEvent {
        at_ms: 150.0,
        kind: ClusterEventKind::Partition {
            groups: vec![
                vec!["node-a".into(), "node-b".into()],
                vec!["node-c".into()],
            ],
        },
    });
    cfg.events.push(ClusterEvent {
        at_ms: 300.0,
        kind: ClusterEventKind::Heal,
    });
    cfg
}

#[test]
fn partition_heal_replay_is_identical_across_seeds() {
    // The rng stream feeds challenges and link delays, never routing:
    // the served/isolated ledger and the final routing state must be
    // bit-identical under different seeds.
    let a = replay_cluster(&partition_heal_config(2019, 20.0));
    let b = replay_cluster(&partition_heal_config(1, 20.0));
    assert!(a.served > 0, "the majority side keeps serving");
    assert!(
        a.isolated > 0,
        "sessions pinned to the minority side must surface as isolated"
    );
    assert_eq!(a.lost, 0, "a healed partition loses no compliant session");
    assert_eq!(a.joins_ok, 2);
    assert_eq!(
        (a.served, a.isolated, a.lost, a.digest),
        (b.served, b.isolated, b.lost, b.digest),
        "replay must not depend on the rng seed"
    );
}

#[test]
fn partition_heal_replay_is_identical_across_tick_cadences() {
    // Drain-on-touch means serving outcomes never depend on how often
    // the background tick runs: 20 ms, 7 ms and "never" must agree.
    let base = replay_cluster(&partition_heal_config(2019, 20.0));
    for tick_ms in [7.0, 0.0] {
        let other = replay_cluster(&partition_heal_config(2019, tick_ms));
        assert_eq!(
            (base.served, base.isolated, base.lost, base.digest),
            (other.served, other.isolated, other.lost, other.digest),
            "tick cadence {tick_ms} ms changed the replay outcome"
        );
    }
}

// ── forged join: zero key material, in the sim and on the registry ──

#[test]
fn forged_join_mints_zero_key_material() {
    let mut cfg = ClusterSimConfig::three_node(2019);
    cfg.nodes.push(SimNode::new("mallory", "prod").forged());
    cfg.events.push(ClusterEvent {
        at_ms: 20.0,
        kind: ClusterEventKind::Join { node: 3 },
    });
    let r = replay_cluster(&cfg);
    assert_eq!(r.joins_ok, 2, "the honest joiners still join");
    assert_eq!(r.joins_denied, 1, "the forged join is denied");
    assert!(
        !r.incarnations.contains_key("mallory"),
        "a denied join must leave no membership state: {:?}",
        r.incarnations
    );

    // same property straight on the registry: the deny frame carries a
    // reason and no grant, and no incarnation was burned for mallory
    let reg = TrackRegistry::new(7, TrackOptions::default());
    reg.claim("prod", "node-a");
    let forged = TrackOptions {
        measurement: origami::crypto::sha256(b"not-the-enclave"),
        ..TrackOptions::default()
    };
    let req = join_request(&forged, "prod", "mallory", 5, 100);
    let reply = reg.handle_join(&req, 100);
    match accept_grant(&forged, "prod", "mallory", 5, &reply, 100) {
        Err(TrackError::Denied(reason)) => {
            assert!(reason.contains("measurement"), "reason: {reason}")
        }
        other => panic!("expected a denial, got {other:?}"),
    }
    assert_eq!(reg.member_count("prod"), 1);
    assert_eq!(reg.incarnation_of("prod", "mallory"), None);
}

// ── live cluster router: kill mid-stream keeps the session serving ──

#[test]
fn node_kill_mid_stream_migrates_sessions_with_epoch_intact() {
    let router = ClusterRouter::new(ClusterOptions::default());
    router.add_node("n1", "prod", member_node());
    router.add_node("n2", "prod", member_node());
    router.add_node("n3", "prod", member_node());

    use origami::coordinator::Frontend;
    let grant = router.establish_session("m", [9u8; 32]);
    let home = router.pin_of(grant.session).expect("establish pins");

    // first request serves on the home node
    let r1 = router
        .submit("m", vec![0u8; 8], grant.session)
        .unwrap()
        .recv()
        .unwrap();
    assert!(r1.error.is_none(), "{:?}", r1.error);
    assert_eq!(r1.probs[0], grant.session as f32);
    let epoch_before = router.session_epoch(grant.session).unwrap();

    // kill the home node mid-stream: the session must migrate to a
    // same-track sibling with its state intact
    let moved = router.kill(&home);
    assert!(moved >= 1, "the pinned session must be migrated");
    let sibling = router.pin_of(grant.session).expect("still pinned");
    assert_ne!(sibling, home, "the pin left the dead node");

    let r2 = router
        .submit("m", vec![0u8; 8], grant.session)
        .unwrap()
        .recv()
        .unwrap();
    assert!(r2.error.is_none(), "{:?}", r2.error);
    assert_eq!(r2.probs[0], grant.session as f32, "the reply stream continues");
    assert_eq!(
        router.session_epoch(grant.session).unwrap(),
        epoch_before,
        "migration must not advance the client's keystream epoch"
    );

    let names = router.shutdown();
    assert_eq!(names.len(), 2, "the killed member was dropped");
}

// ── sweeper regression: TTL reaping must not ride the autoscaler ────

#[test]
fn expired_sessions_are_reaped_with_autoscaling_off() {
    // 30 ms TTL, 5 ms sweep cadence, and — critically — no autoscaler
    // pump: the builder starts none, and this test never calls
    // `autoscale_tick`.  Before the dedicated sweeper existed, expired
    // sessions leaked forever in exactly this configuration.
    let dep = Deployment::builder(FabricOptions::default())
        .sessions(SessionTable::with_capacity(4, 30, 0))
        .sweep_every_ms(5)
        .build();
    let grant = dep.establish_session("m", [7u8; 32]);
    assert!(dep.sessions().contains(grant.session));

    let mut reaped = false;
    for _ in 0..400 {
        if dep.sessions().is_empty() {
            reaped = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(
        reaped,
        "the sweeper must reap expired sessions without autoscaler ticks"
    );
    dep.shutdown();

    // control: with the sweeper disabled and no ticks, the expired
    // entry sits in the table — the reaping above really was the
    // sweeper's doing, not some other path
    let dep = Deployment::builder(FabricOptions::default())
        .sessions(SessionTable::with_capacity(4, 30, 0))
        .sweep_every_ms(0)
        .build();
    dep.establish_session("m", [7u8; 32]);
    std::thread::sleep(std::time::Duration::from_millis(80));
    assert_eq!(
        dep.sessions().len(),
        1,
        "no sweeper, no ticks: nothing reaps (the control for the test above)"
    );
    dep.shutdown();
}
