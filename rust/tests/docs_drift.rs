//! Documentation drift gates: docs/CONFIG.md must cover every config
//! field and flag the parser accepts, docs/ARCHITECTURE.md and the
//! README must stay wired together.  Pure text assertions — they run
//! in the ordinary test leg, so a new knob cannot ship undocumented.

use origami::config::{Config, SPEC_SUFFIX_KEYS};
use origami::util::json::Value;

fn repo_file(rel: &str) -> String {
    let path = format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

#[test]
fn config_md_documents_every_field_flag_and_spec_suffix() {
    let doc = repo_file("docs/CONFIG.md");

    // every serialized Config field appears as a `key` in the doc
    let Value::Obj(fields) = Config::default().to_json() else {
        panic!("config serializes to an object");
    };
    for (key, _) in &fields {
        assert!(
            doc.contains(&format!("`{key}`")),
            "docs/CONFIG.md is missing config field `{key}`"
        );
    }

    // every CLI flag in the generated help table appears — at a word
    // boundary, so `--lanes` is not satisfied by `--min-lanes` and
    // `--autoscale` is not satisfied by `--autoscale-policy`
    let has_flag = |flag: &str| {
        doc.match_indices(flag).any(|(i, _)| {
            doc[i + flag.len()..]
                .chars()
                .next()
                .map(|c| !(c.is_ascii_alphanumeric() || c == '-'))
                .unwrap_or(true)
        })
    };
    for flag_doc in Config::flag_docs() {
        if !flag_doc.flag.is_empty() {
            assert!(
                has_flag(flag_doc.flag),
                "docs/CONFIG.md is missing flag `{}`",
                flag_doc.flag
            );
        }
    }

    // every ModelSpec suffix key appears in its `:key=` form
    for key in SPEC_SUFFIX_KEYS {
        assert!(
            doc.contains(&format!(":{key}=")),
            "docs/CONFIG.md is missing ModelSpec suffix `:{key}=`"
        );
    }
}

#[test]
fn architecture_md_maps_every_coordinator_module() {
    let doc = repo_file("docs/ARCHITECTURE.md");
    let dir = format!("{}/src/coordinator", env!("CARGO_MANIFEST_DIR"));
    for entry in std::fs::read_dir(&dir).expect("coordinator dir") {
        let name = entry.unwrap().file_name().into_string().unwrap();
        if name == "mod.rs" || !name.ends_with(".rs") {
            continue;
        }
        assert!(
            doc.contains(&name),
            "docs/ARCHITECTURE.md module map is missing `{name}`"
        );
    }
    for anchor in ["EPC ledger", "request lifecycle", "blinding boundary"] {
        assert!(
            doc.to_lowercase().contains(&anchor.to_lowercase()),
            "docs/ARCHITECTURE.md lost its `{anchor}` section"
        );
    }
}

#[test]
fn readme_links_docs_and_renders_every_figure() {
    let readme = repo_file("README.md");
    for link in ["docs/ARCHITECTURE.md", "docs/CONFIG.md"] {
        assert!(readme.contains(link), "README is missing a link to {link}");
    }
    // the Results section covers every serving figure
    assert!(readme.contains("## Results"), "README lost its Results section");
    for fig in [
        "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20", "fig21", "fig22",
        "fig23",
    ] {
        assert!(
            readme.contains(fig),
            "README Results must interpret {fig}"
        );
    }
}
