//! Multi-tenant fabric integration: two models deployed over one shared
//! tier-2 lane fabric must produce outputs bit-identical to each model's
//! serial path, admission failures must be typed (and synchronous — no
//! hangs), and the queue-depth autoscaler must demonstrably grow and
//! shrink both tier-1 worker counts and the fabric's lane count.
//!
//! Runs hermetically on the pure-Rust reference backend (`sim8`/`sim16`)
//! — no artifacts, no PJRT — so it executes in every CI environment.

use origami::config::Config;
use origami::coordinator::{AdmissionError, AutoscalePolicy, Deployment};
use origami::enclave::cost::Ledger;
use origami::launcher::{
    autoscale_policy_from_config, build_strategy_with, deploy_from_config, encrypt_request,
    executor_for, fabric_options_from_config, start_deployment_from_config, synth_images,
};

fn sim_config(model: &str, workers: usize) -> Config {
    Config {
        model: model.into(),
        strategy: "origami/6".into(),
        workers,
        max_batch: 4,
        max_delay_ms: 2.0,
        pool_epochs: 32,
        pipeline: true,
        ..Config::default()
    }
}

/// Serial reference: one strategy instance, batch-1 requests in order.
fn serial_outputs(cfg: &Config, images: &[Vec<f32>], sessions: &[u64]) -> Vec<Vec<f32>> {
    let (executor, model) = executor_for(cfg).expect("reference stack");
    let mut strategy = build_strategy_with(executor, model, cfg).expect("strategy");
    images
        .iter()
        .zip(sessions)
        .map(|(img, &session)| {
            let ct = encrypt_request(cfg, session, img);
            strategy
                .infer(&ct, 1, &[session], &mut Ledger::new())
                .expect("serial inference")
        })
        .collect()
}

#[test]
fn two_models_on_shared_fabric_bit_identical_to_serial() {
    let cfg_a = sim_config("sim8", 2);
    let cfg_b = sim_config("sim16", 2);
    // disjoint session id spaces (a session binds to one model)
    let sessions_a: Vec<u64> = (0..16).map(|i| 2 * i).collect();
    let sessions_b: Vec<u64> = (0..8).map(|i| 2 * i + 1).collect();
    let images_a = synth_images(sessions_a.len(), 8, 3, cfg_a.seed);
    let images_b = synth_images(sessions_b.len(), 16, 3, cfg_b.seed);
    let expected_a = serial_outputs(&cfg_a, &images_a, &sessions_a);
    let expected_b = serial_outputs(&cfg_b, &images_b, &sessions_b);

    // shared fabric with a mixed cpu/gpu lane cycle: device-aware lanes
    // change cost accounting, never bits
    let mut base = cfg_a.clone();
    base.lanes = 3;
    base.lane_devices = "cpu,gpu".into();
    let dep = Deployment::new(
        fabric_options_from_config(&base).unwrap(),
        AutoscalePolicy::default(),
    );
    deploy_from_config(&dep, &cfg_a, 2.0).unwrap();
    deploy_from_config(&dep, &cfg_b, 1.0).unwrap();
    assert_eq!(dep.models(), vec!["sim16".to_string(), "sim8".to_string()]);

    // interleave submissions across the two tenants
    let mut replies_a = Vec::new();
    let mut replies_b = Vec::new();
    for i in 0..sessions_a.len().max(sessions_b.len()) {
        if i < sessions_a.len() {
            let ct = encrypt_request(&cfg_a, sessions_a[i], &images_a[i]);
            replies_a.push(dep.submit("sim8", ct, sessions_a[i]).expect("submit a"));
        }
        if i < sessions_b.len() {
            let ct = encrypt_request(&cfg_b, sessions_b[i], &images_b[i]);
            replies_b.push(dep.submit("sim16", ct, sessions_b[i]).expect("submit b"));
        }
    }
    for (i, r) in replies_a.into_iter().enumerate() {
        let resp = r.recv().expect("reply a");
        assert!(resp.error.is_none(), "sim8 req {i}: {:?}", resp.error);
        assert_eq!(resp.probs, expected_a[i], "sim8 request {i} diverged");
    }
    for (i, r) in replies_b.into_iter().enumerate() {
        let resp = r.recv().expect("reply b");
        assert!(resp.error.is_none(), "sim16 req {i}: {:?}", resp.error);
        assert_eq!(resp.probs, expected_b[i], "sim16 request {i} diverged");
    }

    let m = dep.shutdown();
    let a = m.fabric.tenants.get("sim8").expect("sim8 tenant stats");
    let b = m.fabric.tenants.get("sim16").expect("sim16 tenant stats");
    assert_eq!(a.requests, 16);
    assert_eq!(b.requests, 8);
    assert_eq!(a.errors + b.errors, 0);
    assert!(
        m.fabric.makespan_ms() > 0.0,
        "fabric lanes actually ran tier-2 tails: {:?}",
        m.fabric.lane_sim_ms
    );
    assert_eq!(
        a.batches + b.batches,
        m.fabric.lane_batches.iter().sum::<u64>(),
        "every tail batch is accounted to exactly one lane"
    );
    // per-model tier-1 pools did their own enclave work
    for name in ["sim8", "sim16"] {
        let pm = m.models.get(name).expect("pool metrics");
        assert!(pm.tier1_sim_ms.iter().sum::<f64>() > 0.0, "{name} tier-1 idle");
        assert!(pm.affinity_held(), "{name} affinity violated at fixed size");
    }
}

#[test]
fn admission_failures_are_typed_and_synchronous() {
    let cfg = sim_config("sim8", 1);
    let dep = Deployment::new(
        fabric_options_from_config(&cfg).unwrap(),
        AutoscalePolicy::default(),
    );
    deploy_from_config(&dep, &cfg, 1.0).unwrap();
    let cfg_b = sim_config("sim16", 1);
    deploy_from_config(&dep, &cfg_b, 1.0).unwrap();

    let img = &synth_images(1, 8, 3, cfg.seed)[0];
    let good_ct = encrypt_request(&cfg, 7, img);
    let sample_bytes = good_ct.len();
    assert_eq!(sample_bytes, 4 * 8 * 8 * 3);

    // unknown model
    match dep.submit("vgg99", good_ct.clone(), 1).unwrap_err() {
        AdmissionError::UnknownModel { model, known } => {
            assert_eq!(model, "vgg99");
            assert_eq!(known, vec!["sim16".to_string(), "sim8".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // wrong-size ciphertext
    match dep.submit("sim8", vec![0u8; 5], 1).unwrap_err() {
        AdmissionError::WrongSize {
            model,
            expected,
            got,
        } => {
            assert_eq!(model, "sim8");
            assert_eq!(expected, sample_bytes);
            assert_eq!(got, 5);
        }
        other => panic!("expected WrongSize, got {other:?}"),
    }

    // a successful request binds its session to sim8…
    let reply = dep.submit("sim8", good_ct, 7).expect("well-formed request");
    let resp = reply.recv().expect("reply");
    assert!(resp.error.is_none(), "{:?}", resp.error);

    // …so reusing session 7 against sim16 is a typed collision
    let img16 = &synth_images(1, 16, 3, cfg_b.seed)[0];
    let ct16 = encrypt_request(&cfg_b, 7, img16);
    match dep.submit("sim16", ct16.clone(), 7).unwrap_err() {
        AdmissionError::SessionCollision {
            session,
            bound,
            requested,
        } => {
            assert_eq!(session, 7);
            assert_eq!(bound, "sim8");
            assert_eq!(requested, "sim16");
        }
        other => panic!("expected SessionCollision, got {other:?}"),
    }
    // a fresh session id serves fine
    let reply = dep.submit("sim16", ct16, 8).expect("fresh session admitted");
    assert!(reply.recv().expect("reply").error.is_none());

    let m = dep.shutdown();
    assert_eq!(m.fabric.errors, 0, "rejections never reached the fabric");
}

#[test]
fn autoscaler_grows_and_shrinks_workers_and_lanes() {
    // Deterministic drive: ticks are issued manually against observed
    // queue depth (the background pump runs the same code on a timer).
    let mut cfg = sim_config("sim8", 1);
    cfg.min_workers = 1;
    cfg.max_workers = 4;
    cfg.lanes = 1;
    cfg.min_lanes = 1;
    cfg.max_lanes = 4;
    cfg.autoscale_high_depth = 2;
    cfg.autoscale_low_depth = 1;

    let dep = Deployment::new(
        fabric_options_from_config(&cfg).unwrap(),
        autoscale_policy_from_config(&cfg),
    );
    deploy_from_config(&dep, &cfg, 1.0).unwrap();
    assert_eq!(dep.active_workers("sim8"), 1);
    assert_eq!(dep.lane_count(), 1);

    // burst: far more requests than one worker drains instantly
    let n = 96u64;
    let images = synth_images(n as usize, 8, 3, cfg.seed);
    let replies: Vec<_> = (0..n)
        .map(|s| {
            let ct = encrypt_request(&cfg, s, &images[s as usize]);
            dep.submit("sim8", ct, s).expect("submit")
        })
        .collect();

    // tick until the backlog forces growth (bounded retries: the queue
    // is deep enough that the first ticks already see depth ≫ high)
    let mut grew_workers = false;
    let mut grew_lanes = false;
    for _ in 0..200 {
        dep.autoscale_tick();
        grew_workers |= dep.active_workers("sim8") > 1;
        grew_lanes |= dep.lane_count() > 1;
        if grew_workers && grew_lanes {
            break;
        }
        if dep.queue_depth() == 0 {
            break; // drained before we saw growth — would be a failure
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(grew_workers, "queue pressure must grow tier-1 workers");
    assert!(grew_lanes, "queue pressure must grow fabric lanes");

    for (i, r) in replies.into_iter().enumerate() {
        let resp = r.recv().expect("reply");
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
    }

    // drained: repeated ticks must shrink both back to their floors
    for _ in 0..8 {
        dep.autoscale_tick();
    }
    assert_eq!(dep.queue_depth(), 0);
    assert_eq!(dep.active_workers("sim8"), 1, "workers shrink to min");
    assert_eq!(dep.lane_count(), 1, "lanes shrink to min");

    let m = dep.shutdown();
    assert_eq!(m.fabric.tenants["sim8"].requests, n);
    assert_eq!(m.fabric.tenants["sim8"].errors, 0);
    let pm = &m.models["sim8"];
    assert!(pm.grow_events >= 1 && pm.shrink_events >= 1);
    assert!(pm.peak_workers > 1);
    assert!(m.fabric.grow_events >= 1 && m.fabric.shrink_events >= 1);
    assert!(m.fabric.peak_lanes > 1);
}

#[test]
fn background_autoscaler_runs_and_shuts_down_cleanly() {
    // The pump variant of the test above: start via the launcher with
    // autoscale enabled, serve a burst, and make sure shutdown is clean
    // (the pump must never deadlock shutdown).
    let mut base = sim_config("sim8", 1);
    base.models = "sim8=origami/6*2,sim16=slalom".into();
    base.min_workers = 1;
    base.max_workers = 3;
    base.lanes = 1;
    base.min_lanes = 1;
    base.max_lanes = 3;
    base.autoscale = true;
    base.autoscale_tick_ms = 2;

    let specs = origami::config::ModelSpec::parse_list(&base.models).unwrap();
    let dep = start_deployment_from_config(&base, &specs).unwrap();
    let images = synth_images(24, 8, 3, base.seed);
    let replies: Vec<_> = (0..24u64)
        .map(|s| {
            let ct = encrypt_request(&sim_config("sim8", 1), s, &images[s as usize]);
            dep.submit("sim8", ct, s).expect("submit")
        })
        .collect();
    for r in replies {
        assert!(r.recv().expect("reply").error.is_none());
    }
    let m = dep.shutdown();
    assert_eq!(m.fabric.tenants["sim8"].requests, 24);
    assert!(m.models.contains_key("sim16"), "idle tenant still registered");
}
