//! Multi-tenant fabric integration: two models deployed over one shared
//! tier-2 lane fabric must produce outputs bit-identical to each model's
//! serial path, admission failures must be typed (and synchronous — no
//! hangs), and the autoscaler must demonstrably grow and shrink both
//! tier-1 worker counts and the fabric's lane count.
//!
//! Workloads, interleaved submission orders and bit-equality checks come
//! from the deterministic serving-simulation harness
//! (`tests/common/sim.rs`) instead of ad-hoc replay loops.
//!
//! Runs hermetically on the pure-Rust reference backend (`sim8`/`sim16`)
//! — no artifacts, no PJRT — so it executes in every CI environment.

mod common;

use common::sim::{assert_replies, drive_deployment, submit_interleaved, tenant_load};
use origami::config::Config;
use origami::coordinator::{AdmissionError, Deployment};
use origami::launcher::{
    autoscale_policy_from_config, deploy_from_config, fabric_options_from_config,
    start_deployment_from_config,
};

fn sim_config(model: &str, workers: usize) -> Config {
    Config {
        model: model.into(),
        strategy: "origami/6".into(),
        workers,
        max_batch: 4,
        max_delay_ms: 2.0,
        pool_epochs: 32,
        pipeline: true,
        ..Config::default()
    }
}

#[test]
fn two_models_on_shared_fabric_bit_identical_to_serial() {
    // disjoint session id spaces (a session binds to one model)
    let load_a = tenant_load(sim_config("sim8", 2), 16, 0, 2);
    let load_b = tenant_load(sim_config("sim16", 2), 8, 1, 2);

    // shared fabric with a mixed cpu/gpu lane cycle: device-aware lanes
    // change cost accounting, never bits
    let mut base = load_a.cfg.clone();
    base.lanes = 3;
    base.lane_devices = "cpu,gpu".into();
    let dep = Deployment::builder(fabric_options_from_config(&base).unwrap()).build();
    deploy_from_config(&dep, &load_a.cfg, 2.0).unwrap();
    deploy_from_config(&dep, &load_b.cfg, 1.0).unwrap();
    assert_eq!(dep.models(), vec!["sim16".to_string(), "sim8".to_string()]);

    // interleave submissions across the two tenants; every reply is
    // checked bit-identical to its model's serial path
    drive_deployment(&dep, &[&load_a, &load_b]);

    let m = dep.shutdown();
    let a = m.fabric.tenants.get("sim8").expect("sim8 tenant stats");
    let b = m.fabric.tenants.get("sim16").expect("sim16 tenant stats");
    assert_eq!(a.requests, 16);
    assert_eq!(b.requests, 8);
    assert_eq!(a.errors + b.errors, 0);
    assert!(
        m.fabric.makespan_ms() > 0.0,
        "fabric lanes actually ran tier-2 tails: {:?}",
        m.fabric.lane_sim_ms
    );
    assert_eq!(
        a.batches + b.batches,
        m.fabric.lane_batches.iter().sum::<u64>(),
        "every tail batch is accounted to exactly one lane"
    );
    // per-model tier-1 pools did their own enclave work
    for name in ["sim8", "sim16"] {
        let pm = m.models.get(name).expect("pool metrics");
        assert!(pm.tier1_sim_ms.iter().sum::<f64>() > 0.0, "{name} tier-1 idle");
        assert!(pm.affinity_held(), "{name} affinity violated at fixed size");
    }
}

#[test]
fn admission_failures_are_typed_and_synchronous() {
    let load_a = tenant_load(sim_config("sim8", 1), 1, 7, 1);
    let load_b = tenant_load(sim_config("sim16", 1), 1, 8, 1);
    let dep = Deployment::builder(fabric_options_from_config(&load_a.cfg).unwrap()).build();
    deploy_from_config(&dep, &load_a.cfg, 1.0).unwrap();
    deploy_from_config(&dep, &load_b.cfg, 1.0).unwrap();

    let good_ct = load_a.ciphertext(0);
    let sample_bytes = good_ct.len();
    assert_eq!(sample_bytes, 4 * 8 * 8 * 3);

    // unknown model
    match dep.submit("vgg99", good_ct.clone(), 1).unwrap_err() {
        AdmissionError::UnknownModel { model, known } => {
            assert_eq!(model, "vgg99");
            assert_eq!(known, vec!["sim16".to_string(), "sim8".to_string()]);
        }
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // wrong-size ciphertext
    match dep.submit("sim8", vec![0u8; 5], 1).unwrap_err() {
        AdmissionError::WrongSize {
            model,
            expected,
            got,
        } => {
            assert_eq!(model, "sim8");
            assert_eq!(expected, sample_bytes);
            assert_eq!(got, 5);
        }
        other => panic!("expected WrongSize, got {other:?}"),
    }

    // a successful request binds its session (7) to sim8…
    drive_deployment(&dep, &[&load_a]);

    // …so reusing session 7 against sim16 is a typed collision
    let ct16 = origami::launcher::encrypt_request(&load_b.cfg, 7, &load_b.images[0]);
    match dep.submit("sim16", ct16, 7).unwrap_err() {
        AdmissionError::SessionCollision {
            session,
            bound,
            requested,
        } => {
            assert_eq!(session, 7);
            assert_eq!(bound, "sim8");
            assert_eq!(requested, "sim16");
        }
        other => panic!("expected SessionCollision, got {other:?}"),
    }
    // a fresh session id serves fine (and bit-identically)
    drive_deployment(&dep, &[&load_b]);

    let m = dep.shutdown();
    assert_eq!(m.fabric.errors, 0, "rejections never reached the fabric");
}

#[test]
fn autoscaler_grows_and_shrinks_workers_and_lanes() {
    // Deterministic drive: ticks are issued manually against observed
    // queue depth (the background pump runs the same code on a timer).
    let mut cfg = sim_config("sim8", 1);
    cfg.min_workers = 1;
    cfg.max_workers = 4;
    cfg.lanes = 1;
    cfg.min_lanes = 1;
    cfg.max_lanes = 4;
    cfg.autoscale_high_depth = 2;
    cfg.autoscale_low_depth = 1;

    let dep = Deployment::builder(fabric_options_from_config(&cfg).unwrap())
        .policy(autoscale_policy_from_config(&cfg))
        .build();
    deploy_from_config(&dep, &cfg, 1.0).unwrap();
    assert_eq!(dep.active_workers("sim8"), 1);
    assert_eq!(dep.lane_count(), 1);

    // burst: far more requests than one worker drains instantly
    let load = tenant_load(cfg, 96, 0, 1);
    let pending = submit_interleaved(&dep, &[&load]);

    // tick until the backlog forces growth (bounded retries: the queue
    // is deep enough that the first ticks already see depth ≫ high)
    let mut grew_workers = false;
    let mut grew_lanes = false;
    for _ in 0..200 {
        dep.autoscale_tick();
        grew_workers |= dep.active_workers("sim8") > 1;
        grew_lanes |= dep.lane_count() > 1;
        if grew_workers && grew_lanes {
            break;
        }
        if dep.queue_depth() == 0 {
            break; // drained before we saw growth — would be a failure
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(grew_workers, "queue pressure must grow tier-1 workers");
    assert!(grew_lanes, "queue pressure must grow fabric lanes");

    assert_replies(pending, &[&load]);

    // drained: repeated ticks must shrink both back to their floors
    // (cooldown hysteresis holds each target for `cooldown` ticks
    // between events, so budget ticks accordingly)
    for _ in 0..16 {
        dep.autoscale_tick();
    }
    assert_eq!(dep.queue_depth(), 0);
    assert_eq!(dep.active_workers("sim8"), 1, "workers shrink to min");
    assert_eq!(dep.lane_count(), 1, "lanes shrink to min");

    let m = dep.shutdown();
    assert_eq!(m.fabric.tenants["sim8"].requests, 96);
    assert_eq!(m.fabric.tenants["sim8"].errors, 0);
    let pm = &m.models["sim8"];
    assert!(pm.grow_events >= 1 && pm.shrink_events >= 1);
    assert!(pm.peak_workers > 1);
    assert!(m.fabric.grow_events >= 1 && m.fabric.shrink_events >= 1);
    assert!(m.fabric.peak_lanes > 1);
}

#[test]
fn background_autoscaler_runs_and_shuts_down_cleanly() {
    // The pump variant of the test above: start via the launcher with
    // autoscale enabled, serve a burst, and make sure shutdown is clean
    // (the pump must never deadlock shutdown).
    let mut base = sim_config("sim8", 1);
    base.models = "sim8=origami/6*2,sim16=slalom".into();
    base.min_workers = 1;
    base.max_workers = 3;
    base.lanes = 1;
    base.min_lanes = 1;
    base.max_lanes = 3;
    base.autoscale = true;
    base.autoscale_tick_ms = 2;

    let specs = origami::config::ModelSpec::parse_list(&base.models).unwrap();
    let dep = start_deployment_from_config(&base, &specs).unwrap();
    let load = tenant_load(sim_config("sim8", 1), 24, 0, 1);
    drive_deployment(&dep, &[&load]);
    let m = dep.shutdown();
    assert_eq!(m.fabric.tenants["sim8"].requests, 24);
    assert!(m.models.contains_key("sim16"), "idle tenant still registered");
}
