//! Runtime integration: AOT artifacts load, compile, execute, and match
//! the golden vectors JAX computed at export time — the core proof that
//! the Python→HLO→Rust bridge is numerically sound.

mod common;

use common::{golden, max_abs_diff, test_stack};
use origami::enclave::cost::{Cat, Ledger};
use origami::runtime::Device;

#[test]
fn full_open_matches_golden_logits() {
    let Some((stack, _)) = test_stack() else { return };
    let Some(g) = golden("vgg16-32") else { return };
    let mut ledger = Ledger::new();
    let out = stack
        .executor
        .run("vgg16-32", "full_open", 1, &[&g.input], Device::UntrustedCpu, &mut ledger)
        .expect("full_open executes");
    assert_eq!(out.data.len(), g.logits.len());
    let diff = max_abs_diff(&out.data, &g.logits);
    assert!(diff < 1e-4, "golden mismatch: {diff}");
    assert!(ledger.measured_ns(Cat::DeviceCompute) > 0);
}

#[test]
fn vgg19_golden_matches_too() {
    let Some((stack, _)) = test_stack() else { return };
    let Some(g) = golden("vgg19-32") else { return };
    let mut ledger = Ledger::new();
    let out = stack
        .executor
        .run("vgg19-32", "full_open", 1, &[&g.input], Device::UntrustedCpu, &mut ledger)
        .expect("executes");
    assert!(max_abs_diff(&out.data, &g.logits) < 1e-4);
}

#[test]
fn head_plus_tail_compose_to_full() {
    let Some((stack, _)) = test_stack() else { return };
    let Some(g) = golden("vgg16-32") else { return };
    let mut ledger = Ledger::new();
    let p = 6;
    let head = stack
        .executor
        .run("vgg16-32", "head_p06", 1, &[&g.input], Device::UntrustedCpu, &mut ledger)
        .unwrap();
    let tail = stack
        .executor
        .run("vgg16-32", &format!("tail_p{p:02}"), 1, &[&head.data], Device::UntrustedCpu, &mut ledger)
        .unwrap();
    assert!(max_abs_diff(&tail.data, &g.logits) < 1e-4);
}

#[test]
fn batched_artifact_runs_and_broadcasts() {
    let Some((stack, _)) = test_stack() else { return };
    let Some(g) = golden("vgg16-32") else { return };
    // tile the golden input 8x; every row must produce the same logits
    let mut batch_in = Vec::with_capacity(8 * g.input.len());
    for _ in 0..8 {
        batch_in.extend_from_slice(&g.input);
    }
    let mut ledger = Ledger::new();
    let out = stack
        .executor
        .run("vgg16-32", "full_open", 8, &[&batch_in], Device::UntrustedCpu, &mut ledger)
        .unwrap();
    assert_eq!(out.data.len(), 8 * g.logits.len());
    for i in 0..8 {
        let row = &out.data[i * g.logits.len()..(i + 1) * g.logits.len()];
        assert!(max_abs_diff(row, &g.logits) < 1e-4, "row {i}");
    }
}

#[test]
fn executor_rejects_wrong_shapes() {
    let Some((stack, _)) = test_stack() else { return };
    let mut ledger = Ledger::new();
    let bad = vec![0f32; 10];
    assert!(stack
        .executor
        .run("vgg16-32", "full_open", 1, &[&bad], Device::UntrustedCpu, &mut ledger)
        .is_err());
    assert!(stack
        .executor
        .run("vgg16-32", "nonexistent_stage", 1, &[&bad], Device::UntrustedCpu, &mut ledger)
        .is_err());
    assert!(stack
        .executor
        .run("no-such-model", "full_open", 1, &[&bad], Device::UntrustedCpu, &mut ledger)
        .is_err());
}

#[test]
fn registry_caches_compilations() {
    let Some((stack, _)) = test_stack() else { return };
    let before = stack.registry.cached_count();
    let _ = stack.registry.get("vgg16-32", "layer01_lin_open", 1).unwrap();
    let after_first = stack.registry.cached_count();
    let _ = stack.registry.get("vgg16-32", "layer01_lin_open", 1).unwrap();
    assert_eq!(stack.registry.cached_count(), after_first);
    assert!(after_first > before);
}

#[test]
fn gpu_device_models_time_cpu_measures_it() {
    let Some((stack, _)) = test_stack() else { return };
    let Some(g) = golden("vgg16-32") else { return };
    let mut cpu_ledger = Ledger::new();
    let mut gpu_ledger = Ledger::new();
    // warm first so compile time doesn't skew
    for _ in 0..2 {
        let _ = stack
            .executor
            .run("vgg16-32", "full_open", 1, &[&g.input], Device::UntrustedCpu, &mut Ledger::new())
            .unwrap();
    }
    let cpu_out = stack
        .executor
        .run("vgg16-32", "full_open", 1, &[&g.input], Device::UntrustedCpu, &mut cpu_ledger)
        .unwrap();
    let gpu_out = stack
        .executor
        .run("vgg16-32", "full_open", 1, &[&g.input], Device::Gpu, &mut gpu_ledger)
        .unwrap();
    // same numerics either way (GPU is a cost model, not different math)
    assert!(max_abs_diff(&cpu_out.data, &gpu_out.data) < 1e-6);
    assert_eq!(gpu_ledger.measured_ns(Cat::DeviceCompute), 0);
    assert!(gpu_ledger.modeled_ns(Cat::DeviceCompute) > 0);
    assert!(cpu_ledger.measured_ns(Cat::DeviceCompute) > 0);
    // modeled GPU time must be well under measured CPU time
    assert!(
        gpu_ledger.modeled_ns(Cat::DeviceCompute) < cpu_ledger.measured_ns(Cat::DeviceCompute),
        "gpu {} vs cpu {}",
        gpu_ledger.modeled_ns(Cat::DeviceCompute),
        cpu_ledger.measured_ns(Cat::DeviceCompute)
    );
}
