//! Offline stand-in for the `hmac` crate: RFC 2104 HMAC-SHA256 behind the
//! RustCrypto [`Mac`] trait subset (`new_from_slice` / `update` /
//! `finalize().into_bytes()`).  Pinned by RFC 4231 test vectors below.

use sha2::{Digest, Sha256};

/// Message-authentication-code interface (RustCrypto-compatible subset).
pub trait Mac: Sized {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    fn update(&mut self, data: &[u8]);
    fn finalize(self) -> Output;
}

/// Key-length error (HMAC accepts any length; kept for API parity).
#[derive(Debug)]
pub struct InvalidLength;

impl std::fmt::Display for InvalidLength {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("invalid key length")
    }
}

impl std::error::Error for InvalidLength {}

/// Finalized tag wrapper.
pub struct Output {
    tag: [u8; 32],
}

impl Output {
    pub fn into_bytes(self) -> [u8; 32] {
        self.tag
    }
}

const BLOCK: usize = 64;

/// HMAC over a digest; only `Hmac<Sha256>` is instantiated here.
pub struct Hmac<D> {
    inner: D,
    opad_key: [u8; BLOCK],
}

impl Mac for Hmac<Sha256> {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength> {
        let mut block_key = [0u8; BLOCK];
        if key.len() > BLOCK {
            let mut h = Sha256::new();
            h.update(key);
            block_key[..32].copy_from_slice(&h.finalize());
        } else {
            block_key[..key.len()].copy_from_slice(key);
        }
        let mut ipad = [0u8; BLOCK];
        let mut opad = [0u8; BLOCK];
        for i in 0..BLOCK {
            ipad[i] = block_key[i] ^ 0x36;
            opad[i] = block_key[i] ^ 0x5c;
        }
        let mut inner = Sha256::new();
        Digest::update(&mut inner, ipad);
        Ok(Self {
            inner,
            opad_key: opad,
        })
    }

    fn update(&mut self, data: &[u8]) {
        Digest::update(&mut self.inner, data);
    }

    fn finalize(self) -> Output {
        let inner_hash = self.inner.finalize();
        let mut outer = Sha256::new();
        Digest::update(&mut outer, self.opad_key);
        Digest::update(&mut outer, inner_hash);
        Output {
            tag: outer.finalize(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    fn hmac(key: &[u8], data: &[u8]) -> [u8; 32] {
        let mut m = <Hmac<Sha256> as Mac>::new_from_slice(key).unwrap();
        m.update(data);
        m.finalize().into_bytes()
    }

    /// RFC 4231 test case 1.
    #[test]
    fn rfc4231_case1() {
        let key = [0x0bu8; 20];
        assert_eq!(
            hex(&hmac(&key, b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    /// RFC 4231 test case 2 ("Jefe").
    #[test]
    fn rfc4231_case2() {
        assert_eq!(
            hex(&hmac(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    /// RFC 4231 test case 6: key longer than the block size.
    #[test]
    fn rfc4231_long_key() {
        let key = [0xaau8; 131];
        assert_eq!(
            hex(&hmac(&key, b"Test Using Larger Than Block-Size Key - Hash Key First")),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }
}
