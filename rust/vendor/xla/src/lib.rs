//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The execution environment for this workspace has no PJRT runtime, so
//! this stub keeps the artifact code paths *compiling* and failing with
//! an actionable error at the point where a real backend would execute.
//! Everything that runs in CI — unit tests, the pool integration tests,
//! the pool example/bench — goes through the pure-Rust reference backend
//! (`origami::runtime::reference`), which needs none of this.
//!
//! API parity notes: the shapes of `PjRtClient`, `PjRtLoadedExecutable`,
//! `Literal`, `HloModuleProto` and `XlaComputation` mirror the subset the
//! coordinator uses, so swapping the real crate back in is a one-line
//! Cargo change.

use std::fmt;
use std::path::Path;

/// Stub error: always a message.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} unavailable (offline build without PJRT) — \
         use the pure-Rust reference backend (see origami::runtime::reference)"
    ))
}

/// A parsed HLO module (text retained, never lowered here).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from a file; fails only on I/O.
    pub fn from_text_file(path: &Path) -> Result<Self, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {}: {e}", path.display())))?;
        Ok(Self { text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// A PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// The stub client constructs fine; only execution is unavailable.
    pub fn cpu() -> Result<Self, Error> {
        Ok(Self)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("HLO compilation"))
    }
}

/// A loaded executable handle (never produced by the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable invocation"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("buffer readback"))
    }
}

/// Element types a [`Literal`] can read back as.
pub trait Element: Sized + Copy {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// A host-side tensor literal.
#[derive(Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Self {
        Self {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    /// Reshape; errors when the element count changes.
    pub fn reshape(&self, dims: &[i64]) -> Result<Self, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Self {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Unwrap a single-element tuple literal (identity in the stub).
    pub fn to_tuple1(&self) -> Result<Self, Error> {
        Ok(self.clone())
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shapes() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn client_constructs_but_execution_is_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        assert_eq!(c.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: String::new(),
        });
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("reference backend"));
    }
}
