//! Offline stand-in for the `aes` crate: a real FIPS-197 AES-128
//! implementation behind the RustCrypto trait subset this workspace uses
//! ([`cipher::KeyInit`] / [`cipher::BlockEncrypt`]).  Table-free S-box
//! lookups but table-driven in the usual sense (a 256-byte S-box); this
//! is a simulator substrate, not a side-channel-hardened cipher.
//! Pinned by the FIPS-197 Appendix C known-answer test below.

pub mod cipher {
    /// 128-bit key wrapper (`(&[u8; 16]).into()` at call sites).
    pub struct Key(pub(crate) [u8; 16]);

    impl From<&[u8; 16]> for Key {
        fn from(k: &[u8; 16]) -> Self {
            Key(*k)
        }
    }

    impl From<[u8; 16]> for Key {
        fn from(k: [u8; 16]) -> Self {
            Key(k)
        }
    }

    /// One 16-byte block; derefs to `[u8; 16]` for iteration.
    pub struct Block(pub(crate) [u8; 16]);

    impl From<[u8; 16]> for Block {
        fn from(b: [u8; 16]) -> Self {
            Block(b)
        }
    }

    impl std::ops::Deref for Block {
        type Target = [u8; 16];
        fn deref(&self) -> &[u8; 16] {
            &self.0
        }
    }

    impl std::ops::DerefMut for Block {
        fn deref_mut(&mut self) -> &mut [u8; 16] {
            &mut self.0
        }
    }

    /// Construct a cipher from key material.
    pub trait KeyInit: Sized {
        fn new(key: Key) -> Self;
    }

    /// Encrypt a single block in place.
    pub trait BlockEncrypt {
        fn encrypt_block(&self, block: &mut Block);
    }
}

use cipher::{Block, BlockEncrypt, Key, KeyInit};

const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab,
    0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4,
    0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71,
    0xd8, 0x31, 0x15, 0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2,
    0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6,
    0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb,
    0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf, 0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45,
    0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8, 0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5,
    0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44,
    0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73, 0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a,
    0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49,
    0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d,
    0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08, 0xba, 0x78, 0x25,
    0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e,
    0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e, 0xe1,
    0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb,
    0x16,
];

const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

#[inline]
fn xtime(b: u8) -> u8 {
    (b << 1) ^ (((b >> 7) & 1) * 0x1b)
}

/// AES-128 with expanded round keys.
pub struct Aes128 {
    round_keys: [[u8; 16]; 11],
}

impl Aes128 {
    fn expand(key: &[u8; 16]) -> [[u8; 16]; 11] {
        let mut w = [[0u8; 4]; 44];
        for i in 0..4 {
            w[i].copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                t = [
                    SBOX[t[1] as usize] ^ RCON[i / 4 - 1],
                    SBOX[t[2] as usize],
                    SBOX[t[3] as usize],
                    SBOX[t[0] as usize],
                ];
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        let mut rk = [[0u8; 16]; 11];
        for (r, key) in rk.iter_mut().enumerate() {
            for c in 0..4 {
                key[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        rk
    }
}

impl KeyInit for Aes128 {
    fn new(key: Key) -> Self {
        Self {
            round_keys: Self::expand(&key.0),
        }
    }
}

impl BlockEncrypt for Aes128 {
    fn encrypt_block(&self, block: &mut Block) {
        let state = &mut block.0;
        add_round_key(state, &self.round_keys[0]);
        for round in 1..10 {
            sub_bytes(state);
            shift_rows(state);
            mix_columns(state);
            add_round_key(state, &self.round_keys[round]);
        }
        sub_bytes(state);
        shift_rows(state);
        add_round_key(state, &self.round_keys[10]);
    }
}

#[inline]
fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
    for (s, k) in state.iter_mut().zip(rk.iter()) {
        *s ^= k;
    }
}

#[inline]
fn sub_bytes(state: &mut [u8; 16]) {
    for s in state.iter_mut() {
        *s = SBOX[*s as usize];
    }
}

/// Row r (bytes r, r+4, r+8, r+12 in column-major order) rotates left r.
#[inline]
fn shift_rows(state: &mut [u8; 16]) {
    let s = *state;
    for r in 1..4 {
        for c in 0..4 {
            state[r + 4 * c] = s[r + 4 * ((c + r) % 4)];
        }
    }
}

#[inline]
fn mix_columns(state: &mut [u8; 16]) {
    for c in 0..4 {
        let col = [
            state[4 * c],
            state[4 * c + 1],
            state[4 * c + 2],
            state[4 * c + 3],
        ];
        let t = col[0] ^ col[1] ^ col[2] ^ col[3];
        state[4 * c] = col[0] ^ t ^ xtime(col[0] ^ col[1]);
        state[4 * c + 1] = col[1] ^ t ^ xtime(col[1] ^ col[2]);
        state[4 * c + 2] = col[2] ^ t ^ xtime(col[2] ^ col[3]);
        state[4 * c + 3] = col[3] ^ t ^ xtime(col[3] ^ col[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS-197 Appendix C.1 known-answer test.
    #[test]
    fn fips197_vector() {
        let key: [u8; 16] = [
            0x00, 0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09, 0x0a, 0x0b, 0x0c, 0x0d,
            0x0e, 0x0f,
        ];
        let plain: [u8; 16] = [
            0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77, 0x88, 0x99, 0xaa, 0xbb, 0xcc, 0xdd,
            0xee, 0xff,
        ];
        let want: [u8; 16] = [
            0x69, 0xc4, 0xe0, 0xd8, 0x6a, 0x7b, 0x04, 0x30, 0xd8, 0xcd, 0xb7, 0x80, 0x70, 0xb4,
            0xc5, 0x5a,
        ];
        let aes = Aes128::new((&key).into());
        let mut b: Block = plain.into();
        aes.encrypt_block(&mut b);
        assert_eq!(*b, want);
    }

    /// FIPS-197 Appendix B vector (different key/plaintext pair).
    #[test]
    fn fips197_appendix_b() {
        let key: [u8; 16] = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let plain: [u8; 16] = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        let want: [u8; 16] = [
            0x39, 0x25, 0x84, 0x1d, 0x02, 0xdc, 0x09, 0xfb, 0xdc, 0x11, 0x85, 0x97, 0x19, 0x6a,
            0x0b, 0x32,
        ];
        let aes = Aes128::new((&key).into());
        let mut b: Block = plain.into();
        aes.encrypt_block(&mut b);
        assert_eq!(*b, want);
    }

    #[test]
    fn deterministic_and_key_sensitive() {
        let aes1 = Aes128::new((&[1u8; 16]).into());
        let aes2 = Aes128::new((&[2u8; 16]).into());
        let mut a: Block = [0u8; 16].into();
        let mut b: Block = [0u8; 16].into();
        aes1.encrypt_block(&mut a);
        aes2.encrypt_block(&mut b);
        assert_ne!(*a, *b);
    }
}
