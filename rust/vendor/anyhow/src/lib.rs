//! Offline stand-in for the `anyhow` crate — the API subset this
//! workspace uses: [`Error`], [`Result`], the `anyhow!`/`bail!`/`ensure!`
//! macros and the [`Context`] extension trait.  Errors carry a context
//! chain; `{:#}` (alternate Display) prints `outer: inner: root`, like
//! upstream anyhow.

use std::fmt;

/// A boxed, context-carrying error.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error in an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message (no chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket conversion below coherent (same trick as
// upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("non-empty chain")
    }
}

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $msg))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_fail() -> Result<u32> {
        let n: u32 = "nope".parse()?;
        Ok(n)
    }

    #[test]
    fn conversion_and_context_chain() {
        let err = parse_fail().unwrap_err().context("loading config");
        assert_eq!(format!("{err}"), "loading config");
        let alt = format!("{err:#}");
        assert!(alt.starts_with("loading config: "), "{alt}");
        assert!(alt.contains("invalid digit"), "{alt}");
    }

    #[test]
    fn macros_work() {
        let e = anyhow!("x = {}", 5);
        assert_eq!(format!("{e}"), "x = 5");
        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails")
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", f(true).unwrap_err()), "always fails");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let err = none.with_context(|| "missing thing").unwrap_err();
        assert_eq!(format!("{err}"), "missing thing");
    }
}
