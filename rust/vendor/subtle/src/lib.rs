//! Offline stand-in for the `subtle` crate: the [`ConstantTimeEq`] /
//! [`Choice`] subset used for MAC-tag comparison.  The comparison
//! accumulates a byte-OR of differences and reduces once at the end, so
//! no data-dependent branch exists on the comparison path.

/// A boolean that was computed without data-dependent branches.
#[derive(Clone, Copy, Debug)]
pub struct Choice(u8);

impl Choice {
    pub fn unwrap_u8(&self) -> u8 {
        self.0
    }
}

impl From<Choice> for bool {
    fn from(c: Choice) -> bool {
        c.0 != 0
    }
}

/// Constant-time equality comparison.
pub trait ConstantTimeEq {
    fn ct_eq(&self, other: &Self) -> Choice;
}

impl ConstantTimeEq for [u8] {
    fn ct_eq(&self, other: &Self) -> Choice {
        if self.len() != other.len() {
            return Choice(0);
        }
        let mut diff = 0u8;
        for (a, b) in self.iter().zip(other.iter()) {
            diff |= a ^ b;
        }
        // reduce without branching on the value
        Choice(u8::from(diff == 0))
    }
}

impl<const N: usize> ConstantTimeEq for [u8; N] {
    fn ct_eq(&self, other: &Self) -> Choice {
        self[..].ct_eq(&other[..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_and_unequal() {
        let a = [1u8, 2, 3];
        let b = [1u8, 2, 3];
        let c = [1u8, 2, 4];
        assert!(bool::from(a.ct_eq(&b)));
        assert!(!bool::from(a.ct_eq(&c)));
        assert_eq!(a.ct_eq(&b).unwrap_u8(), 1);
    }

    #[test]
    fn slices_of_unequal_length_differ() {
        let a: &[u8] = &[1, 2, 3];
        let b: &[u8] = &[1, 2];
        assert!(!bool::from(a.ct_eq(b)));
    }
}
