//! Dynamic batcher: (max-batch, max-delay) request coalescing.
//!
//! Requests accumulate until either `max_batch` are waiting or the oldest
//! has waited `max_delay`; the batch then ships to a worker.  This is the
//! standard serving trade-off (throughput vs tail latency) and an
//! ablation bench sweeps both knobs.
//!
//! Two policy details matter under load:
//!
//! - The delay window is anchored at the *oldest queued request's
//!   submission time*, not at the moment the batcher happened to poll.
//!   When the ingress queue backs up, a request may already be older
//!   than `max_delay` by the time it is pulled; restarting the window
//!   then would add a full extra delay on top of its queueing time
//!   (starvation under sustained mixed load).
//! - An optional **occupancy probe** makes the flush tier-aware: when
//!   the downstream tier-2 lanes are starved (probe returns `true`),
//!   waiting out the delay window only creates a pipeline bubble, so the
//!   batcher ships what it has immediately.  When the lanes are busy the
//!   full window is used to form larger batches.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::api::InferRequest;
use crate::util::threadpool::Channel;

/// Signals that the downstream execution stage is idle and a partial
/// batch should flush now rather than wait out the delay window.
pub type FlushProbe = Arc<dyn Fn() -> bool + Send + Sync>;

/// Fraction of a latency SLO the batcher may spend coalescing: a pool
/// with an SLO caps its delay window at `SLO × this` (see
/// [`DynamicBatcher::with_deadline_cap`]), leaving the rest of the
/// budget for queueing and both execution tiers.
pub const SLO_WINDOW_FRACTION: f64 = 0.25;

/// How often the occupancy probe is re-sampled while waiting inside the
/// delay window (tier-2 can go idle mid-wait; a bubble should not last
/// longer than this).
const PROBE_INTERVAL: Duration = Duration::from_millis(1);

/// Pulls from the ingress queue and forms batches.
pub struct DynamicBatcher {
    ingress: Channel<InferRequest>,
    pub max_batch: usize,
    pub max_delay: Duration,
    flush_probe: Option<FlushProbe>,
}

impl DynamicBatcher {
    pub fn new(ingress: Channel<InferRequest>, max_batch: usize, max_delay_ms: f64) -> Self {
        Self {
            ingress,
            max_batch: max_batch.max(1),
            max_delay: Duration::from_secs_f64(max_delay_ms.max(0.0) / 1e3),
            flush_probe: None,
        }
    }

    /// Attach an occupancy probe (see module docs): `probe() == true`
    /// means downstream is starved and partial batches flush early.
    pub fn with_flush_probe(mut self, probe: FlushProbe) -> Self {
        self.flush_probe = Some(probe);
        self
    }

    /// SLO-aware window cap: clamp the delay window to `cap` so batch
    /// coalescing can consume at most a bounded share of a request's
    /// latency budget.  Since the window is anchored at the oldest
    /// request's submission time, this bounds the batching contribution
    /// to end-to-end latency at exactly `cap`.
    pub fn with_deadline_cap(mut self, cap: Duration) -> Self {
        if cap < self.max_delay {
            self.max_delay = cap;
        }
        self
    }

    pub fn ingress(&self) -> Channel<InferRequest> {
        self.ingress.clone()
    }

    /// Block for the next batch; None when the queue is closed and empty.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        // block for the first request
        let first = self.ingress.recv()?;
        // Delay window anchored at the oldest request's submission: a
        // request that already out-waited the window in the ingress
        // queue ships immediately instead of paying the window twice.
        let deadline = first.submitted_at + self.max_delay;
        let mut batch = vec![first];
        while batch.len() < self.max_batch {
            // Opportunistic drain costs no latency, so it happens even
            // past the deadline: under backlog the batcher still forms
            // full batches — only *waiting* is cut short.
            let more = self.ingress.drain_up_to(self.max_batch - batch.len());
            if !more.is_empty() {
                batch.extend(more);
                continue;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match &self.flush_probe {
                // nothing queued: if downstream is starved, ship what we
                // have — and re-sample while waiting, since tier-2 can
                // drain to idle mid-window
                Some(probe) => {
                    if probe() {
                        break;
                    }
                    match self.ingress.recv_timeout((deadline - now).min(PROBE_INTERVAL)) {
                        Some(r) => batch.push(r),
                        None => {
                            if self.ingress.is_closed() {
                                break;
                            }
                            // timed out: loop re-checks deadline + probe
                        }
                    }
                }
                None => match self.ingress.recv_timeout(deadline - now) {
                    Some(r) => batch.push(r),
                    None => break,
                },
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::api::InferRequest;

    fn req(id: u64) -> InferRequest {
        InferRequest::new(id, "m", vec![], 0).0
    }

    #[test]
    fn batches_fill_to_max() {
        let ch = Channel::bounded(32);
        for i in 0..5 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        let b = DynamicBatcher::new(ch, 4, 50.0);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 1);
    }

    #[test]
    fn delay_bounds_batch_wait() {
        let ch = Channel::bounded(32);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 20.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t.elapsed();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        assert!(waited < Duration::from_millis(500), "{waited:?}");
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let ch = Channel::bounded(32);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            ch2.send(req(1)).map_err(|_| ()).unwrap();
        });
        let b = DynamicBatcher::new(ch, 8, 60.0);
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn closed_queue_terminates() {
        let ch: Channel<InferRequest> = Channel::bounded(4);
        ch.close();
        let b = DynamicBatcher::new(ch, 4, 1.0);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn zero_delay_ships_immediately() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        ch.send(req(1)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 0.0);
        // first batch grabs whatever is queued at that instant (≥1)
        let batch = b.next_batch().unwrap();
        assert!(!batch.is_empty());
    }

    #[test]
    fn max_batch_one_never_waits_for_peers() {
        let ch = Channel::bounded(8);
        for i in 0..3 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        // generous delay: with max_batch=1 it must still not be consulted
        let b = DynamicBatcher::new(ch, 1, 10_000.0);
        let t = Instant::now();
        for _ in 0..3 {
            assert_eq!(b.next_batch().unwrap().len(), 1);
        }
        assert!(t.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn max_batch_zero_normalizes_to_one() {
        let ch = Channel::bounded(4);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 0, 1.0);
        assert_eq!(b.max_batch, 1);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn close_mid_batch_ships_partial_then_terminates() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let ch2 = ch.clone();
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            ch2.send(req(1)).map_err(|_| ()).unwrap();
            ch2.close();
        });
        // long delay window: the close must cut the wait short
        let b = DynamicBatcher::new(ch, 8, 5_000.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        closer.join().unwrap();
        assert!(batch.len() <= 2 && !batch.is_empty());
        assert!(
            t.elapsed() < Duration::from_millis(2_000),
            "close() must not wait out the full delay window"
        );
        // drain whatever the close left behind, then terminate
        let mut seen = batch.len();
        while let Some(more) = b.next_batch() {
            seen += more.len();
        }
        assert_eq!(seen, 2, "no request lost across the close");
        assert!(b.next_batch().is_none(), "stays terminated");
    }

    #[test]
    fn stale_request_ships_without_restarting_the_window() {
        // Starvation regression: under sustained load the batcher can
        // pull a request that already waited out max_delay in the
        // ingress queue.  The window is anchored at submission time, so
        // the batch must ship immediately — not wait another full
        // window from the poll instant.
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let b = DynamicBatcher::new(ch, 8, 20.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(15),
            "stale request must flush immediately, waited {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn half_spent_window_only_waits_the_remainder() {
        // The oldest request spent part of its window queued; only the
        // remainder may be waited out.
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let b = DynamicBatcher::new(ch, 8, 80.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t.elapsed();
        assert!(waited < Duration::from_millis(70), "{waited:?}");
    }

    #[test]
    fn deadline_cap_clamps_the_window_only_downward() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        // a 10 s window capped at 20 ms must flush within the cap
        let b = DynamicBatcher::new(ch, 8, 10_000.0)
            .with_deadline_cap(Duration::from_millis(20));
        assert_eq!(b.max_delay, Duration::from_millis(20));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t.elapsed();
        assert!(waited < Duration::from_millis(500), "{waited:?}");
        // a cap looser than the window leaves the window alone
        let ch2: Channel<InferRequest> = Channel::bounded(8);
        let b2 = DynamicBatcher::new(ch2, 8, 5.0)
            .with_deadline_cap(Duration::from_millis(500));
        assert_eq!(b2.max_delay, Duration::from_secs_f64(0.005));
    }

    #[test]
    fn idle_downstream_flushes_partial_batches_early() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 10_000.0)
            .with_flush_probe(Arc::new(|| true));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(500),
            "idle downstream must cut the window short"
        );
    }

    #[test]
    fn busy_downstream_keeps_the_window() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 25.0).with_flush_probe(Arc::new(|| false));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() >= Duration::from_millis(15),
            "busy downstream keeps coalescing, waited {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn probe_resampled_mid_wait_cuts_the_window() {
        // tier-2 going idle *after* the batcher starts waiting must
        // still flush the partial batch promptly
        use std::sync::atomic::{AtomicBool, Ordering};
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let idle = Arc::new(AtomicBool::new(false));
        let idle2 = idle.clone();
        let b = DynamicBatcher::new(ch, 8, 10_000.0)
            .with_flush_probe(Arc::new(move || idle2.load(Ordering::SeqCst)));
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            idle.store(true, Ordering::SeqCst);
        });
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(
            t.elapsed() < Duration::from_millis(1_000),
            "mid-wait idle must flush, waited {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn probe_still_drains_queued_requests_first() {
        // An idle-downstream flush must not strand already-queued peers.
        let ch = Channel::bounded(8);
        for i in 0..3 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        let b = DynamicBatcher::new(ch, 8, 10_000.0)
            .with_flush_probe(Arc::new(|| true));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3, "queued requests join before the flush");
    }

    #[test]
    fn burst_larger_than_max_batch_splits_without_loss() {
        let ch = Channel::bounded(32);
        for i in 0..10 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        ch.close();
        let b = DynamicBatcher::new(ch, 4, 50.0);
        let mut sizes = Vec::new();
        let mut ids = Vec::new();
        while let Some(batch) = b.next_batch() {
            sizes.push(batch.len());
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(sizes, vec![4, 4, 2], "burst splits at max_batch, FIFO");
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }
}
