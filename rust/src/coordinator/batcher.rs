//! Dynamic batcher: (max-batch, max-delay) request coalescing.
//!
//! Requests accumulate until either `max_batch` are waiting or the oldest
//! has waited `max_delay`; the batch then ships to a worker.  This is the
//! standard serving trade-off (throughput vs tail latency) and an
//! ablation bench sweeps both knobs.

use std::time::{Duration, Instant};

use super::api::InferRequest;
use crate::util::threadpool::Channel;

/// Pulls from the ingress queue and forms batches.
pub struct DynamicBatcher {
    ingress: Channel<InferRequest>,
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl DynamicBatcher {
    pub fn new(ingress: Channel<InferRequest>, max_batch: usize, max_delay_ms: f64) -> Self {
        Self {
            ingress,
            max_batch: max_batch.max(1),
            max_delay: Duration::from_secs_f64(max_delay_ms.max(0.0) / 1e3),
        }
    }

    pub fn ingress(&self) -> Channel<InferRequest> {
        self.ingress.clone()
    }

    /// Block for the next batch; None when the queue is closed and empty.
    pub fn next_batch(&self) -> Option<Vec<InferRequest>> {
        // block for the first request
        let first = self.ingress.recv()?;
        let deadline = Instant::now() + self.max_delay;
        let mut batch = vec![first];
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // opportunistically drain, then wait out the remaining delay
            let more = self.ingress.drain_up_to(self.max_batch - batch.len());
            if !more.is_empty() {
                batch.extend(more);
                continue;
            }
            match self.ingress.recv_timeout(deadline - now) {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::api::InferRequest;

    fn req(id: u64) -> InferRequest {
        InferRequest::new(id, "m", vec![], 0).0
    }

    #[test]
    fn batches_fill_to_max() {
        let ch = Channel::bounded(32);
        for i in 0..5 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        let b = DynamicBatcher::new(ch, 4, 50.0);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 4);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2.len(), 1);
    }

    #[test]
    fn delay_bounds_batch_wait() {
        let ch = Channel::bounded(32);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 20.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t.elapsed();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        assert!(waited < Duration::from_millis(500), "{waited:?}");
    }

    #[test]
    fn late_arrivals_join_within_window() {
        let ch = Channel::bounded(32);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let ch2 = ch.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            ch2.send(req(1)).map_err(|_| ()).unwrap();
        });
        let b = DynamicBatcher::new(ch, 8, 60.0);
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn closed_queue_terminates() {
        let ch: Channel<InferRequest> = Channel::bounded(4);
        ch.close();
        let b = DynamicBatcher::new(ch, 4, 1.0);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn zero_delay_ships_immediately() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        ch.send(req(1)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 8, 0.0);
        // first batch grabs whatever is queued at that instant (≥1)
        let batch = b.next_batch().unwrap();
        assert!(!batch.is_empty());
    }

    #[test]
    fn max_batch_one_never_waits_for_peers() {
        let ch = Channel::bounded(8);
        for i in 0..3 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        // generous delay: with max_batch=1 it must still not be consulted
        let b = DynamicBatcher::new(ch, 1, 10_000.0);
        let t = Instant::now();
        for _ in 0..3 {
            assert_eq!(b.next_batch().unwrap().len(), 1);
        }
        assert!(t.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn max_batch_zero_normalizes_to_one() {
        let ch = Channel::bounded(4);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let b = DynamicBatcher::new(ch, 0, 1.0);
        assert_eq!(b.max_batch, 1);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn close_mid_batch_ships_partial_then_terminates() {
        let ch = Channel::bounded(8);
        ch.send(req(0)).map_err(|_| ()).unwrap();
        let ch2 = ch.clone();
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            ch2.send(req(1)).map_err(|_| ()).unwrap();
            ch2.close();
        });
        // long delay window: the close must cut the wait short
        let b = DynamicBatcher::new(ch, 8, 5_000.0);
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        closer.join().unwrap();
        assert!(batch.len() <= 2 && !batch.is_empty());
        assert!(
            t.elapsed() < Duration::from_millis(2_000),
            "close() must not wait out the full delay window"
        );
        // drain whatever the close left behind, then terminate
        let mut seen = batch.len();
        while let Some(more) = b.next_batch() {
            seen += more.len();
        }
        assert_eq!(seen, 2, "no request lost across the close");
        assert!(b.next_batch().is_none(), "stays terminated");
    }

    #[test]
    fn burst_larger_than_max_batch_splits_without_loss() {
        let ch = Channel::bounded(32);
        for i in 0..10 {
            ch.send(req(i)).map_err(|_| ()).unwrap();
        }
        ch.close();
        let b = DynamicBatcher::new(ch, 4, 50.0);
        let mut sizes = Vec::new();
        let mut ids = Vec::new();
        while let Some(batch) = b.next_batch() {
            sizes.push(batch.len());
            ids.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(sizes, vec![4, 4, 2], "burst splits at max_batch, FIFO");
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }
}
