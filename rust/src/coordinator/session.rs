//! Sharded session table: binding, keystream epoch and expiry state for
//! every live client session.
//!
//! The paper's deployment model is "millions of users" — session state
//! must be (a) bounded, so long-lived deployments do not leak memory
//! linearly in distinct session ids, and (b) concurrent, so the submit
//! hot path does not serialize every tenant behind one mutex.  The table
//! is N-way striped: a session id selects a shard by hash, each shard is
//! an independent `Mutex<HashMap>` with its own lazy-LRU queue, and a
//! TTL sweep walks the shards one lock at a time.
//!
//! Each entry owns the full lifecycle of one session:
//!
//! * **binding** — the model the session is pinned to (first touch claims
//!   it; a live conflicting bind is a collision),
//! * **epoch** — the AES-CTR keystream epoch.  The nonce the enclave
//!   derives is `crypto::session_word(session, epoch)`, so bumping the
//!   epoch on refresh retires the old keystream instead of replaying it,
//! * **expiry** — an absolute deadline (`established/refreshed + ttl`).
//!   Attested sessions past their deadline are rejected with a typed
//!   [`SessionExpired`](super::router::AdmissionError::SessionExpired)
//!   until refreshed; implicit (in-process, unattested) bindings simply
//!   re-bind cleanly, which is also what makes an expired-then-reused id
//!   safe instead of a phantom collision.
//!
//! All methods take `now_ms` explicitly (milliseconds on the caller's
//! monotone clock) so expiry is deterministic under test.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::crypto;

/// TTL that never expires (saturating deadline arithmetic).
pub const SESSION_TTL_FOREVER: u64 = u64::MAX;

/// Floor of the attested (network) session-id range: high enough that
/// hand-picked in-process ids (tests, benches use small integers) never
/// collide with issued ids, low enough that every issued id stays inside
/// [`crypto::SESSION_ID_MASK`] so the epoch-folded session word remains
/// injective.  Ids inside the range are *drawn at random* (a keyed hash
/// of a per-table secret and a nonce), never allocated sequentially —
/// a remote peer must not be able to enumerate other tenants' sessions.
pub const NET_SESSION_BASE: u64 = 1 << 32;

/// Domain-separation label for the REFRESH control MAC.
pub const CONTROL_REFRESH: &[u8] = b"origami-net-refresh";

/// Domain-separation label for the REVOKE control MAC.
pub const CONTROL_REVOKE: &[u8] = b"origami-net-revoke";

/// The MAC a control frame (REFRESH/REVOKE) must carry: keyed by the
/// session's auth key (derived from the attested session key on both
/// ends), bound to the frame kind, the session id and the *current*
/// epoch — so a captured REFRESH frame cannot be replayed once the
/// epoch has moved on.
pub fn control_mac(auth: &[u8; 32], label: &[u8], session: u64, epoch: u32) -> [u8; 32] {
    crypto::hmac_sha256(auth, &control_bytes(label, session, epoch))
}

fn control_bytes(label: &[u8], session: u64, epoch: u32) -> Vec<u8> {
    let mut data = label.to_vec();
    data.extend_from_slice(&session.to_le_bytes());
    data.extend_from_slice(&epoch.to_le_bytes());
    data
}

/// Per-table secret behind session-id derivation.  Entropy comes from
/// the OS-seeded `RandomState` hasher plus the wall clock — the
/// simulator's stand-in for the enclave's hardware RNG (no external
/// crates in this build).
fn id_seed() -> [u8; 32] {
    use std::hash::{BuildHasher, Hasher};
    let mut material = Vec::with_capacity(40);
    for i in 0..3u64 {
        let mut h = std::collections::hash_map::RandomState::new().build_hasher();
        h.write_u64(i);
        material.extend_from_slice(&h.finish().to_le_bytes());
    }
    if let Ok(t) = std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
        material.extend_from_slice(&t.as_nanos().to_le_bytes());
    }
    crypto::sha256(&material)
}

/// How a `bind` call resolved.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Binding {
    /// Keystream epoch the submit must encrypt/decrypt under.
    pub epoch: u32,
    /// True when this call created (or re-created) the binding — the
    /// caller must release it again on any denial path.
    pub newly_bound: bool,
}

/// Typed session-table failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// The id is live and pinned to a different model.
    Collision { bound: String },
    /// The session's TTL lapsed.  `refreshable` distinguishes an entry
    /// that is still present (a `refresh` — epoch bump — resumes it)
    /// from one the sweep already retired (the client must re-attest).
    Expired { session: u64, refreshable: bool },
    /// No such session (never established, or revoked).
    Unknown { session: u64 },
    /// A control operation did not prove possession of the session's
    /// auth key (bad MAC, stale-epoch MAC, or an implicit session that
    /// has no wire-controllable auth key at all).
    Unauthorized { session: u64 },
}

/// What `establish`/`refresh` hand back to the client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionGrant {
    pub session: u64,
    pub epoch: u32,
    /// Absolute expiry deadline on the table clock.
    pub expires_at_ms: u64,
}

struct Entry {
    model: String,
    epoch: u32,
    expires_at_ms: u64,
    /// Established through the attested handshake (expiry is enforced)
    /// vs. implicitly bound by an in-process submit (expiry recycles).
    attested: bool,
    /// Control-frame MAC key, derived from the attested session key at
    /// establish time.  `None` for implicit (in-process) bindings: the
    /// wire can never refresh or revoke a session it did not establish.
    auth: Option<[u8; 32]>,
    /// Stamp of this entry's newest LRU-queue record; older queue
    /// records for the same id are skipped when they surface.
    stamp: u64,
}

impl Entry {
    fn check_control(&self, label: &[u8], session: u64, tag: &[u8; 32]) -> Result<(), SessionError> {
        let Some(auth) = self.auth.as_ref() else {
            return Err(SessionError::Unauthorized { session });
        };
        if !crypto::verify_hmac(auth, &control_bytes(label, session, self.epoch), tag) {
            return Err(SessionError::Unauthorized { session });
        }
        Ok(())
    }
}

struct Shard {
    map: HashMap<u64, Entry>,
    /// Lazy LRU order: (session, stamp) pushed on every touch.  Stale
    /// records (stamp no longer current) are discarded on pop, so the
    /// queue needs no mid-queue removal.
    lru: VecDeque<(u64, u64)>,
    next_stamp: u64,
}

impl Shard {
    fn touch(&mut self, session: u64) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        if let Some(e) = self.map.get_mut(&session) {
            e.stamp = stamp;
        }
        self.lru.push_back((session, stamp));
        // Bound queue garbage: if lazy records pile up far past the live
        // set, compact by dropping stale heads.
        if self.lru.len() > self.map.len().saturating_mul(4).max(64) {
            while let Some(&(s, st)) = self.lru.front() {
                if self.map.get(&s).map(|e| e.stamp) == Some(st) {
                    break;
                }
                self.lru.pop_front();
            }
        }
    }

    /// Evict the least-recently-touched entry; returns false if empty.
    fn evict_lru(&mut self) -> bool {
        while let Some((s, st)) = self.lru.pop_front() {
            if self.map.get(&s).map(|e| e.stamp) == Some(st) {
                self.map.remove(&s);
                return true;
            }
        }
        // queue exhausted (all records stale) — drop an arbitrary entry
        if let Some(&s) = self.map.keys().next() {
            self.map.remove(&s);
            return true;
        }
        false
    }
}

/// The sharded session table (see module docs).
pub struct SessionTable {
    shards: Vec<Mutex<Shard>>,
    ttl_ms: u64,
    /// Per-shard live-entry ceiling (LRU backstop above TTL); 0 = none.
    shard_cap: usize,
    /// Nonce behind attested-id derivation (not the id itself).
    id_nonce: AtomicU64,
    /// Per-table secret keying attested-id derivation.
    id_seed: [u8; 32],
}

impl SessionTable {
    /// `shards` is rounded up to a power of two; `ttl_ms` is the
    /// lifetime granted at establish/bind/refresh time (0 = immediate
    /// expiry, [`SESSION_TTL_FOREVER`] = never).
    pub fn new(shards: usize, ttl_ms: u64) -> Self {
        Self::with_capacity(shards, ttl_ms, 0)
    }

    /// [`SessionTable::new`] plus a total live-session ceiling: inserts
    /// past `max_sessions` evict the shard's least-recently-used entry,
    /// so the table stays bounded even if nothing ever expires.
    pub fn with_capacity(shards: usize, ttl_ms: u64, max_sessions: usize) -> Self {
        let n = shards.clamp(1, 1 << 16).next_power_of_two();
        Self {
            shards: (0..n)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        lru: VecDeque::new(),
                        next_stamp: 0,
                    })
                })
                .collect(),
            ttl_ms,
            shard_cap: if max_sessions == 0 {
                0
            } else {
                max_sessions.div_ceil(n)
            },
            id_nonce: AtomicU64::new(0),
            id_seed: id_seed(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn ttl_ms(&self) -> u64 {
        self.ttl_ms
    }

    fn shard(&self, session: u64) -> MutexGuard<'_, Shard> {
        // Fibonacci-hash the id so sequential ids spread across shards.
        let h = session.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let idx = (h >> 32) as usize & (self.shards.len() - 1);
        self.shards[idx]
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    fn deadline(&self, now_ms: u64) -> u64 {
        now_ms.saturating_add(self.ttl_ms)
    }

    fn insert(&self, sh: &mut Shard, session: u64, entry: Entry) {
        if self.shard_cap > 0 && sh.map.len() >= self.shard_cap {
            sh.evict_lru();
        }
        sh.map.insert(session, entry);
        sh.touch(session);
    }

    /// Issue a fresh attested session bound to `model`, holding `auth`
    /// as its control-frame MAC key.  Ids are drawn at random from the
    /// attested range `[NET_SESSION_BASE, SESSION_ID_MASK]` — a keyed
    /// hash of a per-table secret and a nonce, never sequential — so a
    /// remote peer cannot enumerate other tenants' sessions, and a live
    /// id is never reissued.
    pub fn establish(&self, model: &str, auth: [u8; 32], now_ms: u64) -> SessionGrant {
        loop {
            let nonce = self.id_nonce.fetch_add(1, Ordering::Relaxed);
            let mut material = self.id_seed.to_vec();
            material.extend_from_slice(&nonce.to_le_bytes());
            let digest = crypto::sha256(&material);
            let id = u64::from_le_bytes(digest[..8].try_into().unwrap()) & crypto::SESSION_ID_MASK;
            if id < NET_SESSION_BASE {
                continue; // keep clear of the hand-picked in-process range
            }
            let mut sh = self.shard(id);
            if sh.map.contains_key(&id) {
                continue; // drew a live id; redraw
            }
            let expires_at_ms = self.deadline(now_ms);
            self.insert(
                &mut sh,
                id,
                Entry {
                    model: model.to_string(),
                    epoch: 0,
                    expires_at_ms,
                    attested: true,
                    auth: Some(auth),
                    stamp: 0,
                },
            );
            return SessionGrant {
                session: id,
                epoch: 0,
                expires_at_ms,
            };
        }
    }

    /// Resolve the binding for a submit: first touch claims the id for
    /// `model`; a live conflicting binding is a collision; an expired
    /// attested session is rejected (refresh required); an expired
    /// implicit binding is recycled in place.
    pub fn bind(
        &self,
        session: u64,
        model: &str,
        now_ms: u64,
    ) -> Result<Binding, SessionError> {
        let mut sh = self.shard(session);
        if let Some(e) = sh.map.get_mut(&session) {
            if now_ms >= e.expires_at_ms {
                if e.attested {
                    return Err(SessionError::Expired {
                        session,
                        refreshable: true,
                    });
                }
                // implicit binding past its TTL: recycle in place (the
                // expired-then-reused regression) — same epoch space is
                // safe because in-process callers always encrypt epoch 0
                // and the keystream is theirs alone.
                e.model = model.to_string();
                e.auth = None;
                e.expires_at_ms = self.deadline(now_ms);
                let epoch = e.epoch;
                sh.touch(session);
                return Ok(Binding {
                    epoch,
                    newly_bound: true,
                });
            }
            if e.model != model {
                return Err(SessionError::Collision {
                    bound: e.model.clone(),
                });
            }
            let epoch = e.epoch;
            sh.touch(session);
            return Ok(Binding {
                epoch,
                newly_bound: false,
            });
        }
        let expires_at_ms = self.deadline(now_ms);
        self.insert(
            &mut sh,
            session,
            Entry {
                model: model.to_string(),
                epoch: 0,
                expires_at_ms,
                attested: false,
                auth: None,
                stamp: 0,
            },
        );
        Ok(Binding {
            epoch: 0,
            newly_bound: true,
        })
    }

    /// Release a binding this submit attempt created (denial path).
    pub fn unbind(&self, session: u64) {
        self.shard(session).map.remove(&session);
    }

    /// The live epoch of `session`, or why it cannot serve.
    pub fn epoch_of(&self, session: u64, now_ms: u64) -> Result<u32, SessionError> {
        let sh = self.shard(session);
        match sh.map.get(&session) {
            None => Err(SessionError::Unknown { session }),
            Some(e) if now_ms >= e.expires_at_ms => Err(SessionError::Expired {
                session,
                refreshable: true,
            }),
            Some(e) => Ok(e.epoch),
        }
    }

    /// Bump the keystream epoch and extend the deadline.  Works on an
    /// expired-but-present entry (that is the point of refresh); a swept
    /// or revoked session returns `Unknown` — the client re-attests.
    pub fn refresh(&self, session: u64, now_ms: u64) -> Result<SessionGrant, SessionError> {
        let mut sh = self.shard(session);
        let Some(e) = sh.map.get_mut(&session) else {
            return Err(SessionError::Unknown { session });
        };
        e.epoch = e.epoch.wrapping_add(1);
        e.expires_at_ms = self.deadline(now_ms);
        let grant = SessionGrant {
            session,
            epoch: e.epoch,
            expires_at_ms: e.expires_at_ms,
        };
        sh.touch(session);
        Ok(grant)
    }

    /// [`SessionTable::refresh`], gated on proof of possession of the
    /// attested session key: `tag` must be
    /// `control_mac(auth, CONTROL_REFRESH, session, current_epoch)`.
    /// Implicit sessions hold no auth key and always refuse — the wire
    /// cannot steer sessions it did not establish.
    pub fn refresh_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
        now_ms: u64,
    ) -> Result<SessionGrant, SessionError> {
        let mut sh = self.shard(session);
        let Some(e) = sh.map.get_mut(&session) else {
            return Err(SessionError::Unknown { session });
        };
        e.check_control(CONTROL_REFRESH, session, tag)?;
        e.epoch = e.epoch.wrapping_add(1);
        e.expires_at_ms = self.deadline(now_ms);
        let grant = SessionGrant {
            session,
            epoch: e.epoch,
            expires_at_ms: e.expires_at_ms,
        };
        sh.touch(session);
        Ok(grant)
    }

    /// Drop the session outright; returns whether it existed.
    pub fn revoke(&self, session: u64) -> bool {
        self.shard(session).map.remove(&session).is_some()
    }

    /// [`SessionTable::revoke`] gated on the session's control MAC
    /// (label [`CONTROL_REVOKE`]).  An absent session is `Ok(false)` —
    /// there is nothing to protect and nothing to reveal; a present one
    /// is only dropped when `tag` proves key possession.
    pub fn revoke_authed(&self, session: u64, tag: &[u8; 32]) -> Result<bool, SessionError> {
        let mut sh = self.shard(session);
        let Some(e) = sh.map.get(&session) else {
            return Ok(false);
        };
        e.check_control(CONTROL_REVOKE, session, tag)?;
        sh.map.remove(&session);
        Ok(true)
    }

    /// Retire every expired entry; returns how many were removed.  One
    /// shard lock at a time, so concurrent submits only ever contend on
    /// the shard currently under the broom.
    pub fn sweep(&self, now_ms: u64) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut sh = shard.lock().unwrap_or_else(|e| e.into_inner());
            let Shard { map, lru, .. } = &mut *sh;
            let before = map.len();
            map.retain(|_, e| now_ms < e.expires_at_ms);
            removed += before - map.len();
            lru.retain(|(s, st)| map.get(s).map(|e| e.stamp) == Some(*st));
        }
        removed
    }

    /// Live entries across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, session: u64) -> bool {
        self.shard(session).map.contains_key(&session)
    }

    /// The model `session` is bound to, if live.
    pub fn bound_model(&self, session: u64, now_ms: u64) -> Option<String> {
        let sh = self.shard(session);
        sh.map
            .get(&session)
            .filter(|e| now_ms < e.expires_at_ms)
            .map(|e| e.model.clone())
    }

    /// Export a live session for migration to a sibling node (cluster
    /// drain).  Expiry travels as *remaining* lifetime, not an absolute
    /// deadline — each table runs its own clock, so an absolute stamp
    /// would silently stretch or clip the TTL across nodes.  `None` if
    /// the session is unknown or already expired.
    pub fn export(&self, session: u64, now_ms: u64) -> Option<SessionSnapshot> {
        let sh = self.shard(session);
        let e = sh.map.get(&session).filter(|e| now_ms < e.expires_at_ms)?;
        Some(SessionSnapshot {
            session,
            model: e.model.clone(),
            epoch: e.epoch,
            remaining_ms: if e.expires_at_ms == SESSION_TTL_FOREVER {
                SESSION_TTL_FOREVER
            } else {
                e.expires_at_ms - now_ms
            },
            attested: e.attested,
            auth: e.auth,
        })
    }

    /// Every live session, for whole-node drain.
    pub fn export_all(&self, now_ms: u64) -> Vec<SessionSnapshot> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let sh = shard.lock().unwrap_or_else(|e| e.into_inner());
            for (&session, e) in sh.map.iter() {
                if now_ms < e.expires_at_ms {
                    out.push(SessionSnapshot {
                        session,
                        model: e.model.clone(),
                        epoch: e.epoch,
                        remaining_ms: if e.expires_at_ms == SESSION_TTL_FOREVER {
                            SESSION_TTL_FOREVER
                        } else {
                            e.expires_at_ms - now_ms
                        },
                        attested: e.attested,
                        auth: e.auth,
                    });
                }
            }
        }
        out.sort_by_key(|s| s.session);
        out
    }

    /// Adopt a migrated session at this table's clock, preserving its
    /// id, epoch, auth key, and remaining lifetime — the client's
    /// keystream position survives the move because epoch and key
    /// material are untouched (same-track siblings share the key root).
    /// Capacity rules still apply: the insert can LRU-evict.
    pub fn adopt(&self, snap: SessionSnapshot, now_ms: u64) {
        let mut sh = self.shard(snap.session);
        let expires_at_ms = if snap.remaining_ms == SESSION_TTL_FOREVER {
            SESSION_TTL_FOREVER
        } else {
            now_ms.saturating_add(snap.remaining_ms)
        };
        self.insert(
            &mut sh,
            snap.session,
            Entry {
                model: snap.model,
                epoch: snap.epoch,
                expires_at_ms,
                attested: snap.attested,
                auth: snap.auth,
                stamp: 0,
            },
        );
    }
}

/// A live session frozen for migration between tables (cluster drain).
/// Everything a sibling needs to keep serving the client mid-stream:
/// the id, the bound model, the epoch (keystream position), the
/// control-frame MAC key, and the lifetime it had left.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionSnapshot {
    pub session: u64,
    pub model: String,
    pub epoch: u32,
    /// Lifetime left at export time ([`SESSION_TTL_FOREVER`] = never
    /// expires); the adopting table re-anchors it to its own clock.
    pub remaining_ms: u64,
    pub attested: bool,
    pub auth: Option<[u8; 32]>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_claims_then_collides_then_unbinds() {
        let t = SessionTable::new(8, SESSION_TTL_FOREVER);
        let b = t.bind(7, "a", 0).unwrap();
        assert!(b.newly_bound);
        assert_eq!(b.epoch, 0);
        // same model: not newly bound
        assert!(!t.bind(7, "a", 0).unwrap().newly_bound);
        // different model: collision
        assert_eq!(
            t.bind(7, "b", 0),
            Err(SessionError::Collision { bound: "a".into() })
        );
        t.unbind(7);
        assert!(t.bind(7, "b", 0).unwrap().newly_bound);
    }

    #[test]
    fn ttl_zero_sweep_empties_ten_thousand_bindings() {
        // the session-leak regression: the old flat map retained every
        // distinct id forever
        let t = SessionTable::new(16, 0);
        for s in 0..10_000u64 {
            t.bind(s, "m", 0).unwrap();
        }
        assert_eq!(t.len(), 10_000);
        t.sweep(1);
        assert_eq!(t.len(), 0, "ttl=0 sessions must all sweep away");
    }

    #[test]
    fn expired_then_reused_id_rebinds_cleanly() {
        let t = SessionTable::new(4, 100);
        t.bind(42, "a", 0).unwrap();
        // past the deadline the id re-binds — to a different model —
        // instead of raising a phantom collision
        let b = t.bind(42, "b", 100).unwrap();
        assert!(b.newly_bound);
        assert_eq!(t.bound_model(42, 150), Some("b".into()));
    }

    #[test]
    fn attested_expiry_is_typed_and_refresh_resumes() {
        let t = SessionTable::new(4, 50);
        let g = t.establish("m", [7u8; 32], 0);
        assert_eq!(g.epoch, 0);
        assert!(t.bind(g.session, "m", 10).is_ok());
        // past the deadline: typed expiry, not a silent rebind
        assert_eq!(
            t.bind(g.session, "m", 60),
            Err(SessionError::Expired {
                session: g.session,
                refreshable: true
            })
        );
        let r = t.refresh(g.session, 60).unwrap();
        assert_eq!(r.epoch, 1, "refresh bumps the keystream epoch");
        assert!(t.bind(g.session, "m", 70).is_ok());
        // a swept session cannot refresh — the client must re-attest
        t.revoke(g.session);
        assert_eq!(
            t.refresh(g.session, 70),
            Err(SessionError::Unknown { session: g.session })
        );
    }

    #[test]
    fn establish_issues_distinct_unguessable_in_mask_ids() {
        let t = SessionTable::new(4, SESSION_TTL_FOREVER);
        let a = t.establish("m", [1u8; 32], 0);
        let b = t.establish("m", [1u8; 32], 0);
        let c = t.establish("m", [1u8; 32], 0);
        assert_ne!(a.session, b.session);
        assert_ne!(b.session, c.session);
        for g in [&a, &b, &c] {
            assert_eq!(g.session & !crypto::SESSION_ID_MASK, 0, "inside the mask");
            assert!(
                g.session >= NET_SESSION_BASE,
                "attested ids stay above the in-process range"
            );
        }
        // Sequential allocation let a remote peer enumerate and revoke
        // other tenants' sessions; three consecutive random 48-bit draws
        // forming a run is a ~2^-95 event.
        assert!(
            !(b.session == a.session + 1 && c.session == b.session + 1),
            "attested ids must not be sequential"
        );
    }

    #[test]
    fn control_frames_require_the_session_auth_key() {
        let t = SessionTable::new(4, SESSION_TTL_FOREVER);
        let auth = [9u8; 32];
        let g = t.establish("m", auth, 0);
        // wrong key, and right key over the wrong epoch, both refuse
        let forged = control_mac(&[0u8; 32], CONTROL_REFRESH, g.session, 0);
        assert_eq!(
            t.refresh_authed(g.session, &forged, 0),
            Err(SessionError::Unauthorized { session: g.session })
        );
        let stale_epoch = control_mac(&auth, CONTROL_REFRESH, g.session, 5);
        assert_eq!(
            t.refresh_authed(g.session, &stale_epoch, 0),
            Err(SessionError::Unauthorized { session: g.session })
        );
        // the real key over the live epoch succeeds and bumps it
        let tag = control_mac(&auth, CONTROL_REFRESH, g.session, 0);
        let r = t.refresh_authed(g.session, &tag, 0).unwrap();
        assert_eq!(r.epoch, 1);
        // the epoch moved, so replaying the captured REFRESH MAC fails
        assert_eq!(
            t.refresh_authed(g.session, &tag, 0),
            Err(SessionError::Unauthorized { session: g.session })
        );
        // revoke: forged tag refused (session survives), real tag drops it
        let bad = control_mac(&auth, CONTROL_REVOKE, g.session, 0);
        assert_eq!(
            t.revoke_authed(g.session, &bad),
            Err(SessionError::Unauthorized { session: g.session })
        );
        assert!(t.contains(g.session));
        let good = control_mac(&auth, CONTROL_REVOKE, g.session, 1);
        assert_eq!(t.revoke_authed(g.session, &good), Ok(true));
        // absent session: nothing to protect, nothing to reveal
        assert_eq!(t.revoke_authed(g.session, &good), Ok(false));
    }

    #[test]
    fn implicit_sessions_are_not_wire_controllable() {
        let t = SessionTable::new(4, SESSION_TTL_FOREVER);
        t.bind(7, "m", 0).unwrap();
        let tag = control_mac(&[0u8; 32], CONTROL_REFRESH, 7, 0);
        assert_eq!(
            t.refresh_authed(7, &tag, 0),
            Err(SessionError::Unauthorized { session: 7 }),
            "implicit bindings hold no auth key; the wire cannot refresh them"
        );
        assert_eq!(
            t.revoke_authed(7, &tag),
            Err(SessionError::Unauthorized { session: 7 })
        );
        assert!(t.contains(7), "the implicit session must survive the attempt");
    }

    #[test]
    fn lru_capacity_bounds_the_table() {
        let t = SessionTable::with_capacity(4, SESSION_TTL_FOREVER, 64);
        for s in 0..10_000u64 {
            t.bind(s, "m", 0).unwrap();
        }
        assert!(
            t.len() <= 64,
            "LRU backstop must hold the table at capacity, got {}",
            t.len()
        );
    }

    #[test]
    fn epoch_of_reports_lifecycle() {
        let t = SessionTable::new(4, 100);
        let g = t.establish("m", [7u8; 32], 0);
        assert_eq!(t.epoch_of(g.session, 50), Ok(0));
        assert_eq!(
            t.epoch_of(g.session, 100),
            Err(SessionError::Expired {
                session: g.session,
                refreshable: true
            })
        );
        assert_eq!(
            t.epoch_of(999_999, 0),
            Err(SessionError::Unknown { session: 999_999 })
        );
    }

    #[test]
    fn sweep_under_concurrent_binds_stays_consistent() {
        use std::sync::Arc;
        let t = Arc::new(SessionTable::new(8, 10));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let s = w * 1_000_000 + i;
                    t.bind(s, "m", i / 100).unwrap();
                }
            }));
        }
        for _ in 0..20 {
            t.sweep(25);
        }
        for h in handles {
            h.join().unwrap();
        }
        t.sweep(u64::MAX - 1);
        assert_eq!(t.len(), 0);
    }
}
