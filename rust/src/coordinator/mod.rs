//! Serving coordinator: request API, router, dynamic batcher, pipeline
//! scheduler and the serving engine.
//!
//! Data path (all Rust, Python never involved):
//!
//! ```text
//! client ──encrypted──▶ Router ──▶ per-model queue ──▶ DynamicBatcher
//!        ◀──probs────── ServingEngine workers (Strategy::infer) ◀──┘
//! ```
//!
//! Batches form under a (max-batch, max-delay) policy; each worker owns a
//! full strategy instance (enclave + blinding state) so batches execute
//! in parallel without sharing enclave state across trust contexts.

pub mod api;
pub mod batcher;
pub mod router;
pub mod scheduler;
pub mod server;

pub use api::{InferRequest, InferResponse};
pub use batcher::DynamicBatcher;
pub use router::Router;
pub use server::ServingEngine;
