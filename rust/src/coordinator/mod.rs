//! Serving coordinator: request API, router/deployment, dynamic batcher,
//! pipeline scheduler, the single-batcher serving engine, the sharded
//! worker pool and the shared tier-2 lane fabric.
//!
//! Data path (all Rust, Python never involved):
//!
//! ```text
//! client ──encrypted──▶ Router ──▶ per-model queue ──▶ DynamicBatcher
//!        ◀──probs────── ServingEngine workers (Strategy::infer) ◀──┘
//! ```
//!
//! or, at pool scale ([`pool::WorkerPool`]):
//!
//! ```text
//! client ──▶ Router ──▶ dispatcher (session % N) ──▶ per-worker batcher
//!                 tier-1: enclave w (blind/unblind, disjoint pad domain)
//!                 tier-2: shared open-device lanes (work-stealing tails)
//! ```
//!
//! or, multi-tenant ([`router::Deployment`] + [`fabric::LaneFabric`]):
//!
//! ```text
//! client ─▶ Deployment ─▶ model A pool: tier-1 shards (enclaves) ─┐
//!   (admission:           model B pool: tier-1 shards (enclaves) ─┼─▶ LaneFabric
//!    model, size, session                                         │   deadline-fair
//!    binding, rate/quota/       autoscaler (depth or p95) ────────┘   queue →
//!    shed per tenant)           EPC ledger (worker residency ≤        device lanes
//!                               usable EPC: reclaim or deny grows)
//! ```
//!
//! Batches form under a (max-batch, max-delay) policy — optionally
//! occupancy-aware, flushing early while tier-2 lanes are starved; each
//! worker owns a full strategy instance (enclave + blinding state) so
//! batches execute in parallel without sharing enclave state across
//! trust contexts.  The pool double-buffers Origami's two tiers,
//! overlapping batch *k+1*'s enclave work with batch *k*'s device tail;
//! the fabric lets *different models* share that tier-2 device capacity,
//! since tails carry no enclave state at all.

pub mod admission;
pub mod api;
pub mod batcher;
pub mod cluster;
pub mod epc_sched;
pub mod fabric;
pub mod net;
pub mod pool;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod telemetry;
pub mod track;

pub use admission::{
    AdmissionDenial, AdmissionLimits, InflightPermit, ShedPolicy, TenantAdmission, TokenBucket,
};
pub use api::{InferRequest, InferResponse};
pub use batcher::DynamicBatcher;
pub use cluster::{
    ClusterOptions, ClusterRouter, NodeHealth, RouteError, RoutePlan, SessionMove,
    DEFAULT_DRAIN_GRACE_MS,
};
pub use epc_sched::{
    EpcAccount, EpcLedger, EpcOptions, EpcPacker, ReclaimCandidate, ScaleDenied,
};
pub use fabric::{
    FabricHandle, FabricMetrics, FabricOptions, FairClock, LaneFabric, SplitPolicy, TenantStats,
};
pub use net::{Deny, DenyCode, NetClient, NetError, NetOptions, NetServer, WireInference};
pub use pool::{PoolMetrics, PoolOptions, WorkerPool};
pub use router::{
    AdmissionError, AutoscalePolicy, DeploySpec, Deployment, DeploymentBuilder,
    DeploymentMetrics, EngineHandle, Frontend, Router, ScaleMode, ScaleSignals,
    DEFAULT_SESSION_SHARDS, DEFAULT_SESSION_SWEEP_MS, DEFAULT_SESSION_TTL_MS,
};
pub use server::ServingEngine;
pub use session::{
    Binding, SessionError, SessionGrant, SessionSnapshot, SessionTable, SESSION_TTL_FOREVER,
};
pub use track::{
    TrackError, TrackKeys, TrackMembership, TrackOptions, TrackRegistry,
    TRACK_DOMAIN_STRIDE,
};
pub use telemetry::{
    AdmissionCounters, AdmissionSnapshot, HistogramSnapshot, LatencyHistogram, ScaleCounters,
    ScaleSnapshot, Stage, TelemetryHub, TenantTelemetry, WindowedHistogram,
};
