//! Serving coordinator: request API, router, dynamic batcher, pipeline
//! scheduler, the single-batcher serving engine and the sharded worker
//! pool.
//!
//! Data path (all Rust, Python never involved):
//!
//! ```text
//! client ──encrypted──▶ Router ──▶ per-model queue ──▶ DynamicBatcher
//!        ◀──probs────── ServingEngine workers (Strategy::infer) ◀──┘
//! ```
//!
//! or, at pool scale ([`pool::WorkerPool`]):
//!
//! ```text
//! client ──▶ Router ──▶ dispatcher (session % N) ──▶ per-worker batcher
//!                 tier-1: enclave w (blind/unblind, disjoint pad domain)
//!                 tier-2: shared open-device lanes (work-stealing tails)
//! ```
//!
//! Batches form under a (max-batch, max-delay) policy; each worker owns a
//! full strategy instance (enclave + blinding state) so batches execute
//! in parallel without sharing enclave state across trust contexts.  The
//! pool additionally double-buffers Origami's two tiers, overlapping
//! batch *k+1*'s enclave work with batch *k*'s device tail.

pub mod api;
pub mod batcher;
pub mod pool;
pub mod router;
pub mod scheduler;
pub mod server;

pub use api::{InferRequest, InferResponse};
pub use batcher::DynamicBatcher;
pub use pool::{PoolMetrics, PoolOptions, WorkerPool};
pub use router::{EngineHandle, Router};
pub use server::ServingEngine;
