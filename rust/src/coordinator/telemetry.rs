//! Per-tenant, per-stage latency telemetry: lock-free histograms with
//! windowed percentile readout.
//!
//! The SLO autoscaler needs *latency* signals, not just queue depth — a
//! hot tenant's tier-2 tail can park a cold tenant's batch behind it and
//! blow p95 without depth ever crossing a threshold.  This module gives
//! every tenant a histogram per pipeline stage:
//!
//! - [`Stage::Tier1`]     — enclave-side batch execution (blind,
//!   non-linear layers, unblind), on the simulated timeline.
//! - [`Stage::QueueWait`] — wall time a tier-2 task spent queued in the
//!   shared lane fabric before a lane popped it.
//! - [`Stage::Tier2`]     — the open-device tail itself (simulated).
//! - [`Stage::EndToEnd`]  — client-visible request latency (wall), the
//!   number SLOs are written against.
//!
//! Design constraints, in order:
//!
//! 1. **Cheap recording.**  `record` is a single atomic `fetch_add` on a
//!    log-spaced bucket — no locks, no allocation — so it can sit on the
//!    per-request hot path of every lane and worker.
//! 2. **Order-independent merging.**  Histograms are bucket-count
//!    vectors; merging worker shards is commutative addition, so readout
//!    never depends on which lane flushed first (pinned by a test).
//! 3. **Windowed readout.**  Percentiles answer "p95 over the last few
//!    ticks", not "since boot": the autoscaler rotates the live buckets
//!    into a short ring each tick and reads the union, so a morning
//!    burst cannot haunt the afternoon's scaling decisions.
//!
//! Buckets are geometric: [`SUB_BUCKETS`] buckets per octave starting at
//! [`MIN_MS`], so any quantile estimate is within one bucket (a factor
//! of 2^(1/SUB_BUCKETS)) of the exact sample quantile — also pinned by a
//! test against a known synthetic distribution.
//!
//! Besides latency, every tenant carries two monotone counter sets that
//! survive window rotation: [`AdmissionCounters`] (every admission
//! verdict — admitted / rate-limited / quota / shed / degraded) and
//! [`ScaleCounters`] (EPC-denied grows, workers reclaimed by the
//! packer, and the live EPC-limited flag the shed hints read).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram bucket count (covers ~1 µs .. ~50 min at 2 buckets/octave).
pub const BUCKETS: usize = 64;
/// Geometric sub-buckets per octave (resolution = 2^(1/SUB_BUCKETS) ≈
/// 1.41x per bucket).
pub const SUB_BUCKETS: usize = 2;
/// Lower bound of bucket 0 (ms).
pub const MIN_MS: f64 = 0.001;

/// Bucket index for a latency in ms (clamped to the histogram range).
pub fn bucket_index(ms: f64) -> usize {
    if !(ms > MIN_MS) {
        return 0; // also catches NaN and non-positive values
    }
    let i = ((ms / MIN_MS).log2() * SUB_BUCKETS as f64).floor() as isize;
    i.clamp(0, BUCKETS as isize - 1) as usize
}

/// Inclusive-lower / exclusive-upper bounds of a bucket (ms).
pub fn bucket_bounds(i: usize) -> (f64, f64) {
    let lo = MIN_MS * 2f64.powf(i as f64 / SUB_BUCKETS as f64);
    let hi = MIN_MS * 2f64.powf((i + 1) as f64 / SUB_BUCKETS as f64);
    (lo, hi)
}

/// Representative value reported for a bucket: its geometric midpoint.
fn bucket_value(i: usize) -> f64 {
    MIN_MS * 2f64.powf((i as f64 + 0.5) / SUB_BUCKETS as f64)
}

/// Pipeline stage a latency sample is attributed to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Enclave-side tier-1 execution (simulated ms per batch).
    Tier1,
    /// Wall time queued in the fabric's fair queue.
    QueueWait,
    /// Open-device tier-2 tail execution (simulated ms per batch).
    Tier2,
    /// Client-visible end-to-end request latency (wall ms).
    EndToEnd,
}

impl Stage {
    pub const ALL: [Stage; 4] = [
        Stage::Tier1,
        Stage::QueueWait,
        Stage::Tier2,
        Stage::EndToEnd,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Tier1 => "tier1",
            Stage::QueueWait => "queue_wait",
            Stage::Tier2 => "tier2",
            Stage::EndToEnd => "e2e",
        }
    }

    fn idx(self) -> usize {
        match self {
            Stage::Tier1 => 0,
            Stage::QueueWait => 1,
            Stage::Tier2 => 2,
            Stage::EndToEnd => 3,
        }
    }
}

/// Lock-free latency histogram: log-spaced atomic bucket counters.
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    /// Sum in nanoseconds (for means).
    sum_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one sample (ms).  Lock-free; safe on any hot path.
    pub fn record(&self, ms: f64) {
        self.buckets[bucket_index(ms)].fetch_add(1, Ordering::Relaxed);
        let ns = (ms.max(0.0) * 1e6) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Samples recorded (derived from the buckets, so it stays exact
    /// across concurrent `drain` rotations).
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Copy the current counts out (concurrent records may land on
    /// either side; that is fine for a monitoring snapshot).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (dst, src) in counts.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }

    /// Drain the counters into a snapshot (window rotation).
    pub fn drain(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (dst, src) in counts.iter_mut().zip(&self.buckets) {
            *dst = src.swap(0, Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            sum_ns: self.sum_ns.swap(0, Ordering::Relaxed),
        }
    }
}

/// An owned bucket-count view; merging is commutative addition.
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    counts: [u64; BUCKETS],
    sum_ns: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        Self {
            counts: [0u64; BUCKETS],
            sum_ns: 0,
        }
    }

    /// Merge another shard's counts in (order-independent by
    /// construction: addition commutes).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_ns += other.sum_ns;
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns as f64 / 1e6 / n as f64
        }
    }

    /// Quantile estimate (q in [0, 100]): the geometric midpoint of the
    /// bucket holding the q-th sample — within one bucket of the exact
    /// sample quantile by construction.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_value(i);
            }
        }
        bucket_value(BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// A live histogram plus a short ring of rotated windows.  Recording
/// touches only the live atomics; `rotate` (autoscaler cadence) shifts
/// the live counts into the ring so readouts cover "the last
/// `keep`+1 windows", not all of history.
pub struct WindowedHistogram {
    live: LatencyHistogram,
    windows: Mutex<Vec<HistogramSnapshot>>,
    keep: usize,
}

impl WindowedHistogram {
    pub fn new(keep: usize) -> Self {
        Self {
            live: LatencyHistogram::new(),
            windows: Mutex::new(Vec::new()),
            keep: keep.max(1),
        }
    }

    /// Record one sample (ms) into the live window.  Lock-free.
    pub fn record(&self, ms: f64) {
        self.live.record(ms);
    }

    /// Close the live window: drain it into the ring, dropping windows
    /// beyond the retention depth.
    pub fn rotate(&self) {
        let snap = self.live.drain();
        let mut g = self.windows.lock().unwrap();
        g.push(snap);
        let len = g.len();
        if len > self.keep {
            g.drain(0..len - self.keep);
        }
    }

    /// Union of the live window and the retained ring.
    pub fn window_snapshot(&self) -> HistogramSnapshot {
        let mut acc = self.live.snapshot();
        let g = self.windows.lock().unwrap();
        for w in g.iter() {
            acc.merge(w);
        }
        acc
    }

    /// Samples currently visible in the readout window.
    pub fn window_count(&self) -> u64 {
        self.window_snapshot().count()
    }
}

/// Per-tenant admission outcome counters (lock-free, monotone).  The
/// deployment's admission gate records every verdict here, so operators
/// and tests can audit exactly how much of a tenant's demand was
/// admitted, rate-limited, quota-rejected, shed or degraded.
#[derive(Default)]
pub struct AdmissionCounters {
    admitted: AtomicU64,
    rate_limited: AtomicU64,
    quota_rejected: AtomicU64,
    shed: AtomicU64,
    degraded: AtomicU64,
}

/// An owned snapshot of one tenant's admission counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionSnapshot {
    /// Requests admitted into the tenant's primary pool.
    pub admitted: u64,
    /// Requests rejected by the token-bucket rate limit.
    pub rate_limited: u64,
    /// Requests rejected by the in-flight concurrency quota.
    pub quota_rejected: u64,
    /// Requests rejected by the queue-depth shed threshold.
    pub shed: u64,
    /// Shed requests rerouted to the tenant's degraded tier instead of
    /// being rejected.
    pub degraded: u64,
}

impl AdmissionSnapshot {
    /// Requests refused outright (every denial except degrades).
    pub fn rejected(&self) -> u64 {
        self.rate_limited + self.quota_rejected + self.shed
    }
}

impl AdmissionCounters {
    pub fn record_admitted(&self) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rate_limited(&self) {
        self.rate_limited.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quota_rejected(&self) {
        self.quota_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> AdmissionSnapshot {
        AdmissionSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            rate_limited: self.rate_limited.load(Ordering::Relaxed),
            quota_rejected: self.quota_rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }
}

/// Per-tenant blinding-factor-pool counters (lock-free, monotone).  The
/// serving pool's workers fold their strategies' cumulative pool stats
/// in after every batch, so operators can see whether the steady state
/// runs off staged factors (hits) or keeps falling back to inline
/// generation (`factor_pool_miss` events).
#[derive(Default)]
pub struct FactorPoolCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    prefilled: AtomicU64,
}

/// An owned snapshot of one tenant's factor-pool counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FactorPoolSnapshot {
    /// Layer passes served from staged (pad, unsealed-R) pairs.
    pub hits: u64,
    /// `factor_pool_miss`: layer passes that generated factors inline
    /// because the pool was cold or drained.
    pub misses: u64,
    /// Entries the prefill workers staged (cumulative).
    pub prefilled: u64,
}

impl FactorPoolCounters {
    /// Fold in counter *deltas* (callers diff cumulative strategy stats).
    pub fn record(&self, hits: u64, misses: u64, prefilled: u64) {
        if hits > 0 {
            self.hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.misses.fetch_add(misses, Ordering::Relaxed);
        }
        if prefilled > 0 {
            self.prefilled.fetch_add(prefilled, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> FactorPoolSnapshot {
        FactorPoolSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            prefilled: self.prefilled.load(Ordering::Relaxed),
        }
    }
}

/// Per-tenant autoscale outcome counters (lock-free, monotone), plus
/// the live EPC-limited flag.  The deployment's autoscaler tick records
/// every EPC-denied grow and every reclaimed worker here; the admission
/// gate reads [`ScaleCounters::epc_limited`] to tell clients *why* a
/// shed tenant is not simply scaling out of its backlog.
#[derive(Default)]
pub struct ScaleCounters {
    epc_denied: AtomicU64,
    epc_reclaimed: AtomicU64,
    /// True while the tenant's most recent grow attempt was refused by
    /// the EPC ledger (cleared by the next successful grow).
    epc_limited: AtomicBool,
}

/// An owned snapshot of one tenant's autoscale counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScaleSnapshot {
    /// Grow attempts the EPC co-scheduler denied
    /// ([`ScaleDenied::EpcExhausted`](super::epc_sched::ScaleDenied)).
    pub epc_denied: u64,
    /// Idle workers reclaimed *from* this tenant to fund another
    /// tenant's grow.
    pub epc_reclaimed: u64,
    /// Whether the tenant's growth is currently EPC-limited.
    pub epc_limited: bool,
}

impl ScaleCounters {
    /// Record an EPC-denied grow (sets the limited flag).
    pub fn record_epc_denied(&self) {
        self.epc_denied.fetch_add(1, Ordering::Relaxed);
        self.epc_limited.store(true, Ordering::Relaxed);
    }

    /// Record `n` workers reclaimed from this tenant by the packer.
    pub fn record_epc_reclaimed(&self, n: u64) {
        self.epc_reclaimed.fetch_add(n, Ordering::Relaxed);
    }

    /// A grow succeeded (or headroom returned): clear the limited flag.
    pub fn clear_epc_limited(&self) {
        self.epc_limited.store(false, Ordering::Relaxed);
    }

    /// Whether the tenant's most recent grow attempt was EPC-denied.
    pub fn epc_limited(&self) -> bool {
        self.epc_limited.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> ScaleSnapshot {
        ScaleSnapshot {
            epc_denied: self.epc_denied.load(Ordering::Relaxed),
            epc_reclaimed: self.epc_reclaimed.load(Ordering::Relaxed),
            epc_limited: self.epc_limited(),
        }
    }
}

/// One tenant's per-stage windowed histograms plus admission and
/// autoscale counters.
pub struct TenantTelemetry {
    stages: [WindowedHistogram; 4],
    admission: AdmissionCounters,
    scale: ScaleCounters,
    factor_pool: FactorPoolCounters,
}

impl TenantTelemetry {
    fn new(keep: usize) -> Self {
        Self {
            stages: std::array::from_fn(|_| WindowedHistogram::new(keep)),
            admission: AdmissionCounters::default(),
            scale: ScaleCounters::default(),
            factor_pool: FactorPoolCounters::default(),
        }
    }

    /// The tenant's admission outcome counters.
    pub fn admission(&self) -> &AdmissionCounters {
        &self.admission
    }

    /// The tenant's autoscale outcome counters (EPC denials/reclaims).
    pub fn scale(&self) -> &ScaleCounters {
        &self.scale
    }

    /// The tenant's blinding-factor-pool counters.
    pub fn factor_pool(&self) -> &FactorPoolCounters {
        &self.factor_pool
    }

    /// Record a latency sample for one stage.  Lock-free.
    pub fn record(&self, stage: Stage, ms: f64) {
        self.stages[stage.idx()].record(ms);
    }

    /// Windowed percentile for a stage (0.0 when no samples).
    pub fn percentile(&self, stage: Stage, q: f64) -> f64 {
        self.stages[stage.idx()].window_snapshot().percentile(q)
    }

    /// Windowed snapshot of one stage.
    pub fn snapshot(&self, stage: Stage) -> HistogramSnapshot {
        self.stages[stage.idx()].window_snapshot()
    }

    /// Samples visible in a stage's readout window.
    pub fn window_count(&self, stage: Stage) -> u64 {
        self.stages[stage.idx()].window_count()
    }

    fn rotate(&self) {
        for s in &self.stages {
            s.rotate();
        }
    }
}

/// Deployment-wide registry: one [`TenantTelemetry`] per model, shared
/// by reference with every lane and worker that records into it.
pub struct TelemetryHub {
    tenants: Mutex<HashMap<String, Arc<TenantTelemetry>>>,
    keep_windows: usize,
}

impl Default for TelemetryHub {
    fn default() -> Self {
        // 50 windows at the default 20 ms autoscaler tick ≈ a 1 s
        // sliding readout window.
        Self::new(50)
    }
}

impl TelemetryHub {
    pub fn new(keep_windows: usize) -> Self {
        Self {
            tenants: Mutex::new(HashMap::new()),
            keep_windows: keep_windows.max(1),
        }
    }

    /// Get-or-create a tenant's telemetry (idempotent).
    pub fn register(&self, model: &str) -> Arc<TenantTelemetry> {
        let mut g = self.tenants.lock().unwrap();
        g.entry(model.to_string())
            .or_insert_with(|| Arc::new(TenantTelemetry::new(self.keep_windows)))
            .clone()
    }

    /// Look a tenant up without creating it.
    pub fn get(&self, model: &str) -> Option<Arc<TenantTelemetry>> {
        self.tenants.lock().unwrap().get(model).cloned()
    }

    /// Registered tenant names (sorted).
    pub fn tenants(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tenants.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Close every tenant's live window (autoscaler tick cadence).
    pub fn rotate_all(&self) {
        let tenants: Vec<Arc<TenantTelemetry>> =
            self.tenants.lock().unwrap().values().cloned().collect();
        for t in tenants {
            t.rotate();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_index_monotone_and_bounded() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(1e12), BUCKETS - 1);
        let mut prev = 0;
        for i in 0..200 {
            let ms = 0.001 * 1.5f64.powi(i);
            let b = bucket_index(ms);
            assert!(b >= prev, "bucket index must be monotone in ms");
            prev = b;
        }
        // bounds are consistent with the index
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!(lo < hi);
            let mid = (lo * hi).sqrt();
            assert_eq!(bucket_index(mid), i, "midpoint of bucket {i}");
        }
    }

    #[test]
    fn merge_is_order_independent_across_workers() {
        // Three "workers" record disjoint sample streams; merging their
        // snapshots in any order must give identical counts and
        // percentiles (bucket addition commutes).
        let shards: Vec<LatencyHistogram> =
            (0..3).map(|_| LatencyHistogram::new()).collect();
        let mut rng = Rng::new(42);
        for (w, h) in shards.iter().enumerate() {
            for _ in 0..500 {
                let ms = rng.range_f32(0.1 * (w + 1) as f32, 50.0 * (w + 1) as f32);
                h.record(ms as f64);
            }
        }
        let snaps: Vec<HistogramSnapshot> = shards.iter().map(|h| h.snapshot()).collect();
        let orders: [[usize; 3]; 3] = [[0, 1, 2], [2, 0, 1], [1, 2, 0]];
        let merged: Vec<HistogramSnapshot> = orders
            .iter()
            .map(|ord| {
                let mut acc = HistogramSnapshot::empty();
                for &i in ord {
                    acc.merge(&snaps[i]);
                }
                acc
            })
            .collect();
        for m in &merged[1..] {
            assert_eq!(m.count(), merged[0].count());
            for q in [50.0, 95.0, 99.0] {
                assert_eq!(m.percentile(q), merged[0].percentile(q), "q={q}");
            }
        }
        assert_eq!(merged[0].count(), 1500);
    }

    #[test]
    fn p95_of_known_distribution_lands_within_one_bucket_of_truth() {
        // 1..=1000 ms uniform: the exact sample p95 is 950 ms.  The
        // histogram's estimate must land in the truth's bucket ± one.
        let h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 1000);
        let truth = 950.0;
        let est = snap.p95();
        let diff = (bucket_index(est) as i64 - bucket_index(truth) as i64).abs();
        assert!(
            diff <= 1,
            "p95 estimate {est:.1}ms (bucket {}) vs truth {truth}ms (bucket {})",
            bucket_index(est),
            bucket_index(truth)
        );
        // and the p50 likewise
        let est50 = snap.p50();
        let diff50 = (bucket_index(est50) as i64 - bucket_index(500.0) as i64).abs();
        assert!(diff50 <= 1, "p50 estimate {est50:.1}ms");
    }

    #[test]
    fn windowed_rotation_expires_old_samples() {
        let w = WindowedHistogram::new(2);
        w.record(100.0);
        assert_eq!(w.window_count(), 1);
        w.rotate(); // window -1
        w.record(1.0);
        w.rotate(); // window -2
        assert_eq!(w.window_count(), 2, "both windows retained");
        w.rotate(); // 100ms sample falls off the ring
        w.rotate();
        assert_eq!(w.window_count(), 0, "old windows expired");
        w.record(5.0);
        assert_eq!(w.window_count(), 1);
    }

    #[test]
    fn admission_counters_accumulate_and_snapshot() {
        let hub = TelemetryHub::new(2);
        let t = hub.register("sim8");
        let a = t.admission();
        assert_eq!(a.snapshot(), AdmissionSnapshot::default());
        a.record_admitted();
        a.record_admitted();
        a.record_rate_limited();
        a.record_quota_rejected();
        a.record_shed();
        a.record_degraded();
        let s = a.snapshot();
        assert_eq!(s.admitted, 2);
        assert_eq!(s.rate_limited, 1);
        assert_eq!(s.quota_rejected, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.rejected(), 3, "degrades are served, not rejected");
        // counters survive window rotation (monotone, not windowed)
        hub.rotate_all();
        assert_eq!(t.admission().snapshot(), s);
    }

    #[test]
    fn scale_counters_track_denials_and_the_limited_flag() {
        let hub = TelemetryHub::new(2);
        let t = hub.register("sim224");
        let s = t.scale();
        assert_eq!(s.snapshot(), ScaleSnapshot::default());
        assert!(!s.epc_limited());
        // a denial counts and raises the live flag…
        s.record_epc_denied();
        s.record_epc_denied();
        s.record_epc_reclaimed(3);
        let snap = s.snapshot();
        assert_eq!(snap.epc_denied, 2);
        assert_eq!(snap.epc_reclaimed, 3);
        assert!(snap.epc_limited);
        // …a successful grow clears the flag but never the history
        s.clear_epc_limited();
        let snap = s.snapshot();
        assert!(!snap.epc_limited);
        assert_eq!(snap.epc_denied, 2);
        // counters are monotone across window rotations
        hub.rotate_all();
        assert_eq!(t.scale().snapshot(), snap);
    }

    #[test]
    fn factor_pool_counters_accumulate_deltas_monotonically() {
        let hub = TelemetryHub::new(2);
        let t = hub.register("sim8");
        let f = t.factor_pool();
        assert_eq!(f.snapshot(), FactorPoolSnapshot::default());
        f.record(5, 1, 6);
        f.record(0, 0, 0); // zero deltas are free no-ops
        f.record(3, 0, 2);
        let s = f.snapshot();
        assert_eq!(s.hits, 8);
        assert_eq!(s.misses, 1);
        assert_eq!(s.prefilled, 8);
        // monotone across window rotation, like the other counter sets
        hub.rotate_all();
        assert_eq!(t.factor_pool().snapshot(), s);
    }

    #[test]
    fn hub_registers_and_rotates_tenants() {
        let hub = TelemetryHub::new(4);
        let a = hub.register("sim8");
        let a2 = hub.register("sim8");
        assert!(Arc::ptr_eq(&a, &a2), "register is idempotent");
        a.record(Stage::EndToEnd, 10.0);
        a.record(Stage::Tier1, 3.0);
        assert_eq!(a.window_count(Stage::EndToEnd), 1);
        assert!(hub.get("missing").is_none());
        assert_eq!(hub.tenants(), vec!["sim8".to_string()]);
        hub.rotate_all();
        assert_eq!(
            a.window_count(Stage::EndToEnd),
            1,
            "rotation keeps the sample in the readout window"
        );
        let p = a.percentile(Stage::EndToEnd, 95.0);
        let diff = (bucket_index(p) as i64 - bucket_index(10.0) as i64).abs();
        assert!(diff <= 1, "p95 {p} vs 10ms");
    }
}
