//! Per-tenant admission control: token-bucket rate limits, in-flight
//! concurrency quotas and queue-depth shedding.
//!
//! The fabric's weighted-fair queue (PR 2) and tail-batch splitting
//! (PR 3) meter *service* fairly — but they admit unbounded *demand*: a
//! rogue tenant bursting 10x its share still parks task-sized quanta of
//! work in front of every other tenant's tails and grows its backlog
//! without limit.  This module bounds demand at the deployment door,
//! per tenant, with three independent mechanisms:
//!
//! 1. **Token-bucket rate limit** ([`TokenBucket`]).  Sustained
//!    admitted throughput is capped at `rps` with a configurable burst
//!    allowance; over-rate requests are rejected synchronously with a
//!    retry-after hint computed from the bucket's refill deficit.
//! 2. **In-flight quota** ([`InflightPermit`]).  At most `inflight`
//!    requests of a tenant may be inside the serving stack at once.
//!    The permit is a drop guard carried *by the request itself*, so
//!    the slot is released exactly when the request leaves the system —
//!    reply sent, error path, or failed submit — and can never leak.
//! 3. **Queue-depth shedding.**  Once a tenant's tier-1 backlog reaches
//!    `shed_depth`, further requests are shed: rejected, or — under
//!    [`ShedPolicy::Degrade`] — rerouted to a cheaper strategy tier
//!    (e.g. an enclave-only `baseline2` pool that stays off the shared
//!    tier-2 lanes entirely).
//!
//! The bucket is parameterized on an external clock (`now_ms`), not
//! `Instant::now()`: the live deployment feeds it wall time from its
//! epoch, while the deterministic serving simulator
//! ([`crate::harness::sim`]) feeds it the *same* `SimClock` that drives
//! autoscaler ticks — so replayed traces make identical admission and
//! scaling decisions on every run.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Per-tenant admission limits.  A zero disables that mechanism, so
/// `AdmissionLimits::default()` admits everything.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct AdmissionLimits {
    /// Sustained admitted request rate (requests/second); 0 = unlimited.
    pub rps: f64,
    /// Token-bucket capacity (requests of burst allowance); 0 derives
    /// `max(1, rps / 10)` — a tenth of a second of rate.
    pub burst: f64,
    /// Maximum in-flight requests; 0 = unlimited.
    pub inflight: usize,
    /// Tier-1 queue depth at which further requests are shed; 0 = off.
    pub shed_depth: usize,
}

/// What to do with a request the shed threshold rejects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Reject with a typed error (the client retries later).
    #[default]
    Reject,
    /// Fall back to the model's cheaper strategy tier (when one is
    /// deployed); otherwise behaves like [`ShedPolicy::Reject`].
    Degrade,
}

/// Token bucket over an external millisecond clock.
///
/// Refill is continuous: `take` first credits `rate × elapsed` tokens
/// (clamped to the burst capacity), so refill works identically across
/// any window rotation or tick cadence — the bucket has no windows of
/// its own, only the caller's clock.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_per_ms: f64,
    burst: f64,
    tokens: f64,
    last_ms: f64,
}

impl TokenBucket {
    /// A bucket admitting `rps` sustained with `burst` capacity
    /// (`burst <= 0` derives `max(1, rps / 10)`).  Starts full.  The
    /// capacity floor is one token: a fractional capacity could never
    /// reach the one-token cost of a request, bricking the tenant.
    pub fn new(rps: f64, burst: f64) -> Self {
        let rps = rps.max(0.0);
        let burst = if burst > 0.0 {
            burst.max(1.0)
        } else {
            (rps / 10.0).max(1.0)
        };
        Self {
            rate_per_ms: rps / 1e3,
            burst,
            tokens: burst,
            last_ms: 0.0,
        }
    }

    /// Take one token at `now_ms`; on refusal returns the milliseconds
    /// until a token will be available (the retry-after hint).  A
    /// non-monotone clock sample never un-refills the bucket.
    pub fn try_take(&mut self, now_ms: f64) -> Result<(), f64> {
        if now_ms > self.last_ms {
            self.tokens =
                (self.tokens + (now_ms - self.last_ms) * self.rate_per_ms).min(self.burst);
            self.last_ms = now_ms;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else if self.rate_per_ms > 0.0 {
            Err((1.0 - self.tokens) / self.rate_per_ms)
        } else {
            Err(f64::INFINITY)
        }
    }

    /// Tokens currently available (diagnostics/tests).
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

/// Drop guard for one in-flight admission slot.  Carried by the
/// [`InferRequest`](super::api::InferRequest) it admitted, so the slot
/// frees exactly when the request is dropped — after its reply is sent,
/// on any error path, or when a submit fails before enqueueing.
#[derive(Debug)]
pub struct InflightPermit {
    gauge: Arc<AtomicU64>,
}

impl Drop for InflightPermit {
    fn drop(&mut self) {
        self.gauge.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Why a request was refused admission (policy-level; the deployment
/// maps this onto [`AdmissionError`](super::AdmissionError) with the
/// model name and telemetry-derived retry hints attached).
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionDenial {
    /// The token bucket is empty; a token arrives in `retry_after_ms`.
    RateLimited { retry_after_ms: f64 },
    /// The in-flight quota is saturated.
    QuotaExceeded { limit: usize, inflight: usize },
    /// The tenant's tier-1 backlog reached the shed threshold.
    Shed { depth: usize, threshold: usize },
}

/// One tenant's admission state: bucket + in-flight gauge + shed
/// threshold.  `admit` is the single gate the deployment calls per
/// request.
pub struct TenantAdmission {
    bucket: Option<Mutex<TokenBucket>>,
    inflight_limit: usize,
    inflight: Arc<AtomicU64>,
    shed_depth: usize,
}

impl TenantAdmission {
    pub fn new(limits: AdmissionLimits) -> Self {
        let bucket =
            (limits.rps > 0.0).then(|| Mutex::new(TokenBucket::new(limits.rps, limits.burst)));
        Self {
            bucket,
            inflight_limit: limits.inflight,
            inflight: Arc::new(AtomicU64::new(0)),
            shed_depth: limits.shed_depth,
        }
    }

    /// Gate one request at `now_ms` with the tenant's current tier-1
    /// queue depth.  Checks run cheapest/most-reversible first — shed,
    /// then quota, then rate — so a denial never consumes rate budget,
    /// and a rate denial releases the quota slot it briefly held (the
    /// permit is a drop guard).  On admission, returns the in-flight
    /// permit the request must carry (None when no quota is configured).
    pub fn admit(
        &self,
        now_ms: f64,
        queue_depth: usize,
    ) -> Result<Option<InflightPermit>, AdmissionDenial> {
        if self.shed_depth > 0 && queue_depth >= self.shed_depth {
            return Err(AdmissionDenial::Shed {
                depth: queue_depth,
                threshold: self.shed_depth,
            });
        }
        let permit = if self.inflight_limit > 0 {
            let mut cur = self.inflight.load(Ordering::SeqCst);
            loop {
                if cur as usize >= self.inflight_limit {
                    return Err(AdmissionDenial::QuotaExceeded {
                        limit: self.inflight_limit,
                        inflight: cur as usize,
                    });
                }
                match self.inflight.compare_exchange(
                    cur,
                    cur + 1,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
            Some(InflightPermit {
                gauge: self.inflight.clone(),
            })
        } else {
            None
        };
        if let Some(bucket) = &self.bucket {
            if let Err(retry_after_ms) = bucket.lock().unwrap().try_take(now_ms) {
                // `permit` drops here, releasing the slot it just took
                return Err(AdmissionDenial::RateLimited { retry_after_ms });
            }
        }
        Ok(permit)
    }

    /// Requests currently holding an in-flight slot.
    pub fn in_flight(&self) -> usize {
        self.inflight.load(Ordering::SeqCst) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_refills_across_window_rotations() {
        // 100 rps = 0.1 tokens/ms, burst 5.  Drain the burst at t=0,
        // then advance the clock in uneven "window" steps: the credit
        // must accrue continuously across every rotation boundary, not
        // reset or double-count at them.
        let mut b = TokenBucket::new(100.0, 5.0);
        for _ in 0..5 {
            assert!(b.try_take(0.0).is_ok());
        }
        let retry = b.try_take(0.0).unwrap_err();
        assert!((retry - 10.0).abs() < 1e-9, "1 token / 0.1 per ms = 10 ms");

        // 4 ms + 6 ms of refill across a rotation = exactly 1 token
        assert!(b.try_take(4.0).is_err(), "0.4 tokens is not enough");
        assert!(b.try_take(10.0).is_ok());
        assert!(b.try_take(10.0).is_err(), "credit was spent, not doubled");

        // a long idle period clamps at the burst capacity
        assert!(b.try_take(1e6).is_ok());
        for _ in 0..4 {
            assert!(b.try_take(1e6).is_ok());
        }
        assert!(b.try_take(1e6).is_err(), "burst capped at 5");

        // a non-monotone clock sample cannot mint credit
        let before = b.tokens();
        assert!(b.try_take(0.0).is_err());
        assert!(b.tokens() <= before + 1e-12);
    }

    #[test]
    fn bucket_derives_burst_and_hints_retry() {
        let mut b = TokenBucket::new(5.0, 0.0);
        assert!(b.try_take(0.0).is_ok(), "derived burst is at least 1");
        let retry = b.try_take(0.0).unwrap_err();
        assert!((retry - 200.0).abs() < 1e-9, "5 rps → 200 ms per token");
        // a fractional configured burst is floored to one token — a
        // sub-1.0 capacity could never afford a request and would brick
        // the tenant with retry hints that can never come true
        let mut b = TokenBucket::new(100.0, 0.5);
        assert!(b.try_take(0.0).is_ok(), "fractional burst floored to 1");
        assert!(b.try_take(1_000.0).is_ok(), "and still refills normally");
    }

    #[test]
    fn quota_slots_release_on_drop_not_on_success_paths_only() {
        // "failed submit" is modeled by dropping the permit without ever
        // replying — the drop guard must return the slot either way.
        let a = TenantAdmission::new(AdmissionLimits {
            inflight: 2,
            ..AdmissionLimits::default()
        });
        let p1 = a.admit(0.0, 0).unwrap();
        let p2 = a.admit(0.0, 0).unwrap();
        assert!(p1.is_some() && p2.is_some());
        assert_eq!(a.in_flight(), 2);
        let denial = a.admit(0.0, 0).unwrap_err();
        assert_eq!(
            denial,
            AdmissionDenial::QuotaExceeded {
                limit: 2,
                inflight: 2
            }
        );
        drop(p1); // the failed-submit path: request never entered a pool
        assert_eq!(a.in_flight(), 1, "no leaked in-flight slot");
        let p3 = a.admit(0.0, 0).expect("freed slot is reusable");
        assert!(p3.is_some());
        assert_eq!(a.in_flight(), 2);
        drop(p2);
        drop(p3);
        assert_eq!(a.in_flight(), 0);
    }

    #[test]
    fn rate_denial_releases_the_quota_slot_it_held() {
        let a = TenantAdmission::new(AdmissionLimits {
            rps: 1.0,
            burst: 1.0,
            inflight: 1,
            ..AdmissionLimits::default()
        });
        let p = a.admit(0.0, 0).unwrap();
        assert_eq!(a.in_flight(), 1);
        drop(p);
        // bucket is now empty; quota has a free slot.  The rate denial
        // must not leave that slot acquired.
        match a.admit(0.0, 0).unwrap_err() {
            AdmissionDenial::RateLimited { retry_after_ms } => {
                assert!(retry_after_ms > 0.0)
            }
            other => panic!("expected a rate denial, got {other:?}"),
        }
        assert_eq!(a.in_flight(), 0, "rate denial leaked an in-flight slot");
    }

    #[test]
    fn shed_threshold_fires_before_rate_or_quota() {
        let a = TenantAdmission::new(AdmissionLimits {
            rps: 1000.0,
            burst: 8.0,
            inflight: 8,
            shed_depth: 3,
        });
        let held = a.admit(0.0, 2).expect("under the threshold");
        assert!(held.is_some(), "quota configured → a permit is issued");
        assert_eq!(
            a.admit(0.0, 3).unwrap_err(),
            AdmissionDenial::Shed {
                depth: 3,
                threshold: 3
            }
        );
        assert_eq!(a.in_flight(), 1, "shed consumed no quota slot");
        drop(held);
        assert_eq!(a.in_flight(), 0);
    }

    #[test]
    fn default_limits_admit_everything_without_permits() {
        let a = TenantAdmission::new(AdmissionLimits::default());
        for i in 0..100 {
            assert!(a.admit(i as f64, i).unwrap().is_none());
        }
        assert_eq!(a.in_flight(), 0);
    }
}
