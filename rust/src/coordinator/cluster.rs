//! The cluster router tier: consistent-hash routing of attested
//! sessions across track members, drain of failing nodes, and typed
//! partition isolation.
//!
//! Two layers, deliberately split:
//!
//! * [`RoutePlan`] is the *pure* routing state machine — nodes, health,
//!   the consistent-hash ring, and the session→node pin table.  It
//!   performs no I/O and reads no clock of its own, so the multi-node
//!   simulator ([`harness::sim`](crate::harness::sim)) replays the
//!   exact production code deterministically, the same way it already
//!   replays admission and autoscaling.
//! * [`ClusterRouter`] wraps a plan around live member [`Deployment`]s
//!   and implements [`Frontend`], so the wire front door serves a
//!   cluster exactly as it serves one node.
//!
//! Routing rules:
//!
//! * a session is **pinned** to the node that first served it (session
//!   affinity: the node holds the session's table entry and its pads);
//! * a node marked failing **drains**: every `route` that touches one
//!   of its sessions re-pins the session to a sibling *in the same
//!   track* right then — lazy, so outcomes never depend on how often a
//!   background tick runs — and [`RoutePlan::tick`] batch-migrates
//!   whatever is left once the drain grace expires, then marks the
//!   node down.  Same track ⇒ same key material ⇒ the client's epoch
//!   and keystream survive the move untouched;
//! * a **partition** assigns nodes to components; only the majority
//!   component serves.  A session pinned to a minority-side node gets
//!   a typed [`RouteError::Isolated`] — it is *never* re-pinned across
//!   the cut, because the minority side may still be serving it, and
//!   two nodes advancing one session's keystream would corrupt it
//!   irrecoverably.  Isolation is an availability loss; re-routing
//!   would be an integrity loss.  Heal re-joins the components and the
//!   pins come back as they were.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::crypto;
use crate::util::threadpool::Channel;

use super::api::InferResponse;
use super::router::{AdmissionError, Deployment, Frontend};
use super::session::{SessionError, SessionGrant};

/// Default drain grace: how long a failing node keeps unreached pinned
/// sessions before the tick force-migrates them (`--drain-grace-ms`).
pub const DEFAULT_DRAIN_GRACE_MS: u64 = 500;

/// Cluster routing knobs.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// How long a draining node keeps its remaining pinned sessions
    /// before [`RoutePlan::tick`] force-migrates them and marks it
    /// down.  Routes touching a draining node's session move it
    /// immediately regardless.
    pub drain_grace_ms: u64,
    /// Virtual ring points per node: more vnodes spread load more
    /// evenly at the cost of a bigger ring.
    pub vnodes: usize,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            drain_grace_ms: DEFAULT_DRAIN_GRACE_MS,
            vnodes: 32,
        }
    }
}

/// One node's serving state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeHealth {
    Healthy,
    /// Marked failing at `since_ms`; sessions drain off it lazily, and
    /// past the grace the tick finishes the job and marks it down.
    Draining { since_ms: u64 },
    Down,
}

/// Why a route could not be served.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// The session is pinned to a node on the minority side of a
    /// partition.  Typed and refused — never silently re-pinned, which
    /// could let two nodes advance one keystream.
    Isolated { session: u64, node: String },
    /// The session's node needs to hand off, but no healthy sibling in
    /// the same track is reachable (siblings share key material; a
    /// foreign track could not serve the session's keystream).
    NoSibling { session: u64, track: String },
    /// No usable node at all (everything down or cut off).
    NoCapacity,
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Isolated { session, node } => write!(
                f,
                "session {session} is pinned to `{node}`, isolated by a partition"
            ),
            RouteError::NoSibling { session, track } => write!(
                f,
                "session {session} has no reachable sibling in track `{track}`"
            ),
            RouteError::NoCapacity => write!(f, "no usable node"),
        }
    }
}

impl std::error::Error for RouteError {}

/// One session re-pinned from a draining/down node to a sibling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionMove {
    pub session: u64,
    pub from: String,
    pub to: String,
}

#[derive(Debug, Clone)]
struct RingNode {
    name: String,
    track: String,
    health: NodeHealth,
    /// Partition component (0 when whole); only the majority component
    /// serves.
    component: u32,
}

/// Deterministic consistent-hash routing state (see module docs).
#[derive(Debug)]
pub struct RoutePlan {
    opts: ClusterOptions,
    nodes: Vec<RingNode>,
    /// Sorted (point, node index) — usable nodes only; rebuilt on any
    /// membership/health/partition change.
    ring: Vec<(u64, usize)>,
    /// Session affinity: session → node index.
    pinned: HashMap<u64, usize>,
}

impl RoutePlan {
    pub fn new(opts: ClusterOptions) -> Self {
        Self {
            opts,
            nodes: Vec::new(),
            ring: Vec::new(),
            pinned: HashMap::new(),
        }
    }

    pub fn options(&self) -> &ClusterOptions {
        &self.opts
    }

    /// Register a node.  Existing pins are sticky — consistent hashing
    /// only changes where *new* sessions land, so a membership change
    /// rebalances without moving live keystreams.
    pub fn add_node(&mut self, name: &str, track: &str) {
        if self.index_of(name).is_some() {
            return;
        }
        self.nodes.push(RingNode {
            name: name.to_string(),
            track: track.to_string(),
            health: NodeHealth::Healthy,
            component: 0,
        });
        self.rebuild_ring();
    }

    pub fn node_names(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.name.clone()).collect()
    }

    pub fn health(&self, name: &str) -> Option<NodeHealth> {
        self.index_of(name).map(|i| self.nodes[i].health)
    }

    pub fn track_of(&self, name: &str) -> Option<&str> {
        self.index_of(name).map(|i| self.nodes[i].track.as_str())
    }

    /// Sessions currently pinned to `name`.
    pub fn pinned_to(&self, name: &str) -> Vec<u64> {
        let Some(idx) = self.index_of(name) else {
            return Vec::new();
        };
        let mut v: Vec<u64> = self
            .pinned
            .iter()
            .filter(|&(_, &i)| i == idx)
            .map(|(&s, _)| s)
            .collect();
        v.sort_unstable();
        v
    }

    /// The node a session is pinned to, if any.
    pub fn pin_of(&self, session: u64) -> Option<&str> {
        self.pinned
            .get(&session)
            .map(|&i| self.nodes[i].name.as_str())
    }

    /// Explicitly pin a session (the router records where an establish
    /// landed).
    pub fn pin(&mut self, session: u64, name: &str) {
        if let Some(idx) = self.index_of(name) {
            self.pinned.insert(session, idx);
        }
    }

    pub fn unpin(&mut self, session: u64) {
        self.pinned.remove(&session);
    }

    /// Mark a node failing: it serves no *new* sessions from here on,
    /// existing sessions drain off it (lazily on touch, in bulk by the
    /// tick once `drain_grace_ms` passes).  Idempotent; a down node
    /// stays down.
    pub fn mark_failing(&mut self, name: &str, now_ms: u64) {
        if let Some(i) = self.index_of(name) {
            if self.nodes[i].health == NodeHealth::Healthy {
                self.nodes[i].health = NodeHealth::Draining { since_ms: now_ms };
                self.rebuild_ring();
            }
        }
    }

    /// Split the cluster: `groups[i]` becomes component `i`; nodes not
    /// named stay in component 0.  Only the majority component (most
    /// usable nodes; ties to the lowest id) serves.
    pub fn partition(&mut self, groups: &[Vec<String>]) {
        for n in &mut self.nodes {
            n.component = 0;
        }
        for (cid, group) in groups.iter().enumerate() {
            for name in group {
                if let Some(i) = self.index_of(name) {
                    self.nodes[i].component = cid as u32;
                }
            }
        }
        self.rebuild_ring();
    }

    /// Rejoin all components.  Pins on the (former) minority side come
    /// back exactly as they were — isolation never rewrote them.
    pub fn heal(&mut self) {
        for n in &mut self.nodes {
            n.component = 0;
        }
        self.rebuild_ring();
    }

    /// Route `session` to a node name.  A new session lands on the ring
    /// (usable nodes only); a pinned session sticks to its node unless
    /// that node is draining or down, in which case it is re-pinned to
    /// a same-track sibling *now* — drain is lazy on touch, so serving
    /// outcomes are independent of any background tick cadence.  The
    /// second return is the move performed, if any.
    pub fn route(
        &mut self,
        session: u64,
        _now_ms: u64,
    ) -> std::result::Result<(String, Option<SessionMove>), RouteError> {
        let majority = self.majority_component();
        if let Some(&idx) = self.pinned.get(&session) {
            let node = &self.nodes[idx];
            if node.component != majority {
                // the minority side may still be serving this session:
                // re-pinning would double-drive its keystream
                return Err(RouteError::Isolated {
                    session,
                    node: node.name.clone(),
                });
            }
            if node.health == NodeHealth::Healthy {
                return Ok((node.name.clone(), None));
            }
            // draining or down: hand off to a same-track sibling
            let track = node.track.clone();
            let from = node.name.clone();
            let Some(to_idx) = self.sibling_for(session, &track, idx) else {
                return Err(RouteError::NoSibling { session, track });
            };
            self.pinned.insert(session, to_idx);
            let to = self.nodes[to_idx].name.clone();
            return Ok((
                to.clone(),
                Some(SessionMove { session, from, to }),
            ));
        }
        let Some(idx) = self.ring_walk(point_of_session(session), None) else {
            return Err(RouteError::NoCapacity);
        };
        self.pinned.insert(session, idx);
        Ok((self.nodes[idx].name.clone(), None))
    }

    /// Drain pass: nodes draining past the grace get their remaining
    /// pinned sessions migrated to same-track siblings and are marked
    /// down.  Returns the moves (the caller migrates the session state
    /// alongside).  Deterministic: sessions are processed in sorted
    /// order and targets come from the ring, not the clock — so the
    /// final pinning is identical whatever cadence calls this.
    pub fn tick(&mut self, now_ms: u64) -> Vec<SessionMove> {
        let expired: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n.health {
                NodeHealth::Draining { since_ms }
                    if now_ms.saturating_sub(since_ms) >= self.opts.drain_grace_ms =>
                {
                    Some(i)
                }
                _ => None,
            })
            .collect();
        let mut moves = Vec::new();
        for idx in expired {
            let from = self.nodes[idx].name.clone();
            let track = self.nodes[idx].track.clone();
            let mut sessions: Vec<u64> = self
                .pinned
                .iter()
                .filter(|&(_, &i)| i == idx)
                .map(|(&s, _)| s)
                .collect();
            sessions.sort_unstable();
            for session in sessions {
                if let Some(to_idx) = self.sibling_for(session, &track, idx) {
                    self.pinned.insert(session, to_idx);
                    moves.push(SessionMove {
                        session,
                        from: from.clone(),
                        to: self.nodes[to_idx].name.clone(),
                    });
                }
                // no sibling: leave the pin — the session surfaces as a
                // typed NoSibling on its next touch, never silently lost
            }
            self.nodes[idx].health = NodeHealth::Down;
        }
        if !moves.is_empty() {
            self.rebuild_ring();
        }
        moves
    }

    /// Order-independent digest of the full routing state (nodes,
    /// health, components, pins) — what the simulator's determinism
    /// regressions compare across seeds, runs, and tick cadences.
    pub fn digest(&self) -> u64 {
        let mut acc: u64 = 0xcbf2_9ce4_8422_2325;
        let mut fold = |bytes: &[u8]| {
            for &b in bytes {
                acc ^= b as u64;
                acc = acc.wrapping_mul(0x1000_0000_01b3);
            }
        };
        let mut nodes: Vec<&RingNode> = self.nodes.iter().collect();
        nodes.sort_by(|a, b| a.name.cmp(&b.name));
        for n in nodes {
            fold(n.name.as_bytes());
            fold(n.track.as_bytes());
            fold(&n.component.to_le_bytes());
            fold(&match n.health {
                NodeHealth::Healthy => [0u8; 9],
                NodeHealth::Draining { since_ms } => {
                    let mut b = [1u8; 9];
                    b[1..].copy_from_slice(&since_ms.to_le_bytes());
                    b
                }
                NodeHealth::Down => [2u8; 9],
            });
        }
        let mut pins: Vec<(u64, &str)> = self
            .pinned
            .iter()
            .map(|(&s, &i)| (s, self.nodes[i].name.as_str()))
            .collect();
        pins.sort_unstable();
        for (s, name) in pins {
            fold(&s.to_le_bytes());
            fold(name.as_bytes());
        }
        acc
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.name == name)
    }

    fn usable(&self, idx: usize, majority: u32) -> bool {
        let n = &self.nodes[idx];
        n.health == NodeHealth::Healthy && n.component == majority
    }

    /// The serving component: most usable nodes, ties to the lowest id.
    fn majority_component(&self) -> u32 {
        let mut counts: HashMap<u32, usize> = HashMap::new();
        for n in &self.nodes {
            if n.health == NodeHealth::Healthy {
                *counts.entry(n.component).or_insert(0) += 1;
            }
        }
        let mut best = (0u32, 0usize);
        let mut ids: Vec<u32> = counts.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let c = counts[&id];
            if c > best.1 {
                best = (id, c);
            }
        }
        best.0
    }

    fn rebuild_ring(&mut self) {
        let majority = self.majority_component();
        self.ring.clear();
        for (idx, n) in self.nodes.iter().enumerate() {
            if n.health != NodeHealth::Healthy || n.component != majority {
                continue;
            }
            for v in 0..self.opts.vnodes.max(1) {
                self.ring.push((point_of_node(&n.name, v), idx));
            }
        }
        self.ring.sort_unstable();
    }

    /// First usable node at or clockwise of `point`, optionally
    /// restricted to `track` — the ring only carries usable nodes.
    fn ring_walk(&self, point: u64, track: Option<&str>) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let start = self.ring.partition_point(|&(p, _)| p < point);
        for off in 0..self.ring.len() {
            let (_, idx) = self.ring[(start + off) % self.ring.len()];
            match track {
                Some(t) if self.nodes[idx].track != t => continue,
                _ => return Some(idx),
            }
        }
        None
    }

    fn sibling_for(&self, session: u64, track: &str, exclude: usize) -> Option<usize> {
        if self.ring.is_empty() {
            return None;
        }
        let point = point_of_session(session);
        let start = self.ring.partition_point(|&(p, _)| p < point);
        for off in 0..self.ring.len() {
            let (_, idx) = self.ring[(start + off) % self.ring.len()];
            if idx != exclude && self.nodes[idx].track == track {
                return Some(idx);
            }
        }
        None
    }
}

fn point_of_node(name: &str, vnode: usize) -> u64 {
    let mut material = b"origami-ring-node:".to_vec();
    material.extend_from_slice(name.as_bytes());
    material.extend_from_slice(&(vnode as u64).to_le_bytes());
    let d = crypto::sha256(&material);
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

fn point_of_session(session: u64) -> u64 {
    let mut material = b"origami-ring-session:".to_vec();
    material.extend_from_slice(&session.to_le_bytes());
    let d = crypto::sha256(&material);
    u64::from_le_bytes(d[..8].try_into().unwrap())
}

struct ClusterInner {
    plan: RoutePlan,
    members: HashMap<String, Arc<Deployment>>,
    /// Completed drain/route migrations (audit trail; tests read it).
    moves: Vec<SessionMove>,
}

/// A [`Frontend`] over many track members: routes every session-scoped
/// call through the [`RoutePlan`] and migrates session state alongside
/// every drain move (same-track siblings share key material, so the
/// moved session's epoch and control key stay valid verbatim).
pub struct ClusterRouter {
    inner: Mutex<ClusterInner>,
    /// Round-robin establish spreading (deterministic).
    next_establish: AtomicU64,
    epoch: Instant,
}

impl ClusterRouter {
    pub fn new(opts: ClusterOptions) -> Self {
        Self {
            inner: Mutex::new(ClusterInner {
                plan: RoutePlan::new(opts),
                members: HashMap::new(),
                moves: Vec::new(),
            }),
            next_establish: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    /// Register a member node serving under `track`.
    pub fn add_node(&self, name: &str, track: &str, deployment: Arc<Deployment>) {
        let mut g = self.lock();
        g.plan.add_node(name, track);
        g.members.insert(name.to_string(), deployment);
    }

    /// Mark `name` failing and drain it: every session still pinned to
    /// it is re-pinned to a same-track sibling with its table entry
    /// migrated (epoch, control key, and remaining TTL intact), then
    /// the node is marked down and its deployment handle dropped.
    /// Returns how many sessions moved.
    pub fn kill(&self, name: &str) -> usize {
        let now = self.now_ms();
        let mut g = self.lock();
        g.plan.mark_failing(name, now);
        // force the grace over: a kill is immediate (mark_failing alone
        // models the graceful variant)
        let moves = g.plan.tick(now.saturating_add(g.plan.options().drain_grace_ms));
        let n = moves.len();
        for mv in moves {
            Self::migrate(&mut g, &mv);
            g.moves.push(mv);
        }
        g.members.remove(name);
        n
    }

    /// Graceful variant: mark failing now; routes and later
    /// [`ClusterRouter::drain_tick`] calls do the moving.
    pub fn mark_failing(&self, name: &str) {
        let now = self.now_ms();
        self.lock().plan.mark_failing(name, now);
    }

    /// Background drain pass (the cluster analogue of the session
    /// sweeper): migrate sessions off any node whose drain grace has
    /// expired.  Returns how many moved.
    pub fn drain_tick(&self) -> usize {
        let now = self.now_ms();
        let mut g = self.lock();
        let moves = g.plan.tick(now);
        let n = moves.len();
        for mv in moves {
            Self::migrate(&mut g, &mv);
            g.moves.push(mv);
        }
        n
    }

    /// Completed session migrations so far.
    pub fn moves(&self) -> Vec<SessionMove> {
        self.lock().moves.clone()
    }

    /// The routing digest (see [`RoutePlan::digest`]).
    pub fn digest(&self) -> u64 {
        self.lock().plan.digest()
    }

    /// The node currently pinned for `session`, if any.
    pub fn pin_of(&self, session: u64) -> Option<String> {
        self.lock().plan.pin_of(session).map(str::to_string)
    }

    /// Shut down every member, returning their names in drop order.
    pub fn shutdown(self) -> Vec<String> {
        let inner = self.inner.into_inner().unwrap_or_else(|e| e.into_inner());
        let mut names: Vec<String> = inner.members.keys().cloned().collect();
        names.sort();
        for (_, dep) in inner.members {
            if let Ok(dep) = Arc::try_unwrap(dep) {
                dep.shutdown();
            }
        }
        names
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, ClusterInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Move one session's table entry from `mv.from` to `mv.to`.  TTL
    /// travels as remaining lifetime (each deployment runs its own
    /// clock); epoch and control key are copied verbatim — same-track
    /// siblings share the key root, so the client notices nothing.
    fn migrate(g: &mut ClusterInner, mv: &SessionMove) {
        let (Some(from), Some(to)) = (g.members.get(&mv.from), g.members.get(&mv.to)) else {
            return;
        };
        if let Some(snap) = from.sessions().export(mv.session, from.now_ms()) {
            to.sessions().adopt(snap, to.now_ms());
            from.sessions().unbind(mv.session);
        }
    }

    /// Route a session-scoped call to its member, migrating state if
    /// the route performed a drain move.
    fn member_for(
        &self,
        session: u64,
    ) -> std::result::Result<Arc<Deployment>, RouteError> {
        let now = self.now_ms();
        let mut g = self.lock();
        let (name, mv) = g.plan.route(session, now)?;
        if let Some(mv) = mv {
            Self::migrate(&mut g, &mv);
            g.moves.push(mv);
        }
        g.members.get(&name).cloned().ok_or(RouteError::NoCapacity)
    }

    /// The member already holding `session`, bypassing routing (for
    /// read-only session lookups on unpinned ids).
    fn member_holding(&self, session: u64) -> Option<Arc<Deployment>> {
        let g = self.lock();
        if let Some(name) = g.plan.pin_of(session) {
            return g.members.get(name).cloned();
        }
        let mut names: Vec<&String> = g.members.keys().collect();
        names.sort();
        for name in names {
            let dep = &g.members[name];
            if dep.sessions().contains(session) {
                return Some(dep.clone());
            }
        }
        None
    }
}

impl Frontend for ClusterRouter {
    fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> std::result::Result<Channel<InferResponse>, AdmissionError> {
        let dep = self.member_for(session).map_err(|e| match e {
            // typed isolation/capacity loss surfaces as unavailability —
            // retryable, never a corrupt answer
            RouteError::Isolated { .. } | RouteError::NoSibling { .. } | RouteError::NoCapacity => {
                AdmissionError::Unavailable {
                    model: model.to_string(),
                }
            }
        })?;
        dep.submit(model, ciphertext, session)
    }

    fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let dep = self
            .member_for(session)
            .map_err(|e| anyhow!("cluster route failed: {e}"))?;
        dep.infer_blocking(model, ciphertext, session)
    }

    fn has_model(&self, model: &str) -> bool {
        let g = self.lock();
        g.members.values().any(|d| d.has_model(model))
    }

    fn models(&self) -> Vec<String> {
        let g = self.lock();
        let mut v: Vec<String> = g
            .members
            .values()
            .flat_map(|d| d.models())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn session_ttl_ms(&self) -> u64 {
        let g = self.lock();
        let mut names: Vec<&String> = g.members.keys().collect();
        names.sort();
        names
            .first()
            .map(|n| g.members[*n].sessions().ttl_ms())
            .unwrap_or(0)
    }

    fn establish_session(&self, model: &str, auth: [u8; 32]) -> SessionGrant {
        // spread establishes round-robin over nodes serving the model,
        // then pin the minted id where it landed
        let nth = self.next_establish.fetch_add(1, Ordering::Relaxed);
        let (name, dep) = {
            let g = self.lock();
            let mut serving: Vec<(&String, &Arc<Deployment>)> = g
                .members
                .iter()
                .filter(|(name, d)| {
                    d.has_model(model)
                        && g.plan.health(name) == Some(NodeHealth::Healthy)
                })
                .collect();
            serving.sort_by(|a, b| a.0.cmp(b.0));
            if serving.is_empty() {
                // degenerate: no healthy server — fall back to any
                // member so the grant is at least well-formed
                let mut all: Vec<(&String, &Arc<Deployment>)> = g.members.iter().collect();
                all.sort_by(|a, b| a.0.cmp(b.0));
                let (name, dep) = all[(nth as usize) % all.len().max(1)];
                (name.clone(), dep.clone())
            } else {
                let (name, dep) = serving[(nth as usize) % serving.len()];
                (name.clone(), dep.clone())
            }
        };
        let grant = dep.establish_session(model, auth);
        self.lock().plan.pin(grant.session, &name);
        grant
    }

    fn refresh_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<SessionGrant, SessionError> {
        let dep = self
            .member_holding(session)
            .ok_or(SessionError::Unknown { session })?;
        dep.refresh_session_authed(session, tag)
    }

    fn revoke_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<bool, SessionError> {
        let dep = self
            .member_holding(session)
            .ok_or(SessionError::Unknown { session })?;
        let revoked = dep.revoke_session_authed(session, tag)?;
        if revoked {
            self.lock().plan.unpin(session);
        }
        Ok(revoked)
    }

    fn session_epoch(&self, session: u64) -> std::result::Result<u32, SessionError> {
        let dep = self
            .member_holding(session)
            .ok_or(SessionError::Unknown { session })?;
        dep.session_epoch(session)
    }

    fn bound_model(&self, session: u64) -> Option<String> {
        let dep = self.member_holding(session)?;
        dep.sessions().bound_model(session, dep.now_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan3() -> RoutePlan {
        let mut p = RoutePlan::new(ClusterOptions::default());
        p.add_node("a", "prod");
        p.add_node("b", "prod");
        p.add_node("c", "prod");
        p
    }

    #[test]
    fn new_sessions_spread_and_stick() {
        let mut p = plan3();
        let mut seen = std::collections::HashSet::new();
        for s in 0..64u64 {
            let (node, mv) = p.route(s, 0).unwrap();
            assert!(mv.is_none());
            seen.insert(node.clone());
            // sticky: the same session routes to the same node forever
            assert_eq!(p.route(s, 1_000).unwrap().0, node);
        }
        assert_eq!(seen.len(), 3, "64 sessions should touch all 3 nodes");
    }

    #[test]
    fn draining_node_hands_off_on_touch_same_track() {
        let mut p = plan3();
        let (home, _) = p.route(7, 0).unwrap();
        p.mark_failing(&home, 100);
        let (node, mv) = p.route(7, 101).unwrap();
        assert_ne!(node, home);
        let mv = mv.expect("a drain move");
        assert_eq!(mv.from, home);
        assert_eq!(mv.to, node);
        assert_eq!(p.track_of(&node), Some("prod"));
        // moved once — the new pin is sticky
        assert!(p.route(7, 102).unwrap().1.is_none());
    }

    #[test]
    fn tick_migrates_leftovers_after_grace_then_downs_the_node() {
        let mut p = plan3();
        for s in 0..32u64 {
            p.route(s, 0).unwrap();
        }
        let victim = p.pin_of(3).unwrap().to_string();
        let before = p.pinned_to(&victim).len();
        assert!(before > 0);
        p.mark_failing(&victim, 100);
        assert!(p.tick(100).is_empty(), "inside the grace, nothing moves");
        let moves = p.tick(100 + p.options().drain_grace_ms);
        assert_eq!(moves.len(), before);
        assert_eq!(p.health(&victim), Some(NodeHealth::Down));
        assert!(p.pinned_to(&victim).is_empty());
    }

    #[test]
    fn drain_outcome_is_tick_cadence_invariant() {
        // same scenario, three cadences: route-touch drains vs tick
        // drains must land every session on the same final node
        let run = |tick_every: u64| {
            let mut p = plan3();
            for s in 0..24u64 {
                p.route(s, 0).unwrap();
            }
            let victim = p.pin_of(5).unwrap().to_string();
            p.mark_failing(&victim, 10);
            for now in 11..1200 {
                if tick_every > 0 && now % tick_every == 0 {
                    p.tick(now);
                }
                if now % 7 == 0 {
                    let _ = p.route(now % 24, now);
                }
            }
            p.tick(1_200);
            p.digest()
        };
        let d1 = run(1);
        let d50 = run(50);
        let d_never = run(0);
        assert_eq!(d1, d50);
        assert_eq!(d1, d_never);
    }

    #[test]
    fn partition_isolates_never_repins() {
        let mut p = plan3();
        let (home, _) = p.route(9, 0).unwrap();
        // cut `home` off alone: it becomes the minority component
        let others: Vec<String> = p
            .node_names()
            .into_iter()
            .filter(|n| *n != home)
            .collect();
        p.partition(&[others.clone(), vec![home.clone()]]);
        let err = p.route(9, 10).unwrap_err();
        assert_eq!(
            err,
            RouteError::Isolated {
                session: 9,
                node: home.clone()
            }
        );
        // new sessions keep landing on the majority side
        for s in 100..110u64 {
            let (n, _) = p.route(s, 10).unwrap();
            assert!(others.contains(&n));
        }
        // heal: the pin is exactly where it was
        p.heal();
        assert_eq!(p.route(9, 20).unwrap(), (home, None));
    }

    #[test]
    fn no_same_track_sibling_is_a_typed_loss() {
        let mut p = RoutePlan::new(ClusterOptions::default());
        p.add_node("a", "prod");
        p.add_node("x", "canary");
        let mut on_a = None;
        for s in 0..64u64 {
            let (n, _) = p.route(s, 0).unwrap();
            if n == "a" {
                on_a = Some(s);
                break;
            }
        }
        let s = on_a.expect("some session lands on a");
        p.mark_failing("a", 0);
        // the only other node is a different track: handing the session
        // to it would put it under foreign key material
        assert_eq!(
            p.route(s, 1).unwrap_err(),
            RouteError::NoSibling {
                session: s,
                track: "prod".into()
            }
        );
    }

    #[test]
    fn digest_is_deterministic_and_state_sensitive() {
        let mut a = plan3();
        let mut b = plan3();
        for s in 0..16u64 {
            a.route(s, 0).unwrap();
            b.route(s, 0).unwrap();
        }
        assert_eq!(a.digest(), b.digest());
        a.mark_failing("b", 5);
        assert_ne!(a.digest(), b.digest());
    }
}
