//! Batch scheduler: executes formed batches on a strategy, splitting the
//! batched output back into per-request responses.
//!
//! Requests in one batch are concatenated into a single padded tensor
//! matching an exported artifact batch size; padding rides along and its
//! outputs are discarded (PJRT executables are shape-specialized, so the
//! batcher pads rather than recompiling — the standard serving trick).
//!
//! Two execution shapes:
//! - [`BatchScheduler::execute`] — the serial path: one call runs tier-1
//!   and tier-2 back to back and replies.
//! - [`BatchScheduler::execute_tier1`] + [`Tier2Finisher::finish`] — the
//!   pipelined path the worker pool uses: tier-1 (enclave-bound) yields a
//!   [`Tier2Task`] that any open-device lane can finish, so batch *k+1*'s
//!   tier-1 overlaps batch *k*'s tier-2.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::api::{BatchRecord, InferRequest, InferResponse, LedgerSummary};
use crate::enclave::cost::Ledger;
use crate::runtime::{Device, StageExecutor};
use crate::strategies::{Strategy, Tier1Output};
use crate::util::arena::{Arena, ArenaStats, TensorArena};

/// Executes batches against one strategy instance.
pub struct BatchScheduler {
    strategy: Box<dyn Strategy>,
    /// Bytes of one plaintext sample (f32 image).
    pub sample_bytes: usize,
    /// Artifact batch sizes available, ascending (e.g. [1, 8]).
    pub artifact_batches: Vec<usize>,
    /// Recycles the concatenated-ciphertext batch buffer: steady-state
    /// batch assembly reuses one size-classed allocation per shape.
    cipher_arena: Arena<u8>,
}

impl BatchScheduler {
    pub fn new(
        strategy: Box<dyn Strategy>,
        sample_bytes: usize,
        mut artifact_batches: Vec<usize>,
    ) -> Self {
        artifact_batches.sort_unstable();
        assert!(!artifact_batches.is_empty(), "no artifact batch sizes");
        Self {
            strategy,
            sample_bytes,
            artifact_batches,
            cipher_arena: Arena::new(),
        }
    }

    /// Cumulative cipher-batch arena counters (allocation telemetry).
    pub fn cipher_arena_stats(&self) -> ArenaStats {
        self.cipher_arena.stats()
    }

    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }

    pub fn strategy_mut(&mut self) -> &mut dyn Strategy {
        self.strategy.as_mut()
    }

    /// The strategy's cumulative blinding-factor-pool counters (None for
    /// strategies without a pool).
    pub fn factor_pool_stats(&self) -> Option<crate::blinding::FactorPoolStats> {
        self.strategy.factor_pool_stats()
    }

    /// Smallest exported batch size ≥ n (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        for &b in &self.artifact_batches {
            if b >= n {
                return b;
            }
        }
        *self.artifact_batches.last().unwrap()
    }

    /// Run one formed batch; replies to every request, returns a record.
    pub fn execute(&mut self, mut requests: Vec<InferRequest>) -> Result<BatchRecord> {
        let n = requests.len();
        let exec_batch = self.pick_batch(n);
        // If the queue outran the largest artifact, split recursively and
        // merge the chunks' records — otherwise the tail chunks vanish
        // from queue-wait/latency accounting entirely.
        if n > exec_batch {
            let rest = requests.split_off(exec_batch);
            let mut rec = self.execute(requests)?;
            let tail = self.execute(rest)?;
            rec.batch += tail.batch;
            rec.queue_ms = rec.queue_ms.max(tail.queue_ms);
            rec.exec_wall_ms += tail.exec_wall_ms;
            rec.sim_ms += tail.sim_ms;
            rec.ledger.merge(&tail.ledger);
            return Ok(rec);
        }
        let queue_ms = requests
            .iter()
            .map(|r| r.submitted_at.elapsed().as_secs_f64() * 1e3)
            .fold(0.0, f64::max);

        // Concatenate ciphertexts (each independently encrypted under
        // its own session keystream); pad the batch tail with zeros.
        let sessions: Vec<u64> = requests.iter().map(|r| r.session).collect();
        let mut cipher = self.cipher_arena.take_empty(exec_batch * self.sample_bytes);
        for r in &requests {
            if r.ciphertext.len() != self.sample_bytes {
                let (got, want) = (r.ciphertext.len(), self.sample_bytes);
                let id = r.id;
                self.cipher_arena.give(cipher);
                anyhow::bail!("request {id}: ciphertext {got} bytes, expected {want}");
            }
            cipher.extend_from_slice(&r.ciphertext);
        }
        cipher.resize(exec_batch * self.sample_bytes, 0);

        let mut ledger = Ledger::new();
        let t = Instant::now();
        let result = self
            .strategy
            .infer(&cipher, exec_batch, &sessions, &mut ledger);
        self.cipher_arena.give(cipher);
        let exec_wall_ms = t.elapsed().as_secs_f64() * 1e3;
        let sim_ms = ledger.grand_total_ms();

        match result {
            Ok(probs) => {
                let per = probs.len() / exec_batch;
                for (i, r) in requests.iter().enumerate() {
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: probs[i * per..(i + 1) * per].to_vec(),
                        latency_ms: r.submitted_at.elapsed().as_secs_f64() * 1e3,
                        sim_ms: sim_ms / n as f64,
                        batch: n,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in &requests {
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: vec![],
                        latency_ms: r.submitted_at.elapsed().as_secs_f64() * 1e3,
                        sim_ms: 0.0,
                        batch: n,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
        Ok(BatchRecord {
            batch: n,
            queue_ms,
            exec_wall_ms,
            sim_ms,
            ledger: LedgerSummary::from(&ledger),
        })
    }

    /// Whether this scheduler's strategy supports the tier-1/tier-2 split.
    pub fn tiered(&self) -> bool {
        self.strategy.tiered()
    }

    /// Run only tier-1 of one formed batch, returning the open-tail tasks
    /// (one per artifact-sized sub-batch).  Strategy failures are folded
    /// into the task (`error`) so the finisher still replies and the
    /// batch still produces a record.
    pub fn execute_tier1(
        &mut self,
        mut requests: Vec<InferRequest>,
        home_worker: usize,
    ) -> Result<Vec<Tier2Task>> {
        let n = requests.len();
        let exec_batch = self.pick_batch(n);
        if n > exec_batch {
            let rest = requests.split_off(exec_batch);
            let mut tasks = self.execute_tier1(requests, home_worker)?;
            tasks.extend(self.execute_tier1(rest, home_worker)?);
            return Ok(tasks);
        }
        let queue_ms = requests
            .iter()
            .map(|r| r.submitted_at.elapsed().as_secs_f64() * 1e3)
            .fold(0.0, f64::max);
        let model = requests
            .first()
            .map(|r| r.model.clone())
            .unwrap_or_default();
        let sessions: Vec<u64> = requests.iter().map(|r| r.session).collect();
        let mut cipher = self.cipher_arena.take_empty(exec_batch * self.sample_bytes);
        for r in &requests {
            if r.ciphertext.len() != self.sample_bytes {
                let (got, want) = (r.ciphertext.len(), self.sample_bytes);
                let id = r.id;
                self.cipher_arena.give(cipher);
                anyhow::bail!("request {id}: ciphertext {got} bytes, expected {want}");
            }
            cipher.extend_from_slice(&r.ciphertext);
        }
        cipher.resize(exec_batch * self.sample_bytes, 0);

        let mut ledger = Ledger::new();
        let started = Instant::now();
        let tier1 = self
            .strategy
            .infer_tier1(&cipher, exec_batch, &sessions, &mut ledger);
        self.cipher_arena.give(cipher);
        let task = match tier1 {
            Ok(Tier1Output::Final(probs)) => Tier2Task {
                model,
                requests,
                exec_batch,
                stage: None,
                features: probs,
                ledger,
                queue_ms,
                started,
                home_worker,
                error: None,
                artifact_batches: self.artifact_batches.clone(),
            },
            Ok(Tier1Output::Handoff { features, stage }) => Tier2Task {
                model,
                requests,
                exec_batch,
                stage: Some(stage),
                features,
                ledger,
                queue_ms,
                started,
                home_worker,
                error: None,
                artifact_batches: self.artifact_batches.clone(),
            },
            Err(e) => Tier2Task {
                model,
                requests,
                exec_batch,
                stage: None,
                features: Vec::new(),
                ledger,
                queue_ms,
                started,
                home_worker,
                error: Some(format!("{e:#}")),
                artifact_batches: self.artifact_batches.clone(),
            },
        };
        Ok(vec![task])
    }
}

/// A tier-1-complete batch: everything a peer lane needs to finish it.
///
/// Carries no enclave state — only the plaintext-safe intermediate
/// feature map (already past the privacy partition) and the reply
/// handles, which is exactly why tier-2 tasks may be work-stolen by any
/// worker — or drained by a *shared* multi-tenant lane fabric
/// ([`crate::coordinator::LaneFabric`]) — without moving session keys.
pub struct Tier2Task {
    /// Tenant tag: the model whose tail this is (fabric routing +
    /// weighted-fair accounting).
    pub model: String,
    pub requests: Vec<InferRequest>,
    pub exec_batch: usize,
    /// Open-tail stage to run, or None when `features` are already final.
    pub stage: Option<String>,
    pub features: Vec<f32>,
    /// Tier-1 costs, merged into the batch record at finish time.
    pub ledger: Ledger,
    pub queue_ms: f64,
    /// When tier-1 execution began (end-to-end batch wall clock).
    pub started: Instant,
    /// Worker whose enclave ran tier-1 (affinity audit).
    pub home_worker: usize,
    /// Tier-1 failure, delivered to every request by the finisher.
    pub error: Option<String>,
    /// Batch sizes the model's stages are exported at (ascending) —
    /// tail-batch splitting picks sub-batch shapes from it.  Empty means
    /// "any batch size executes" (test doubles).
    pub artifact_batches: Vec<usize>,
}

impl Tier2Task {
    /// Tail-batch splitting: break this task into chunks of at most
    /// `max_requests` requests each, so a long tail interleaves with
    /// other tenants under the fabric's weighted-fair clock instead of
    /// occupying a lane for its whole batch.
    ///
    /// Bit-safety: every tail stage computes samples independently (both
    /// the reference interpreter and the exported HLO stages are
    /// per-sample maps over the batch axis), so running a sub-range of
    /// the feature map at a smaller exported batch size produces exactly
    /// the bytes the unsplit batch would have produced for those
    /// requests — pinned by `tests/slo_integration.rs`.  Padding samples
    /// added to fill a sub-batch shape are discarded, as in the unsplit
    /// path.
    ///
    /// Final tasks (`stage == None`) and failed tasks are never split:
    /// there is no tail work to chunk.  The tier-1 ledger rides with the
    /// first chunk only, so merged records never double-count enclave
    /// time.
    pub fn split(self, max_requests: usize) -> Vec<Tier2Task> {
        // pass-through arena: identical allocation behaviour to the
        // pre-arena code for callers without a buffer pool
        self.split_into(max_requests, &mut TensorArena::with_retention(0))
    }

    /// [`Tier2Task::split`] drawing chunk feature buffers from `arena`
    /// and recycling the parent feature map into it — the fabric's
    /// steady-state submit path allocates nothing for chunked tails.
    pub fn split_into(self, max_requests: usize, arena: &mut TensorArena) -> Vec<Tier2Task> {
        let n = self.requests.len();
        if max_requests == 0 || n <= max_requests || self.stage.is_none() || self.error.is_some()
        {
            return vec![self];
        }
        // Chunks must map onto exported batch shapes: cap the chunk at
        // the largest exported size so `pick_exported_batch` always
        // finds one (today redundant — n ≤ exec_batch ≤ largest — but
        // it keeps the invariant explicit rather than implicit).
        let max_requests = match self.artifact_batches.last() {
            Some(&largest) => max_requests.min(largest),
            None => max_requests,
        };
        let per = if self.exec_batch > 0 {
            self.features.len() / self.exec_batch
        } else {
            0
        };
        if per == 0 {
            return vec![self];
        }
        let Tier2Task {
            model,
            mut requests,
            exec_batch: _,
            stage,
            features,
            ledger,
            queue_ms,
            started,
            home_worker,
            error: _,
            artifact_batches,
        } = self;
        let mut out = Vec::with_capacity((n + max_requests - 1) / max_requests);
        let mut offset = 0usize; // sample offset into the feature map
        while !requests.is_empty() {
            let take = requests.len().min(max_requests);
            let rest = requests.split_off(take);
            let chunk = std::mem::replace(&mut requests, rest);
            let sub_exec = pick_exported_batch(&artifact_batches, take);
            let mut feats = arena.take_empty(sub_exec * per);
            feats.extend_from_slice(&features[offset * per..(offset + take) * per]);
            feats.resize(sub_exec * per, 0.0);
            offset += take;
            out.push(Tier2Task {
                model: model.clone(),
                requests: chunk,
                exec_batch: sub_exec,
                stage: stage.clone(),
                features: feats,
                ledger: if out.is_empty() {
                    ledger.clone()
                } else {
                    Ledger::new()
                },
                queue_ms,
                started,
                home_worker,
                error: None,
                artifact_batches: artifact_batches.clone(),
            });
        }
        // the parent feature map is fully copied out — recycle it
        arena.give(features);
        out
    }
}

/// Smallest exported batch size ≥ n (n itself when none is exported —
/// the reference backend and test doubles accept any batch).
fn pick_exported_batch(batches: &[usize], n: usize) -> usize {
    for &b in batches {
        if b >= n {
            return b;
        }
    }
    n
}

/// Finishes [`Tier2Task`]s on an open device: runs the tail stage,
/// splits the batched output into per-request responses, replies.
///
/// Holds only an executor + device — no enclave, no keys — so the pool
/// creates one per tier-2 lane and lets lanes steal freely.
pub struct Tier2Finisher {
    executor: Arc<StageExecutor>,
    model: String,
    device: Device,
}

impl Tier2Finisher {
    pub fn new(executor: Arc<StageExecutor>, model: &str, device: Device) -> Self {
        Self {
            executor,
            model: model.to_string(),
            device,
        }
    }

    /// Re-pin the finisher to an explicit device.  The lane fabric uses
    /// this to give every lane its *own* device cost profile instead of
    /// whatever the model's config inherited — numerics are unchanged
    /// (the modeled GPU runs on the CPU for bits), only the simulated
    /// cost accounting moves.
    pub fn with_device(mut self, device: Device) -> Self {
        self.device = device;
        self
    }

    /// The device this finisher charges tail stages to.
    pub fn device(&self) -> Device {
        self.device
    }

    /// The model this finisher can finish tails for.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Finish one task. The outcome's `record.sim_ms` covers both tiers;
    /// `tier2_sim_ms` is the tier-2 share alone (lane accounting).
    pub fn finish(&self, task: Tier2Task) -> FinishOutcome {
        let Tier2Task {
            requests,
            exec_batch,
            stage,
            features,
            ledger: mut total,
            queue_ms,
            started,
            error,
            ..
        } = task;
        let n = requests.len();
        let mut tier2_ms = 0.0;
        let mut spent_features = None;
        let outcome: Result<Vec<f32>> = match (error, stage) {
            (Some(msg), _) => Err(anyhow::anyhow!(msg)),
            (None, None) => Ok(features),
            (None, Some(stage)) => {
                let mut t2 = Ledger::new();
                let r = self
                    .executor
                    .run(&self.model, &stage, exec_batch, &[&features], self.device, &mut t2)
                    .map(|out| out.data);
                tier2_ms = t2.grand_total_ms();
                total.merge(&t2);
                // tail ran: the input feature map is spent — hand it back
                // so the caller can recycle it into its arena
                spent_features = Some(features);
                r
            }
        };
        let sim_ms = total.grand_total_ms();
        let ok = outcome.is_ok();
        let mut latencies_ms = Vec::with_capacity(n);
        match outcome {
            Ok(probs) => {
                let per = probs.len() / exec_batch;
                for (i, r) in requests.iter().enumerate() {
                    let latency_ms = r.submitted_at.elapsed().as_secs_f64() * 1e3;
                    latencies_ms.push(latency_ms);
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: probs[i * per..(i + 1) * per].to_vec(),
                        latency_ms,
                        sim_ms: sim_ms / n as f64,
                        batch: n,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in &requests {
                    let latency_ms = r.submitted_at.elapsed().as_secs_f64() * 1e3;
                    latencies_ms.push(latency_ms);
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: vec![],
                        latency_ms,
                        sim_ms: 0.0,
                        batch: n,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
        FinishOutcome {
            record: BatchRecord {
                batch: n,
                queue_ms,
                exec_wall_ms: started.elapsed().as_secs_f64() * 1e3,
                sim_ms,
                ledger: LedgerSummary::from(&total),
            },
            tier2_sim_ms: tier2_ms,
            ok,
            latencies_ms,
            spent_features,
        }
    }
}

/// What finishing a [`Tier2Task`] produced.
pub struct FinishOutcome {
    pub record: BatchRecord,
    /// Simulated ms spent in the tier-2 tail alone.
    pub tier2_sim_ms: f64,
    /// False when the batch failed (tier-1 or tail error).
    pub ok: bool,
    /// Client-visible latency of each request in the batch at reply
    /// time (wall ms) — the samples SLO telemetry records.
    pub latencies_ms: Vec<f64>,
    /// The task's feature-map buffer when a tail stage consumed it —
    /// Some only on that path; callers `give` it back to their arena.
    pub spent_features: Option<Vec<f32>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Strategy double: echoes batch/softmax-like outputs.
    struct FakeStrategy {
        classes: usize,
        fail: bool,
    }

    impl Strategy for FakeStrategy {
        fn name(&self) -> String {
            "fake".into()
        }

        fn setup(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(
            &mut self,
            ciphertext: &[u8],
            batch: usize,
            _sessions: &[u64],
            ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            if self.fail {
                anyhow::bail!("boom");
            }
            ledger.add_measured(crate::enclave::cost::Cat::DeviceCompute, 1_000_000);
            assert_eq!(ciphertext.len() % batch, 0);
            Ok(vec![1.0 / self.classes as f32; batch * self.classes])
        }

        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    fn sched(fail: bool) -> BatchScheduler {
        BatchScheduler::new(
            Box::new(FakeStrategy { classes: 10, fail }),
            16,
            vec![1, 8],
        )
    }

    fn req(id: u64) -> (InferRequest, crate::util::threadpool::Channel<InferResponse>) {
        InferRequest::new(id, "m", vec![0u8; 16], 3)
    }

    #[test]
    fn pick_batch_rounds_up() {
        let s = sched(false);
        assert_eq!(s.pick_batch(1), 1);
        assert_eq!(s.pick_batch(2), 8);
        assert_eq!(s.pick_batch(8), 8);
        assert_eq!(s.pick_batch(20), 8);
    }

    #[test]
    fn batch_of_three_pads_to_eight_and_splits_output() {
        let mut s = sched(false);
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        let (r3, c3) = req(3);
        let rec = s.execute(vec![r1, r2, r3]).unwrap();
        assert_eq!(rec.batch, 3);
        for c in [c1, c2, c3] {
            let resp = c.recv().unwrap();
            assert_eq!(resp.probs.len(), 10);
            assert!(resp.error.is_none());
            assert_eq!(resp.batch, 3);
        }
        assert!(rec.sim_ms >= 1.0);
    }

    #[test]
    fn oversized_queue_splits_across_executions() {
        let mut s = sched(false);
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 0..11 {
            let (r, c) = req(i);
            reqs.push(r);
            chans.push(c);
        }
        s.execute(reqs).unwrap();
        for c in chans {
            assert!(c.recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn oversized_queue_merges_tail_records() {
        let mut s = sched(false);
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 0..11 {
            let (r, c) = req(i);
            reqs.push(r);
            chans.push(c);
        }
        let rec = s.execute(reqs).unwrap();
        // 11 requests over [1, 8] artifacts run as 8 + 3: the record must
        // cover both chunks (the tail used to be dropped on the floor).
        assert_eq!(rec.batch, 11);
        assert!(
            rec.sim_ms > 1.5,
            "both chunks' ledgers summed (1 ms each), got {}",
            rec.sim_ms
        );
        assert!(rec.ledger.measured_ms > 1.5, "ledger summary summed");
        assert!(rec.queue_ms >= 0.0);
        assert!(rec.exec_wall_ms >= 0.0);
        for c in chans {
            assert!(c.recv().unwrap().error.is_none());
        }
    }

    /// Strategy double recording exactly what the scheduler hands it.
    struct RecordingStrategy {
        classes: usize,
        #[allow(clippy::type_complexity)]
        seen: std::rc::Rc<std::cell::RefCell<Vec<(usize, usize, Vec<u8>)>>>,
    }

    impl Strategy for RecordingStrategy {
        fn name(&self) -> String {
            "recording".into()
        }

        fn setup(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(
            &mut self,
            ciphertext: &[u8],
            batch: usize,
            sessions: &[u64],
            _ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            self.seen
                .borrow_mut()
                .push((batch, sessions.len(), ciphertext.to_vec()));
            Ok(vec![0.0; batch * self.classes])
        }

        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    #[test]
    fn padded_tail_never_extends_sessions_or_keystream() {
        let seen = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut s = BatchScheduler::new(
            Box::new(RecordingStrategy {
                classes: 10,
                seen: seen.clone(),
            }),
            16,
            vec![1, 8],
        );
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 1..=3u64 {
            let (mut r, c) = req(i);
            r.session = 100 + i;
            r.ciphertext = vec![i as u8; 16];
            reqs.push(r);
            chans.push(c);
        }
        s.execute(reqs).unwrap();
        let calls = seen.borrow();
        assert_eq!(calls.len(), 1);
        let (batch, n_sessions, cipher) = &calls[0];
        assert_eq!(*batch, 8, "3 requests pad up to the batch-8 artifact");
        assert_eq!(
            *n_sessions, 3,
            "sessions cover only real samples — padding slots have no session entry"
        );
        assert_eq!(cipher.len(), 8 * 16);
        // The padded tail is zero bytes: it must never be filled by
        // advancing (and thus consuming) any session's keystream.
        assert!(
            cipher[3 * 16..].iter().all(|&b| b == 0),
            "padding must be zeroed, not keystream-derived"
        );
        assert_eq!(&cipher[..16], &[1u8; 16][..], "real samples pass through");
        assert_eq!(&cipher[16..32], &[2u8; 16][..]);
        assert_eq!(&cipher[32..48], &[3u8; 16][..]);
        drop(calls);
        for c in chans {
            assert!(c.recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn failures_propagate_to_every_request() {
        let mut s = sched(true);
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        s.execute(vec![r1, r2]).unwrap();
        assert!(c1.recv().unwrap().error.is_some());
        assert!(c2.recv().unwrap().error.is_some());
    }

    #[test]
    fn wrong_sized_ciphertext_rejected() {
        let mut s = sched(false);
        let (mut r, _c) = req(1);
        r.ciphertext = vec![0u8; 7];
        assert!(s.execute(vec![r]).is_err());
    }

    fn finisher() -> Tier2Finisher {
        let rb = Arc::new(
            crate::runtime::ReferenceBackend::vgg_lite("sim8", 1).unwrap(),
        );
        let ex = Arc::new(StageExecutor::reference(
            rb,
            crate::enclave::cost::CostModel::default(),
        ));
        Tier2Finisher::new(ex, "sim8", Device::UntrustedCpu)
    }

    #[test]
    fn tier1_plus_finish_replies_like_execute() {
        let mut s = sched(false);
        assert!(!s.tiered(), "fake strategy has no open tail");
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        let tasks = s.execute_tier1(vec![r1, r2], 3).unwrap();
        assert_eq!(tasks.len(), 1);
        assert_eq!(tasks[0].home_worker, 3);
        assert!(tasks[0].stage.is_none(), "non-tiered → Final task");
        let fin = finisher();
        let out = fin.finish(tasks.into_iter().next().unwrap());
        assert!(out.ok);
        assert_eq!(out.record.batch, 2);
        assert_eq!(out.tier2_sim_ms, 0.0, "no tail stage ran");
        assert!(out.record.sim_ms >= 1.0, "tier-1 ledger carried into the record");
        for c in [c1, c2] {
            let resp = c.recv().unwrap();
            assert!(resp.error.is_none());
            assert_eq!(resp.probs.len(), 10);
            assert_eq!(resp.batch, 2);
        }
    }

    #[test]
    fn tier1_splits_oversized_batches() {
        let mut s = sched(false);
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 0..11 {
            let (r, c) = req(i);
            reqs.push(r);
            chans.push(c);
        }
        let tasks = s.execute_tier1(reqs, 0).unwrap();
        assert_eq!(tasks.len(), 2, "11 reqs over [1,8] artifacts → 8 + 3");
        let fin = finisher();
        for t in tasks {
            fin.finish(t);
        }
        for c in chans {
            assert!(c.recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn split_chunks_requests_and_feature_map_consistently() {
        // A tiered 8-request task over a 2-wide feature map splits into
        // 3-request chunks whose features are the matching sample rows.
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 0..8 {
            let (r, c) = req(i);
            reqs.push(r);
            chans.push(c);
        }
        let features: Vec<f32> = (0..16).map(|v| v as f32).collect(); // 8 samples × 2
        let task = Tier2Task {
            model: "m".into(),
            requests: reqs,
            exec_batch: 8,
            stage: Some("tail_p02".into()),
            features,
            ledger: {
                let mut l = Ledger::new();
                l.add_measured(crate::enclave::cost::Cat::Blind, 2_000_000);
                l
            },
            queue_ms: 1.5,
            started: Instant::now(),
            home_worker: 4,
            error: None,
            artifact_batches: vec![1, 2, 4, 8],
        };
        let parts = task.split(3);
        assert_eq!(parts.len(), 3, "8 requests at chunk 3 → 3+3+2");
        assert_eq!(parts[0].requests.len(), 3);
        assert_eq!(parts[1].requests.len(), 3);
        assert_eq!(parts[2].requests.len(), 2);
        // sub-batches round up to exported sizes, features padded to fit
        assert_eq!(parts[0].exec_batch, 4);
        assert_eq!(parts[0].features.len(), 8);
        assert_eq!(&parts[0].features[..6], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(&parts[0].features[6..], &[0.0, 0.0], "padding is zeroed");
        assert_eq!(&parts[1].features[..6], &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(parts[2].exec_batch, 2);
        assert_eq!(&parts[2].features[..], &[12.0, 13.0, 14.0, 15.0]);
        // request order preserved end to end
        let ids: Vec<u64> = parts.iter().flat_map(|p| p.requests.iter().map(|r| r.id)).collect();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        // tier-1 ledger rides with the first chunk only
        assert!(parts[0].ledger.grand_total_ms() > 0.0);
        assert_eq!(parts[1].ledger.grand_total_ms(), 0.0);
        assert_eq!(parts[2].ledger.grand_total_ms(), 0.0);
        for p in &parts {
            assert_eq!(p.stage.as_deref(), Some("tail_p02"));
            assert_eq!(p.home_worker, 4);
            assert_eq!(p.queue_ms, 1.5);
        }
    }

    fn two_wide_task(reqs: Vec<InferRequest>) -> Tier2Task {
        let n = reqs.len();
        Tier2Task {
            model: "m".into(),
            requests: reqs,
            exec_batch: n,
            stage: Some("tail_p02".into()),
            features: (0..2 * n).map(|v| v as f32).collect(),
            ledger: Ledger::new(),
            queue_ms: 0.0,
            started: Instant::now(),
            home_worker: 0,
            error: None,
            artifact_batches: vec![1, 2, 4, 8],
        }
    }

    #[test]
    fn split_into_recycles_buffers_across_batches() {
        let mut arena = TensorArena::new();
        let mk = || {
            let mut reqs = Vec::new();
            for i in 0..8 {
                // replies are never sent here — tasks are only split
                let (r, _c) = req(i);
                reqs.push(r);
            }
            two_wide_task(reqs)
        };
        // warmup: split once and recycle the chunks, as the fabric does
        // after each tail finishes
        let parts = mk().split_into(3, &mut arena);
        assert_eq!(parts.len(), 3);
        for p in parts {
            arena.give(p.features);
        }
        // parent feature map + 3 chunk buffers all came back
        assert!(arena.pooled() >= 4, "pooled {}", arena.pooled());
        let fresh_after_warmup = arena.stats().fresh;
        // a second identical batch draws every chunk from the pool
        let parts2 = mk().split_into(3, &mut arena);
        let s = arena.stats();
        assert!(s.hits >= 3, "chunks served from the pool (hits {})", s.hits);
        assert_eq!(
            s.fresh, fresh_after_warmup,
            "steady-state splitting allocates nothing"
        );
        // chunk contents are unchanged by pooling
        assert_eq!(&parts2[0].features[..6], &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(&parts2[0].features[6..8], &[0.0, 0.0], "padding re-zeroed");
    }

    #[test]
    fn cipher_batch_buffer_is_reused_across_executions() {
        let mut s = sched(false);
        for round in 0..4 {
            let (r, c) = req(round);
            s.execute(vec![r]).unwrap();
            assert!(c.recv().unwrap().error.is_none());
        }
        let stats = s.cipher_arena_stats();
        assert_eq!(stats.takes, 4);
        assert_eq!(stats.fresh, 1, "one allocation serves every batch");
        assert_eq!(stats.hits, 3);
    }

    #[test]
    fn finish_returns_spent_features_only_when_a_tail_ran() {
        let fin = finisher();
        // Final task (no stage): features ARE the result — never spent
        let mut s = sched(false);
        let (r1, c1) = req(1);
        let tasks = s.execute_tier1(vec![r1], 0).unwrap();
        let out = fin.finish(tasks.into_iter().next().unwrap());
        assert!(out.spent_features.is_none());
        assert!(c1.recv().unwrap().error.is_none());
    }

    #[test]
    fn split_leaves_small_final_and_failed_tasks_alone() {
        let mut s = sched(false);
        let (r1, _c1) = req(1);
        let (r2, _c2) = req(2);
        let tasks = s.execute_tier1(vec![r1, r2], 0).unwrap();
        let task = tasks.into_iter().next().unwrap();
        assert!(task.stage.is_none(), "fake strategy yields Final tasks");
        let parts = task.split(1);
        assert_eq!(parts.len(), 1, "Final tasks are never split");

        let mut s = sched(true);
        let (r1, _c1) = req(1);
        let (r2, _c2) = req(2);
        let tasks = s.execute_tier1(vec![r1, r2], 0).unwrap();
        let task = tasks.into_iter().next().unwrap();
        assert!(task.error.is_some());
        let parts = task.split(1);
        assert_eq!(parts.len(), 1, "failed tasks are never split");

        // chunk 0 disables splitting outright
        let mut s = sched(false);
        let (r1, _c1) = req(1);
        let tasks = s.execute_tier1(vec![r1], 0).unwrap();
        let parts = tasks.into_iter().next().unwrap().split(0);
        assert_eq!(parts.len(), 1);
    }

    #[test]
    fn tier1_failure_reaches_every_request_via_finisher() {
        let mut s = sched(true);
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        let tasks = s.execute_tier1(vec![r1, r2], 0).unwrap();
        assert_eq!(tasks.len(), 1);
        assert!(tasks[0].error.is_some());
        let fin = finisher();
        let out = fin.finish(tasks.into_iter().next().unwrap());
        assert!(!out.ok);
        assert_eq!(out.record.batch, 2);
        assert!(c1.recv().unwrap().error.is_some());
        assert!(c2.recv().unwrap().error.is_some());
    }
}
