//! Batch scheduler: executes formed batches on a strategy, splitting the
//! batched output back into per-request responses.
//!
//! Requests in one batch are concatenated into a single padded tensor
//! matching an exported artifact batch size; padding rides along and its
//! outputs are discarded (PJRT executables are shape-specialized, so the
//! batcher pads rather than recompiling — the standard serving trick).

use std::time::Instant;

use anyhow::Result;

use super::api::{BatchRecord, InferRequest, InferResponse, LedgerSummary};
use crate::enclave::cost::Ledger;
use crate::strategies::Strategy;

/// Executes batches against one strategy instance.
pub struct BatchScheduler {
    strategy: Box<dyn Strategy>,
    /// Bytes of one plaintext sample (f32 image).
    pub sample_bytes: usize,
    /// Artifact batch sizes available, ascending (e.g. [1, 8]).
    pub artifact_batches: Vec<usize>,
}

impl BatchScheduler {
    pub fn new(
        strategy: Box<dyn Strategy>,
        sample_bytes: usize,
        mut artifact_batches: Vec<usize>,
    ) -> Self {
        artifact_batches.sort_unstable();
        assert!(!artifact_batches.is_empty(), "no artifact batch sizes");
        Self {
            strategy,
            sample_bytes,
            artifact_batches,
        }
    }

    pub fn strategy_name(&self) -> String {
        self.strategy.name()
    }

    pub fn strategy_mut(&mut self) -> &mut dyn Strategy {
        self.strategy.as_mut()
    }

    /// Smallest exported batch size ≥ n (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        for &b in &self.artifact_batches {
            if b >= n {
                return b;
            }
        }
        *self.artifact_batches.last().unwrap()
    }

    /// Run one formed batch; replies to every request, returns a record.
    pub fn execute(&mut self, mut requests: Vec<InferRequest>) -> Result<BatchRecord> {
        let n = requests.len();
        let exec_batch = self.pick_batch(n);
        // If the queue outran the largest artifact, split recursively.
        if n > exec_batch {
            let rest = requests.split_off(exec_batch);
            let rec = self.execute(requests)?;
            let _ = self.execute(rest)?;
            return Ok(rec);
        }
        let queue_ms = requests
            .iter()
            .map(|r| r.submitted_at.elapsed().as_secs_f64() * 1e3)
            .fold(0.0, f64::max);

        // Concatenate ciphertexts (each independently encrypted under
        // its own session keystream); pad the batch tail with zeros.
        let sessions: Vec<u64> = requests.iter().map(|r| r.session).collect();
        let mut cipher = Vec::with_capacity(exec_batch * self.sample_bytes);
        for r in &requests {
            anyhow::ensure!(
                r.ciphertext.len() == self.sample_bytes,
                "request {}: ciphertext {} bytes, expected {}",
                r.id,
                r.ciphertext.len(),
                self.sample_bytes
            );
            cipher.extend_from_slice(&r.ciphertext);
        }
        cipher.resize(exec_batch * self.sample_bytes, 0);

        let mut ledger = Ledger::new();
        let t = Instant::now();
        let result = self
            .strategy
            .infer(&cipher, exec_batch, &sessions, &mut ledger);
        let exec_wall_ms = t.elapsed().as_secs_f64() * 1e3;
        let sim_ms = ledger.grand_total_ms();

        match result {
            Ok(probs) => {
                let per = probs.len() / exec_batch;
                for (i, r) in requests.iter().enumerate() {
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: probs[i * per..(i + 1) * per].to_vec(),
                        latency_ms: r.submitted_at.elapsed().as_secs_f64() * 1e3,
                        sim_ms: sim_ms / n as f64,
                        batch: n,
                        error: None,
                    });
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in &requests {
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        probs: vec![],
                        latency_ms: r.submitted_at.elapsed().as_secs_f64() * 1e3,
                        sim_ms: 0.0,
                        batch: n,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
        Ok(BatchRecord {
            batch: n,
            queue_ms,
            exec_wall_ms,
            sim_ms,
            ledger: LedgerSummary::from(&ledger),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Strategy double: echoes batch/softmax-like outputs.
    struct FakeStrategy {
        classes: usize,
        fail: bool,
    }

    impl Strategy for FakeStrategy {
        fn name(&self) -> String {
            "fake".into()
        }

        fn setup(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(
            &mut self,
            ciphertext: &[u8],
            batch: usize,
            _sessions: &[u64],
            ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            if self.fail {
                anyhow::bail!("boom");
            }
            ledger.add_measured(crate::enclave::cost::Cat::DeviceCompute, 1_000_000);
            assert_eq!(ciphertext.len() % batch, 0);
            Ok(vec![1.0 / self.classes as f32; batch * self.classes])
        }

        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    fn sched(fail: bool) -> BatchScheduler {
        BatchScheduler::new(
            Box::new(FakeStrategy { classes: 10, fail }),
            16,
            vec![1, 8],
        )
    }

    fn req(id: u64) -> (InferRequest, crate::util::threadpool::Channel<InferResponse>) {
        InferRequest::new(id, "m", vec![0u8; 16], 3)
    }

    #[test]
    fn pick_batch_rounds_up() {
        let s = sched(false);
        assert_eq!(s.pick_batch(1), 1);
        assert_eq!(s.pick_batch(2), 8);
        assert_eq!(s.pick_batch(8), 8);
        assert_eq!(s.pick_batch(20), 8);
    }

    #[test]
    fn batch_of_three_pads_to_eight_and_splits_output() {
        let mut s = sched(false);
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        let (r3, c3) = req(3);
        let rec = s.execute(vec![r1, r2, r3]).unwrap();
        assert_eq!(rec.batch, 3);
        for c in [c1, c2, c3] {
            let resp = c.recv().unwrap();
            assert_eq!(resp.probs.len(), 10);
            assert!(resp.error.is_none());
            assert_eq!(resp.batch, 3);
        }
        assert!(rec.sim_ms >= 1.0);
    }

    #[test]
    fn oversized_queue_splits_across_executions() {
        let mut s = sched(false);
        let mut reqs = Vec::new();
        let mut chans = Vec::new();
        for i in 0..11 {
            let (r, c) = req(i);
            reqs.push(r);
            chans.push(c);
        }
        s.execute(reqs).unwrap();
        for c in chans {
            assert!(c.recv().unwrap().error.is_none());
        }
    }

    #[test]
    fn failures_propagate_to_every_request() {
        let mut s = sched(true);
        let (r1, c1) = req(1);
        let (r2, c2) = req(2);
        s.execute(vec![r1, r2]).unwrap();
        assert!(c1.recv().unwrap().error.is_some());
        assert!(c2.recv().unwrap().error.is_some());
    }

    #[test]
    fn wrong_sized_ciphertext_rejected() {
        let mut s = sched(false);
        let (mut r, _c) = req(1);
        r.ciphertext = vec![0u8; 7];
        assert!(s.execute(vec![r]).is_err());
    }
}
