//! The network front door: a length-prefixed binary protocol over TCP
//! in front of the [`Deployment`](super::router::Deployment) registry,
//! with an attested session lifecycle.
//!
//! The paper's deployment model is a *service*: clients encrypt inputs
//! to a remote enclave and the per-session keystream keeps the
//! offloaded computation blind.  This module puts an actual wire on
//! that story — std `TcpListener` + thread-per-connection, no external
//! runtime — and routes every byte through the existing admission gate
//! and telemetry:
//!
//! ```text
//! client                               server
//!   │ ── HELLO {challenge, model} ──────▶ │  quote(measurement,
//!   │                                     │        challenge, ttl)
//!   │ ◀── ATTEST_GRANT {report, session,  │  session = table.establish
//!   │        epoch, ttl, grant MAC} ───── │
//!   │  verify(report): measurement,       │
//!   │  challenge, freshness, MAC;         │
//!   │  derive session key; check grant    │
//!   │ ── INFER {session, epoch, ct} ────▶ │  epoch check → admission
//!   │ ◀── INFER_OK {probs…} ───────────── │  gate → pool → reply
//!   │ ── REFRESH {session, MAC} ────────▶ │  MAC check → epoch += 1,
//!   │ ◀── REFRESHED {epoch, ttl} ──────── │  TTL extends
//! ```
//!
//! Session ids are random draws from the 48-bit attested range (never
//! sequential), and the control frames that steer a session's lifecycle
//! — REFRESH and REVOKE — must carry an HMAC over (frame kind, session,
//! current epoch) under a key derived from the attested session key.
//! Knowing (or guessing) a bare session id therefore lets a remote peer
//! neither revoke another tenant's session nor bump its keystream epoch
//! out from under it.
//!
//! Every frame is `u32 LE length ‖ u8 type ‖ payload`.  Denials are
//! *typed* on the wire ([`Deny`]): the admission gate's `retry_after_ms`
//! hints and the session lifecycle's "expired — refresh to resume"
//! signal survive serialization, so a remote client can implement the
//! same backoff/refresh logic an in-process caller can.
//!
//! Data-plane encryption is the enclave session keystream keyed by the
//! epoch-folded session word ([`crypto::session_word`]); the attested
//! session key MACs the *grant* (session id, epoch, TTL), so a client
//! knows the lifecycle parameters came from the enclave it verified.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::router::{AdmissionError, Frontend};
use super::session::{control_mac, SessionError, CONTROL_REFRESH, CONTROL_REVOKE};
use super::track::TrackRegistry;
use crate::crypto;
use crate::enclave::attestation::{self, Report};
use crate::util::sync::lock_recover;

/// Frames larger than this are a protocol violation (16 MiB).
const MAX_FRAME_BYTES: usize = 16 << 20;

/// Poll interval for the stop flag while a connection idles.
const IDLE_POLL: Duration = Duration::from_millis(100);

/// Once a frame has started arriving, the rest of it must land within
/// this window — a peer that sends half a head and stalls is cut off
/// instead of pinning its connection thread (and server shutdown) on a
/// read that never completes.
const MID_FRAME_DEADLINE: Duration = Duration::from_secs(30);

// Client → server frame types.
const MSG_HELLO: u8 = 0x01;
const MSG_INFER: u8 = 0x02;
const MSG_REFRESH: u8 = 0x03;
const MSG_REVOKE: u8 = 0x04;

// Server → client frame types.
const MSG_ATTEST_GRANT: u8 = 0x81;
const MSG_INFER_OK: u8 = 0x82;
const MSG_DENIED: u8 = 0x83;
const MSG_REFRESHED: u8 = 0x84;
const MSG_REVOKED: u8 = 0x85;

/// Typed denial codes carried on the wire (mirrors [`AdmissionError`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum DenyCode {
    UnknownModel = 1,
    WrongSize = 2,
    SessionCollision = 3,
    Unavailable = 4,
    RateLimited = 5,
    QuotaExceeded = 6,
    Shed = 7,
    SessionExpired = 8,
    Protocol = 9,
    /// A control frame (REFRESH/REVOKE) failed its MAC check: the peer
    /// did not prove possession of the attested session key.
    Unauthorized = 10,
}

impl DenyCode {
    fn from_u8(v: u8) -> Self {
        match v {
            1 => DenyCode::UnknownModel,
            2 => DenyCode::WrongSize,
            3 => DenyCode::SessionCollision,
            4 => DenyCode::Unavailable,
            5 => DenyCode::RateLimited,
            6 => DenyCode::QuotaExceeded,
            7 => DenyCode::Shed,
            8 => DenyCode::SessionExpired,
            10 => DenyCode::Unauthorized,
            _ => DenyCode::Protocol,
        }
    }
}

/// A typed wire denial: the admission gate's backoff hint and the
/// session lifecycle's refresh hint survive the socket.
#[derive(Debug, Clone, PartialEq)]
pub struct Deny {
    pub code: DenyCode,
    /// Client back-off hint, when the denial is load-dependent.
    pub retry_after_ms: Option<u64>,
    /// True when a session refresh (epoch bump) is enough to resume;
    /// false means re-attest (or the denial is not session-related).
    pub refreshable: bool,
    /// Human-readable rendering of the server-side error.
    pub message: String,
}

impl Deny {
    fn of_admission(err: &AdmissionError) -> Self {
        let code = match err {
            AdmissionError::UnknownModel { .. } => DenyCode::UnknownModel,
            AdmissionError::WrongSize { .. } => DenyCode::WrongSize,
            AdmissionError::SessionCollision { .. } => DenyCode::SessionCollision,
            AdmissionError::Unavailable { .. } => DenyCode::Unavailable,
            AdmissionError::RateLimited { .. } => DenyCode::RateLimited,
            AdmissionError::QuotaExceeded { .. } => DenyCode::QuotaExceeded,
            AdmissionError::Shed { .. } => DenyCode::Shed,
            AdmissionError::SessionExpired { .. } => DenyCode::SessionExpired,
        };
        Deny {
            code,
            retry_after_ms: err.retry_after_ms(),
            refreshable: matches!(
                err,
                AdmissionError::SessionExpired {
                    refreshable: true,
                    ..
                }
            ),
            message: err.to_string(),
        }
    }

    fn of_session(err: &SessionError) -> Self {
        match err {
            SessionError::Collision { bound } => Deny {
                code: DenyCode::SessionCollision,
                retry_after_ms: None,
                refreshable: false,
                message: format!("session is bound to model `{bound}`"),
            },
            SessionError::Expired {
                session,
                refreshable,
            } => Deny {
                code: DenyCode::SessionExpired,
                retry_after_ms: None,
                refreshable: *refreshable,
                message: format!("session {session} expired"),
            },
            SessionError::Unknown { session } => Deny {
                code: DenyCode::SessionExpired,
                retry_after_ms: None,
                refreshable: false,
                message: format!("unknown session {session}; re-attest"),
            },
            SessionError::Unauthorized { session } => Deny {
                code: DenyCode::Unauthorized,
                retry_after_ms: None,
                refreshable: false,
                message: format!("session {session}: control frame MAC rejected"),
            },
        }
    }

    fn protocol(msg: &str) -> Self {
        Deny {
            code: DenyCode::Protocol,
            retry_after_ms: None,
            refreshable: false,
            message: msg.to_string(),
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(16 + self.message.len());
        p.push(self.code as u8);
        match self.retry_after_ms {
            Some(ms) => {
                p.push(1);
                p.extend_from_slice(&ms.to_le_bytes());
            }
            None => {
                p.push(0);
                p.extend_from_slice(&0u64.to_le_bytes());
            }
        }
        p.push(self.refreshable as u8);
        put_str(&mut p, &self.message);
        p
    }

    fn decode(c: &mut Cursor<'_>) -> io::Result<Self> {
        let code = DenyCode::from_u8(c.u8()?);
        let has_retry = c.u8()? != 0;
        let retry = c.u64()?;
        let refreshable = c.u8()? != 0;
        let message = c.str()?;
        Ok(Deny {
            code,
            retry_after_ms: has_retry.then_some(retry),
            refreshable,
            message,
        })
    }
}

/// A successful wire inference.
#[derive(Debug, Clone, PartialEq)]
pub struct WireInference {
    pub probs: Vec<f32>,
    pub latency_ms: f64,
    pub sim_ms: f64,
    pub batch: u32,
}

/// Client-side failure modes.
#[derive(Debug)]
pub enum NetError {
    /// Transport failure (connect, read, write, framing).
    Io(io::Error),
    /// The attestation evidence failed verification: wrong measurement,
    /// wrong challenge, stale report, or a bad MAC.
    Attestation(String),
    /// The server denied the request with a typed reason.
    Denied(Deny),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "transport error: {e}"),
            NetError::Attestation(m) => write!(f, "attestation rejected: {m}"),
            NetError::Denied(d) => write!(f, "denied ({:?}): {}", d.code, d.message),
        }
    }
}

impl std::error::Error for NetError {}

impl From<io::Error> for NetError {
    fn from(e: io::Error) -> Self {
        NetError::Io(e)
    }
}

/// Server-side knobs.
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Bind address, e.g. `127.0.0.1:0` (port 0 = ephemeral).
    pub listen: String,
    /// Validity window of issued attestation reports (ms).
    pub attest_ttl_ms: u64,
    /// The enclave measurement the server quotes (MRENCLAVE analogue).
    pub measurement: [u8; 32],
    /// Shared platform MAC key (the quoting-enclave key stand-in).
    pub platform_key: Vec<u8>,
}

impl Default for NetOptions {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:0".into(),
            attest_ttl_ms: 60_000,
            measurement: crypto::sha256(b"origami-enclave-v1"),
            platform_key: b"origami-platform-key".to_vec(),
        }
    }
}

/// The listening front door: accept loop + one thread per connection.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind and start serving `deployment` on `opts.listen`.  The
    /// frontend may be a local [`Deployment`](super::router::Deployment)
    /// or the multi-node [`ClusterRouter`](super::cluster::ClusterRouter)
    /// — the wire cannot tell the difference (an `Arc<Deployment>`
    /// coerces here unchanged).
    pub fn start(deployment: Arc<dyn Frontend>, opts: NetOptions) -> Result<Self> {
        Self::start_with_tracks(deployment, opts, None)
    }

    /// [`NetServer::start`], plus a track registry: the front door then
    /// also answers [`MSG_TRACK_JOIN`](super::track::MSG_TRACK_JOIN)
    /// frames, handing the track keys to attested joiners
    /// (`--track-peers` points a joining node at a member's front door).
    pub fn start_with_tracks(
        deployment: Arc<dyn Frontend>,
        opts: NetOptions,
        tracks: Option<Arc<TrackRegistry>>,
    ) -> Result<Self> {
        let listener = TcpListener::bind(&opts.listen)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let opts = opts.clone();
            std::thread::Builder::new()
                .name("origami-net-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let dep = deployment.clone();
                        let stop_c = stop.clone();
                        let opts_c = opts.clone();
                        let tracks_c = tracks.clone();
                        let handle = std::thread::Builder::new()
                            .name("origami-net-conn".into())
                            .spawn(move || {
                                let _ = serve_connection(
                                    stream,
                                    &dep,
                                    &opts_c,
                                    tracks_c.as_deref(),
                                    &stop_c,
                                );
                            })
                            .expect("spawn connection thread");
                        let mut held = lock_recover(&conns);
                        // Reap connections that already ended, so a
                        // long-running server does not accumulate one
                        // dead JoinHandle per past connection.
                        let mut i = 0;
                        while i < held.len() {
                            if held[i].is_finished() {
                                let _ = held.swap_remove(i).join();
                            } else {
                                i += 1;
                            }
                        }
                        held.push(handle);
                    }
                })
                .expect("spawn accept thread")
        };
        Ok(Self {
            local_addr,
            stop,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, wake idle connections, join every thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the blocking accept() awake
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = lock_recover(&self.conns).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// One connection: handshake-optional frame loop.  A session issued on
/// one connection is valid on any other (the table is the authority),
/// which is what lets a client resume after a refresh or reconnect.
fn serve_connection(
    mut stream: TcpStream,
    dep: &dyn Frontend,
    opts: &NetOptions,
    tracks: Option<&TrackRegistry>,
    stop: &AtomicBool,
) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(IDLE_POLL))?;
    loop {
        let Some((ty, payload)) = read_frame_stoppable(&mut stream, stop)? else {
            return Ok(()); // clean EOF or shutdown
        };
        let mut c = Cursor::new(&payload);
        let reply: io::Result<()> = match ty {
            MSG_HELLO => {
                let challenge = c.u64()?;
                let model = c.str()?;
                handle_hello(&mut stream, dep, opts, challenge, &model)
            }
            MSG_INFER => {
                let session = c.u64()?;
                let epoch = c.u32()?;
                let ciphertext = c.bytes_u32()?;
                handle_infer(&mut stream, dep, session, epoch, ciphertext)
            }
            MSG_REFRESH => {
                let session = c.u64()?;
                let tag = c.arr32()?;
                match dep.refresh_session_authed(session, &tag) {
                    Ok(grant) => {
                        let mut p = Vec::with_capacity(24);
                        p.extend_from_slice(&grant.session.to_le_bytes());
                        p.extend_from_slice(&grant.epoch.to_le_bytes());
                        p.extend_from_slice(&dep.session_ttl_ms().to_le_bytes());
                        write_frame(&mut stream, MSG_REFRESHED, &p)
                    }
                    Err(e) => {
                        write_frame(&mut stream, MSG_DENIED, &Deny::of_session(&e).encode())
                    }
                }
            }
            MSG_REVOKE => {
                let session = c.u64()?;
                let tag = c.arr32()?;
                match dep.revoke_session_authed(session, &tag) {
                    Ok(existed) => write_frame(&mut stream, MSG_REVOKED, &[existed as u8]),
                    Err(e) => {
                        write_frame(&mut stream, MSG_DENIED, &Deny::of_session(&e).encode())
                    }
                }
            }
            super::track::MSG_TRACK_JOIN => match tracks {
                Some(reg) => {
                    // the track handler consumes the framed request
                    // verbatim (it is shared with the in-memory
                    // simulator) — rebuild the frame it was read from
                    let mut frame = Vec::with_capacity(payload.len() + 5);
                    write_frame(&mut frame, ty, &payload)?;
                    let reply = reg.handle_join(&frame, super::track::wall_now_ms());
                    stream.write_all(&reply)?;
                    stream.flush()
                }
                None => write_frame(
                    &mut stream,
                    MSG_DENIED,
                    &Deny::protocol("this node serves no enclave track").encode(),
                ),
            },
            other => write_frame(
                &mut stream,
                MSG_DENIED,
                &Deny::protocol(&format!("unknown frame type {other:#x}")).encode(),
            ),
        };
        reply?;
    }
}

fn handle_hello(
    stream: &mut TcpStream,
    dep: &dyn Frontend,
    opts: &NetOptions,
    challenge: u64,
    model: &str,
) -> io::Result<()> {
    // No evidence, no session state for models this deployment does not
    // serve — an unauthenticated HELLO flood may not grow the table with
    // bindings to arbitrary names.
    if !dep.has_model(model) {
        let deny = Deny {
            code: DenyCode::UnknownModel,
            retry_after_ms: None,
            refreshable: false,
            message: format!("unknown model `{model}`; deployed: {:?}", dep.models()),
        };
        return write_frame(stream, MSG_DENIED, &deny.encode());
    }
    let now_ms = dep.now_ms();
    let report = attestation::quote(
        &opts.platform_key,
        opts.measurement,
        challenge,
        now_ms,
        opts.attest_ttl_ms,
    );
    // The grant rides under the attested session key: a client that
    // verified the report can check the lifecycle parameters were not
    // rewritten in flight.  The same key (via a derived control key)
    // later gates REFRESH/REVOKE frames for this session.
    let sk = attestation::session_key(&opts.platform_key, &report);
    let grant = dep.establish_session(model, control_key(&sk));
    let ttl_ms = dep.session_ttl_ms();
    let grant_tag = grant_mac(&sk, grant.session, grant.epoch, ttl_ms);
    let mut p = Vec::with_capacity(32 + 8 + 8 + 8 + 32 + 8 + 4 + 8 + 32);
    p.extend_from_slice(&report.measurement);
    p.extend_from_slice(&report.challenge.to_le_bytes());
    p.extend_from_slice(&report.issued_at_ms.to_le_bytes());
    p.extend_from_slice(&report.ttl_ms.to_le_bytes());
    p.extend_from_slice(&report.tag);
    p.extend_from_slice(&grant.session.to_le_bytes());
    p.extend_from_slice(&grant.epoch.to_le_bytes());
    p.extend_from_slice(&ttl_ms.to_le_bytes());
    p.extend_from_slice(&grant_tag);
    write_frame(stream, MSG_ATTEST_GRANT, &p)
}

fn handle_infer(
    stream: &mut TcpStream,
    dep: &dyn Frontend,
    session: u64,
    epoch: u32,
    ciphertext: Vec<u8>,
) -> io::Result<()> {
    // Lifecycle gate first: the table is the authority on whether this
    // session may serve and under which keystream epoch.
    let live_epoch = match dep.session_epoch(session) {
        Ok(e) => e,
        Err(e) => {
            return write_frame(stream, MSG_DENIED, &Deny::of_session(&e).encode());
        }
    };
    if epoch != live_epoch {
        let deny = Deny {
            code: DenyCode::SessionExpired,
            retry_after_ms: None,
            refreshable: true,
            message: format!(
                "keystream epoch {epoch} is stale (session is at {live_epoch}); refresh"
            ),
        };
        return write_frame(stream, MSG_DENIED, &deny.encode());
    }
    let Some(model) = dep.bound_model(session) else {
        let deny = Deny::of_session(&SessionError::Unknown { session });
        return write_frame(stream, MSG_DENIED, &deny.encode());
    };
    match dep.submit(&model, ciphertext, session) {
        Ok(reply) => match reply.recv() {
            Some(resp) => {
                if let Some(err) = resp.error {
                    return write_frame(
                        stream,
                        MSG_DENIED,
                        &Deny {
                            code: DenyCode::Unavailable,
                            retry_after_ms: None,
                            refreshable: false,
                            message: err,
                        }
                        .encode(),
                    );
                }
                let mut p = Vec::with_capacity(4 + resp.probs.len() * 4 + 20);
                p.extend_from_slice(&(resp.probs.len() as u32).to_le_bytes());
                for v in &resp.probs {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                p.extend_from_slice(&resp.latency_ms.to_le_bytes());
                p.extend_from_slice(&resp.sim_ms.to_le_bytes());
                p.extend_from_slice(&(resp.batch as u32).to_le_bytes());
                write_frame(stream, MSG_INFER_OK, &p)
            }
            None => write_frame(
                stream,
                MSG_DENIED,
                &Deny::protocol("reply channel closed").encode(),
            ),
        },
        Err(adm) => write_frame(stream, MSG_DENIED, &Deny::of_admission(&adm).encode()),
    }
}

fn grant_mac(session_key: &[u8; 32], session: u64, epoch: u32, ttl_ms: u64) -> [u8; 32] {
    let mut data = b"origami-session-grant".to_vec();
    data.extend_from_slice(&session.to_le_bytes());
    data.extend_from_slice(&epoch.to_le_bytes());
    data.extend_from_slice(&ttl_ms.to_le_bytes());
    crypto::hmac_sha256(session_key, &data)
}

/// The control-frame MAC key both ends derive from the attested session
/// key.  A derived key (not the session key itself) is what the table
/// stores, so session-key material never sits in the session registry.
fn control_key(session_key: &[u8; 32]) -> [u8; 32] {
    crypto::hmac_sha256(session_key, b"origami-session-control")
}

/// Attested client for the wire protocol.
///
/// `connect` runs the full handshake: challenge → report → verify
/// (measurement, challenge, freshness, MAC) → derive the session key →
/// check the grant MAC.  Transport only — the caller encrypts payloads
/// under [`NetClient::session_word`] (the enclave session keystream).
pub struct NetClient {
    stream: TcpStream,
    session: u64,
    epoch: u32,
    session_ttl_ms: u64,
    /// Control-frame MAC key derived from the attested session key;
    /// proves possession on REFRESH/REVOKE.
    control_key: [u8; 32],
    report: Report,
}

impl NetClient {
    /// Handshake against `addr`, binding the new session to `model`.
    /// `expected_measurement` is the enclave the client is willing to
    /// talk to; `challenge` should be fresh per connection.
    pub fn connect(
        addr: &SocketAddr,
        model: &str,
        expected_measurement: &[u8; 32],
        platform_key: &[u8],
        challenge: u64,
    ) -> std::result::Result<Self, NetError> {
        Self::connect_assuming_age(addr, model, expected_measurement, platform_key, challenge, 0)
    }

    /// [`NetClient::connect`] with a floor on how old the client assumes
    /// the returned evidence is.  Freshness is judged on the *client's*
    /// clock: the report cannot predate the HELLO (it echoes our fresh
    /// challenge), so its age is at most the handshake round-trip — the
    /// server-stamped `issued_at_ms` is never trusted as "now".
    /// `min_age_ms` lets tests (and cautious callers) model a report
    /// that sat captured for that long before being presented.
    pub fn connect_assuming_age(
        addr: &SocketAddr,
        model: &str,
        expected_measurement: &[u8; 32],
        platform_key: &[u8],
        challenge: u64,
        min_age_ms: u64,
    ) -> std::result::Result<Self, NetError> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let sent_at = std::time::Instant::now();
        let mut hello = Vec::with_capacity(8 + 2 + model.len());
        hello.extend_from_slice(&challenge.to_le_bytes());
        put_str(&mut hello, model);
        write_frame(&mut stream, MSG_HELLO, &hello)?;
        let (ty, payload) = read_frame(&mut stream)?;
        if ty == MSG_DENIED {
            let mut c = Cursor::new(&payload);
            return Err(NetError::Denied(Deny::decode(&mut c)?));
        }
        if ty != MSG_ATTEST_GRANT {
            return Err(NetError::Io(protocol_err("expected ATTEST_GRANT")));
        }
        let mut c = Cursor::new(&payload);
        let report = Report {
            measurement: c.arr32()?,
            challenge: c.u64()?,
            issued_at_ms: c.u64()?,
            ttl_ms: c.u64()?,
            tag: c.arr32()?,
        };
        let session = c.u64()?;
        let epoch = c.u32()?;
        let session_ttl_ms = c.u64()?;
        let grant_tag = c.arr32()?;
        // The report's age on our clock: it was issued no earlier than
        // the HELLO left, so elapsed-since-HELLO bounds it from above.
        // Folding that into `now` keeps the validity window meaningful
        // even though the server stamps `issued_at_ms` on its own clock
        // — a self-referential check (now = issued_at) would declare any
        // ttl > 0 report fresh forever.
        let age_ms = (sent_at.elapsed().as_millis() as u64).max(min_age_ms);
        let now_ms = report.issued_at_ms.saturating_add(age_ms);
        if !attestation::verify(platform_key, &report, expected_measurement, challenge, now_ms) {
            return Err(NetError::Attestation(
                if !attestation::is_fresh(&report, now_ms) {
                    format!("stale report (ttl {} ms, age ≥ {age_ms} ms)", report.ttl_ms)
                } else if &report.measurement != expected_measurement {
                    "measurement mismatch (wrong enclave)".to_string()
                } else {
                    "bad challenge or MAC".to_string()
                },
            ));
        }
        let sk = attestation::session_key(platform_key, &report);
        if grant_mac(&sk, session, epoch, session_ttl_ms) != grant_tag {
            return Err(NetError::Attestation("grant MAC mismatch".into()));
        }
        Ok(Self {
            stream,
            session,
            epoch,
            session_ttl_ms,
            control_key: control_key(&sk),
            report,
        })
    }

    /// The attested session id.
    pub fn session(&self) -> u64 {
        self.session
    }

    /// The current keystream epoch (bumped by [`NetClient::refresh`]).
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Session TTL the server granted (ms).
    pub fn session_ttl_ms(&self) -> u64 {
        self.session_ttl_ms
    }

    /// The verified attestation report.
    pub fn report(&self) -> &Report {
        &self.report
    }

    /// The word payloads must be encrypted under (feeds the enclave's
    /// session-key derivation and AES-CTR nonce).
    pub fn session_word(&self) -> u64 {
        crypto::session_word(self.session, self.epoch)
    }

    /// One inference round trip.  `ciphertext` must already be
    /// encrypted under [`NetClient::session_word`].
    pub fn infer(&mut self, ciphertext: &[u8]) -> std::result::Result<WireInference, NetError> {
        let mut p = Vec::with_capacity(16 + ciphertext.len());
        p.extend_from_slice(&self.session.to_le_bytes());
        p.extend_from_slice(&self.epoch.to_le_bytes());
        p.extend_from_slice(&(ciphertext.len() as u32).to_le_bytes());
        p.extend_from_slice(ciphertext);
        write_frame(&mut self.stream, MSG_INFER, &p)?;
        let (ty, payload) = read_frame(&mut self.stream)?;
        let mut c = Cursor::new(&payload);
        match ty {
            MSG_INFER_OK => {
                let n = c.u32()? as usize;
                let mut probs = Vec::with_capacity(n);
                for _ in 0..n {
                    probs.push(c.f32()?);
                }
                Ok(WireInference {
                    probs,
                    latency_ms: c.f64()?,
                    sim_ms: c.f64()?,
                    batch: c.u32()?,
                })
            }
            MSG_DENIED => Err(NetError::Denied(Deny::decode(&mut c)?)),
            _ => Err(NetError::Io(protocol_err("expected INFER_OK or DENIED"))),
        }
    }

    /// Refresh the session: bumps the keystream epoch and extends the
    /// TTL.  Subsequent payloads must re-encrypt under the new
    /// [`NetClient::session_word`].
    pub fn refresh(&mut self) -> std::result::Result<u32, NetError> {
        let mut p = Vec::with_capacity(40);
        p.extend_from_slice(&self.session.to_le_bytes());
        p.extend_from_slice(&control_mac(
            &self.control_key,
            CONTROL_REFRESH,
            self.session,
            self.epoch,
        ));
        write_frame(&mut self.stream, MSG_REFRESH, &p)?;
        let (ty, payload) = read_frame(&mut self.stream)?;
        let mut c = Cursor::new(&payload);
        match ty {
            MSG_REFRESHED => {
                let session = c.u64()?;
                let epoch = c.u32()?;
                let ttl = c.u64()?;
                if session != self.session {
                    return Err(NetError::Io(protocol_err("refresh for wrong session")));
                }
                self.epoch = epoch;
                self.session_ttl_ms = ttl;
                Ok(epoch)
            }
            MSG_DENIED => Err(NetError::Denied(Deny::decode(&mut c)?)),
            _ => Err(NetError::Io(protocol_err("expected REFRESHED or DENIED"))),
        }
    }

    /// Revoke the session server-side; returns whether it existed.
    pub fn revoke(&mut self) -> std::result::Result<bool, NetError> {
        let mut p = Vec::with_capacity(40);
        p.extend_from_slice(&self.session.to_le_bytes());
        p.extend_from_slice(&control_mac(
            &self.control_key,
            CONTROL_REVOKE,
            self.session,
            self.epoch,
        ));
        write_frame(&mut self.stream, MSG_REVOKE, &p)?;
        let (ty, payload) = read_frame(&mut self.stream)?;
        let mut c = Cursor::new(&payload);
        match ty {
            MSG_REVOKED => Ok(c.u8()? != 0),
            MSG_DENIED => Err(NetError::Denied(Deny::decode(&mut c)?)),
            _ => Err(NetError::Io(protocol_err("expected REVOKED or DENIED"))),
        }
    }
}

// ---------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------

pub(crate) fn protocol_err(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

pub(crate) fn write_frame(w: &mut impl Write, ty: u8, payload: &[u8]) -> io::Result<()> {
    let len = payload.len() + 1;
    if len > MAX_FRAME_BYTES {
        return Err(protocol_err("frame too large"));
    }
    let mut head = [0u8; 5];
    head[..4].copy_from_slice(&(len as u32).to_le_bytes());
    head[4] = ty;
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking frame read (client side).
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<(u8, Vec<u8>)> {
    let mut head = [0u8; 5];
    r.read_exact(&mut head)?;
    decode_head(&head).and_then(|(ty, len)| {
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        Ok((ty, payload))
    })
}

fn decode_head(head: &[u8; 5]) -> io::Result<(u8, usize)> {
    let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(protocol_err("bad frame length"));
    }
    Ok((head[4], len - 1))
}

/// Server-side frame read under a read timeout: between frames the
/// loop wakes every [`IDLE_POLL`] to check the stop flag; once a frame
/// has started, timeouts keep accumulating bytes.  `Ok(None)` on clean
/// EOF or shutdown.
fn read_frame_stoppable(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> io::Result<Option<(u8, Vec<u8>)>> {
    let mut head = [0u8; 5];
    if !read_exact_stoppable(stream, &mut head, stop, true)? {
        return Ok(None);
    }
    let (ty, len) = decode_head(&head)?;
    let mut payload = vec![0u8; len];
    if !read_exact_stoppable(stream, &mut payload, stop, false)? {
        return Err(protocol_err("connection closed mid-frame"));
    }
    Ok(Some((ty, payload)))
}

/// `read_exact` that tolerates timeouts.  `Ok(false)` when the peer
/// closed (or shutdown was requested) before the first byte of a frame;
/// `interruptible` marks the between-frames idle state where that is a
/// clean exit.  The stop flag is honored at *any* offset — a raised
/// flag mid-frame errors the connection out instead of leaving its
/// thread (and the shutdown join) looping on timeouts — and once a
/// frame has started arriving it must complete within
/// [`MID_FRAME_DEADLINE`], so a peer that stalls after a partial frame
/// is cut off rather than holding the thread forever.
fn read_exact_stoppable(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    interruptible: bool,
) -> io::Result<bool> {
    let mut off = 0;
    // Payload reads are mid-frame from their first byte; head reads
    // only start the clock once a byte arrives.
    let mut started: Option<std::time::Instant> = if interruptible {
        None
    } else {
        Some(std::time::Instant::now())
    };
    while off < buf.len() {
        if stop.load(Ordering::SeqCst) {
            if off == 0 && interruptible {
                return Ok(false);
            }
            return Err(io::Error::new(
                io::ErrorKind::TimedOut,
                "shutdown requested mid-frame",
            ));
        }
        if let Some(t0) = started {
            if t0.elapsed() >= MID_FRAME_DEADLINE {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "peer stalled mid-frame",
                ));
            }
        }
        match stream.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 && interruptible {
                    return Ok(false);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "peer closed mid-frame",
                ));
            }
            Ok(n) => {
                off += n;
                started.get_or_insert_with(std::time::Instant::now);
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

// ---------------------------------------------------------------------
// Payload cursor
// ---------------------------------------------------------------------

pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, off: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            return Err(protocol_err("truncated payload"));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> io::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn arr32(&mut self) -> io::Result<[u8; 32]> {
        Ok(self.take(32)?.try_into().unwrap())
    }

    pub(crate) fn str(&mut self) -> io::Result<String> {
        let len = u16::from_le_bytes(self.take(2)?.try_into().unwrap()) as usize;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| protocol_err("invalid utf-8 string"))
    }

    pub(crate) fn bytes_u32(&mut self) -> io::Result<Vec<u8>> {
        let len = self.u32()? as usize;
        Ok(self.take(len)?.to_vec())
    }
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    out.extend_from_slice(&(bytes.len().min(u16::MAX as usize) as u16).to_le_bytes());
    out.extend_from_slice(&bytes[..bytes.len().min(u16::MAX as usize)]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deny_roundtrips_with_and_without_hints() {
        let with_hint = Deny {
            code: DenyCode::RateLimited,
            retry_after_ms: Some(42),
            refreshable: false,
            message: "slow down".into(),
        };
        let expired = Deny {
            code: DenyCode::SessionExpired,
            retry_after_ms: None,
            refreshable: true,
            message: "session 9 expired".into(),
        };
        let unauthorized = Deny {
            code: DenyCode::Unauthorized,
            retry_after_ms: None,
            refreshable: false,
            message: "session 9: control frame MAC rejected".into(),
        };
        for d in [with_hint, expired, unauthorized] {
            let bytes = d.encode();
            let back = Deny::decode(&mut Cursor::new(&bytes)).unwrap();
            assert_eq!(back, d);
        }
    }

    #[test]
    fn admission_errors_map_to_wire_codes() {
        let rl = AdmissionError::RateLimited {
            model: "m".into(),
            retry_after_ms: 7,
        };
        let d = Deny::of_admission(&rl);
        assert_eq!(d.code, DenyCode::RateLimited);
        assert_eq!(d.retry_after_ms, Some(7));
        let exp = AdmissionError::SessionExpired {
            session: 3,
            refreshable: true,
        };
        let d = Deny::of_admission(&exp);
        assert_eq!(d.code, DenyCode::SessionExpired);
        assert!(d.refreshable);
        assert_eq!(d.retry_after_ms, None);
    }

    #[test]
    fn frame_head_rejects_oversize_and_zero() {
        let mut head = [0u8; 5];
        head[..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_head(&head).is_err(), "zero length");
        head[..4].copy_from_slice(&(MAX_FRAME_BYTES as u32 + 1).to_le_bytes());
        assert!(decode_head(&head).is_err(), "oversize");
        head[..4].copy_from_slice(&5u32.to_le_bytes());
        head[4] = MSG_HELLO;
        assert_eq!(decode_head(&head).unwrap(), (MSG_HELLO, 4));
    }

    #[test]
    fn frame_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, MSG_INFER, b"payload").unwrap();
        let (ty, payload) = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(ty, MSG_INFER);
        assert_eq!(payload, b"payload");
    }

    #[test]
    fn cursor_guards_truncation() {
        let mut c = Cursor::new(&[1, 2, 3]);
        assert_eq!(c.u8().unwrap(), 1);
        assert!(c.u64().is_err(), "only 2 bytes left");
    }

    #[test]
    fn control_key_is_derived_not_the_session_key() {
        let sk = crypto::sha256(b"some session key");
        let ck = control_key(&sk);
        assert_ne!(ck, sk, "the table must never hold raw session-key material");
        assert_eq!(ck, control_key(&sk), "both ends derive the same control key");
    }
}
