//! The serving engine: batcher + worker threads + metrics.
//!
//! Each worker owns a complete [`BatchScheduler`] (strategy + enclave +
//! blinding state).  Workers pull formed batches from the shared
//! [`DynamicBatcher`]; a bounded ingress channel provides backpressure
//! toward clients.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::api::{BatchRecord, InferRequest, InferResponse};
use super::batcher::DynamicBatcher;
use super::scheduler::BatchScheduler;
use crate::util::stats::Summary;
use crate::util::sync::lock_recover;
use crate::util::threadpool::Channel;

/// Aggregated serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exec_wall_ms: Summary,
    pub sim_ms: Summary,
    pub batch_size: Summary,
    pub batches: u64,
    pub requests: u64,
    pub errors: u64,
}

impl Metrics {
    pub fn record(&mut self, rec: &BatchRecord) {
        self.batches += 1;
        self.requests += rec.batch as u64;
        self.queue_ms.record(rec.queue_ms);
        self.exec_wall_ms.record(rec.exec_wall_ms);
        self.sim_ms.record(rec.sim_ms);
        self.batch_size.record(rec.batch as f64);
    }
}

/// A running serving stack for one model+strategy.
pub struct ServingEngine {
    ingress: Channel<InferRequest>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl ServingEngine {
    /// Start `workers` worker threads sharing one batcher.
    ///
    /// `factory` constructs a complete [`BatchScheduler`] *inside* each
    /// worker thread — PJRT handles (the `xla` crate) are `Rc`-backed and
    /// must not cross threads, so every worker owns its own client,
    /// compiled artifacts, enclave and factor pools.  The factory's
    /// setup cost (artifact compilation, factor precompute) is incurred
    /// once per worker at startup, not on the request path.
    pub fn start<F>(workers: usize, max_batch: usize, max_delay_ms: f64, factory: F) -> Self
    where
        F: Fn(usize) -> anyhow::Result<BatchScheduler> + Send + Sync + 'static,
    {
        let ingress: Channel<InferRequest> = Channel::bounded(256);
        let batcher = Arc::new(DynamicBatcher::new(ingress.clone(), max_batch, max_delay_ms));
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let factory = Arc::new(factory);
        let ready = Arc::new(std::sync::Barrier::new(workers.max(1) + 1));
        let handles: Vec<JoinHandle<()>> = (0..workers.max(1))
            .map(|i| {
                let b = batcher.clone();
                let m = metrics.clone();
                let f = factory.clone();
                let r = ready.clone();
                std::thread::Builder::new()
                    .name(format!("origami-serve-{i}"))
                    .spawn(move || {
                        // NOTE: workers share one batcher, so a worker
                        // that fails setup simply exits — its peers keep
                        // serving.  (The WorkerPool differs: per-worker
                        // queues mean a failed shard must keep draining
                        // and erroring, which pool.rs does.)
                        let mut sched = match f(i) {
                            Ok(s) => {
                                r.wait();
                                s
                            }
                            Err(e) => {
                                eprintln!("[serve] worker {i} failed to start: {e:#}");
                                lock_recover(&m).errors += 1;
                                r.wait();
                                return;
                            }
                        };
                        while let Some(batch) = b.next_batch() {
                            // A panicking batch must not take the worker
                            // (or, via a poisoned metrics mutex, the
                            // whole pool) down with it: catch it, reply
                            // a typed error to every rider, and rebuild
                            // the scheduler — its internal state is
                            // suspect after an unwind.
                            let replies: Vec<_> = batch
                                .iter()
                                .map(|q| (q.id, q.reply.clone(), q.submitted_at))
                                .collect();
                            let run = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| sched.execute(batch)),
                            );
                            match run {
                                Ok(Ok(rec)) => lock_recover(&m).record(&rec),
                                Ok(Err(e)) => {
                                    eprintln!("[serve] batch failed: {e:#}");
                                    lock_recover(&m).errors += 1;
                                }
                                Err(_) => {
                                    eprintln!("[serve] worker {i}: batch panicked");
                                    for (id, reply, t0) in replies {
                                        let _ = reply.send(InferResponse {
                                            id,
                                            probs: vec![],
                                            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                            sim_ms: 0.0,
                                            batch: 0,
                                            error: Some("worker panicked".into()),
                                        });
                                    }
                                    lock_recover(&m).errors += 1;
                                    match f(i) {
                                        Ok(s) => sched = s,
                                        Err(e) => {
                                            eprintln!(
                                                "[serve] worker {i} failed to rebuild: {e:#}"
                                            );
                                            return;
                                        }
                                    }
                                }
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        // wait until every worker finished setup so the caller's first
        // request latency doesn't include artifact compilation
        ready.wait();
        Self {
            ingress,
            workers: handles,
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit an encrypted request; returns the reply channel.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let (req, reply) = InferRequest::new(id, model, ciphertext, session);
        self.ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("serving engine is shut down"))?;
        Ok(reply)
    }

    /// Submit and block for the response (records client latency).
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let reply = self.submit(model, ciphertext, session)?;
        let resp = reply
            .recv()
            .ok_or_else(|| anyhow::anyhow!("reply channel closed"))?;
        lock_recover(&self.metrics).latency_ms.record(resp.latency_ms);
        Ok(resp)
    }

    pub fn queue_depth(&self) -> usize {
        self.ingress.len()
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) -> Metrics {
        self.ingress.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        Arc::try_unwrap(std::mem::take(&mut self.metrics))
            .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
            .unwrap_or_default()
    }
}

impl Drop for ServingEngine {
    fn drop(&mut self) {
        self.ingress.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enclave::cost::Ledger;
    use crate::strategies::Strategy;

    /// Detonates on session 666; healthy otherwise.
    struct Grenade;

    impl Strategy for Grenade {
        fn name(&self) -> String {
            "grenade".into()
        }

        fn setup(&mut self) -> Result<()> {
            Ok(())
        }

        fn infer(
            &mut self,
            _ciphertext: &[u8],
            batch: usize,
            sessions: &[u64],
            _ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            if sessions.contains(&666) {
                panic!("injected batch panic");
            }
            Ok(vec![0.5; batch])
        }

        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    #[test]
    fn panicking_batch_replies_and_pool_keeps_draining() {
        let engine = ServingEngine::start(1, 1, 0.0, |_| {
            Ok(BatchScheduler::new(Box::new(Grenade), 16, vec![1]))
        });
        // the grenade batch: the client gets a typed error, not a hang
        // on a dropped reply channel
        let resp = engine.infer_blocking("m", vec![0u8; 16], 666).unwrap();
        assert_eq!(resp.error.as_deref(), Some("worker panicked"));
        // the worker rebuilt its scheduler and the pool keeps serving —
        // the metrics mutex was not poisoned into a panic cascade
        let ok = engine.infer_blocking("m", vec![0u8; 16], 7).unwrap();
        assert!(ok.error.is_none(), "pool must drain after a panic");
        let metrics = engine.shutdown();
        assert_eq!(metrics.errors, 1);
        assert!(metrics.requests >= 1);
    }
}
