//! Request router: maps (model) → serving engine or worker pool.
//!
//! A deployment can host several private-inference backends (e.g. a
//! VGG-16 Origami pool and a VGG-19 Slalom engine); the router is the
//! single client-facing entry point and enforces basic admission checks
//! (known model, correctly sized ciphertext).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::api::InferResponse;
use super::pool::WorkerPool;
use super::server::ServingEngine;
use crate::util::threadpool::Channel;

/// A registered serving backend: the classic shared-batcher engine or
/// the sharded worker pool.
pub enum EngineHandle {
    Engine(ServingEngine),
    Pool(WorkerPool),
}

impl EngineHandle {
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        match self {
            EngineHandle::Engine(e) => e.submit(model, ciphertext, session),
            EngineHandle::Pool(p) => p.submit(model, ciphertext, session),
        }
    }

    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        match self {
            EngineHandle::Engine(e) => e.infer_blocking(model, ciphertext, session),
            EngineHandle::Pool(p) => p.infer_blocking(model, ciphertext, session),
        }
    }

    pub fn queue_depth(&self) -> usize {
        match self {
            EngineHandle::Engine(e) => e.queue_depth(),
            EngineHandle::Pool(p) => p.queue_depth(),
        }
    }

    pub fn shutdown(self) {
        match self {
            EngineHandle::Engine(e) => {
                e.shutdown();
            }
            EngineHandle::Pool(p) => {
                p.shutdown();
            }
        }
    }
}

impl From<ServingEngine> for EngineHandle {
    fn from(e: ServingEngine) -> Self {
        EngineHandle::Engine(e)
    }
}

impl From<WorkerPool> for EngineHandle {
    fn from(p: WorkerPool) -> Self {
        EngineHandle::Pool(p)
    }
}

/// Per-model registration.
struct Route {
    engine: EngineHandle,
    sample_bytes: usize,
}

/// The client-facing multiplexer.
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Route>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an engine or pool for `model`; requests must carry
    /// ciphertexts of exactly `sample_bytes`.
    pub fn register(
        &mut self,
        model: &str,
        engine: impl Into<EngineHandle>,
        sample_bytes: usize,
    ) {
        self.routes.insert(
            model.to_string(),
            Route {
                engine: engine.into(),
                sample_bytes,
            },
        );
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.routes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route a request (admission-checked) to its engine.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}` (have {:?})", self.models()))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.submit(model, ciphertext, session)
    }

    /// Blocking convenience.
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}`"))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.infer_blocking(model, ciphertext, session)
    }

    /// Total queued requests across engines.
    pub fn queue_depth(&self) -> usize {
        self.routes.values().map(|r| r.engine.queue_depth()).sum()
    }

    /// Shut all engines down.
    pub fn shutdown(self) {
        for (_, r) in self.routes {
            r.engine.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_rejected() {
        let r = Router::new();
        assert!(r.submit("nope", vec![], 0).is_err());
        assert!(r.models().is_empty());
    }
}
