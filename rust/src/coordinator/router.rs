//! Request router: maps (model) → serving engine.
//!
//! A deployment can host several private-inference engines (e.g. a
//! VGG-16 Origami engine and a VGG-19 Slalom engine); the router is the
//! single client-facing entry point and enforces basic admission checks
//! (known model, correctly sized ciphertext).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::api::InferResponse;
use super::server::ServingEngine;
use crate::util::threadpool::Channel;

/// Per-model registration.
struct Route {
    engine: ServingEngine,
    sample_bytes: usize,
}

/// The client-facing multiplexer.
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Route>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an engine for `model`; requests must carry ciphertexts of
    /// exactly `sample_bytes`.
    pub fn register(&mut self, model: &str, engine: ServingEngine, sample_bytes: usize) {
        self.routes.insert(
            model.to_string(),
            Route {
                engine,
                sample_bytes,
            },
        );
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.routes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route a request (admission-checked) to its engine.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}` (have {:?})", self.models()))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.submit(model, ciphertext, session)
    }

    /// Blocking convenience.
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}`"))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.infer_blocking(model, ciphertext, session)
    }

    /// Total queued requests across engines.
    pub fn queue_depth(&self) -> usize {
        self.routes.values().map(|r| r.engine.queue_depth()).sum()
    }

    /// Shut all engines down.
    pub fn shutdown(self) {
        for (_, r) in self.routes {
            r.engine.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_rejected() {
        let r = Router::new();
        assert!(r.submit("nope", vec![], 0).is_err());
        assert!(r.models().is_empty());
    }
}
