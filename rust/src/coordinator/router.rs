//! Request routing: the legacy single-engine [`Router`] and the
//! multi-tenant [`Deployment`] registry.
//!
//! A deployment hosts several private-inference models at once (e.g. a
//! VGG-16 Origami pool and a VGG-19 Slalom pool).  Each model gets its
//! own [`WorkerPool`] of tier-1 shards — enclaves and blinding state are
//! never shared across models — while every pool's open tier-2 tails
//! drain through one shared, device-aware [`LaneFabric`]: the
//! capacity-sharing opportunity Origami's tier split creates.
//!
//! The deployment is the single client-facing entry point and enforces
//! admission as *typed* errors ([`AdmissionError`]): unknown model,
//! mis-sized ciphertext, and cross-model session collisions (a session
//! is bound to the first model it touches; reusing its id against
//! another model is rejected, since session keystreams are per-session,
//! not per-model).  A queue-depth autoscaler ([`AutoscalePolicy`])
//! grows and shrinks each pool's tier-1 workers and the fabric's lane
//! count between their configured bounds.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::admission::{AdmissionDenial, AdmissionLimits, ShedPolicy, TenantAdmission};
use super::api::InferResponse;
use super::epc_sched::{EpcAccount, EpcLedger, EpcOptions, EpcPacker, ReclaimCandidate};
use super::fabric::{FabricMetrics, FabricOptions, LaneFabric};
use super::pool::{PoolMetrics, PoolOptions, WorkerPool};
use super::scheduler::{BatchScheduler, Tier2Finisher};
use super::server::ServingEngine;
use super::session::{SessionError, SessionGrant, SessionTable};
use super::telemetry::{AdmissionSnapshot, ScaleSnapshot, Stage, TelemetryHub, TenantTelemetry};
use crate::crypto;
use crate::util::threadpool::Channel;

/// A registered serving backend: the classic shared-batcher engine or
/// the sharded worker pool.
pub enum EngineHandle {
    Engine(ServingEngine),
    Pool(WorkerPool),
}

impl EngineHandle {
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        match self {
            EngineHandle::Engine(e) => e.submit(model, ciphertext, session),
            EngineHandle::Pool(p) => p.submit(model, ciphertext, session),
        }
    }

    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        match self {
            EngineHandle::Engine(e) => e.infer_blocking(model, ciphertext, session),
            EngineHandle::Pool(p) => p.infer_blocking(model, ciphertext, session),
        }
    }

    pub fn queue_depth(&self) -> usize {
        match self {
            EngineHandle::Engine(e) => e.queue_depth(),
            EngineHandle::Pool(p) => p.queue_depth(),
        }
    }

    pub fn shutdown(self) {
        match self {
            EngineHandle::Engine(e) => {
                e.shutdown();
            }
            EngineHandle::Pool(p) => {
                p.shutdown();
            }
        }
    }
}

impl From<ServingEngine> for EngineHandle {
    fn from(e: ServingEngine) -> Self {
        EngineHandle::Engine(e)
    }
}

impl From<WorkerPool> for EngineHandle {
    fn from(p: WorkerPool) -> Self {
        EngineHandle::Pool(p)
    }
}

/// Per-model registration.
struct Route {
    engine: EngineHandle,
    sample_bytes: usize,
}

/// The legacy client-facing multiplexer (single-tenant engines that own
/// their own tier-2 capacity; see [`Deployment`] for the shared-fabric
/// shape).
#[derive(Default)]
pub struct Router {
    routes: HashMap<String, Route>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an engine or pool for `model`; requests must carry
    /// ciphertexts of exactly `sample_bytes`.
    pub fn register(
        &mut self,
        model: &str,
        engine: impl Into<EngineHandle>,
        sample_bytes: usize,
    ) {
        self.routes.insert(
            model.to_string(),
            Route {
                engine: engine.into(),
                sample_bytes,
            },
        );
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.routes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route a request (admission-checked) to its engine.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}` (have {:?})", self.models()))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.submit(model, ciphertext, session)
    }

    /// Blocking convenience.
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| anyhow!("no engine for model `{model}`"))?;
        if ciphertext.len() != route.sample_bytes {
            return Err(anyhow!(
                "model `{model}` expects {}-byte ciphertexts, got {}",
                route.sample_bytes,
                ciphertext.len()
            ));
        }
        route.engine.infer_blocking(model, ciphertext, session)
    }

    /// Total queued requests across engines.
    pub fn queue_depth(&self) -> usize {
        self.routes.values().map(|r| r.engine.queue_depth()).sum()
    }

    /// Shut all engines down.
    pub fn shutdown(self) {
        for (_, r) in self.routes {
            r.engine.shutdown();
        }
    }
}

/// Typed admission failures: every rejected request gets a precise,
/// matchable reason — and is rejected *synchronously*, so a bad request
/// can never hang a client waiting for a reply that won't come.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// The model has no deployment.
    UnknownModel { model: String, known: Vec<String> },
    /// The ciphertext is not one encrypted sample for this model.
    WrongSize {
        model: String,
        expected: usize,
        got: usize,
    },
    /// The session id is already bound to a different model.
    SessionCollision {
        session: u64,
        bound: String,
        requested: String,
    },
    /// The attested session's TTL lapsed.  `refreshable` hints whether
    /// a session refresh (keystream-epoch bump) is enough to resume, or
    /// the session is gone and the client must re-attest from scratch.
    SessionExpired { session: u64, refreshable: bool },
    /// The model's pool refused the request (shutting down).
    Unavailable { model: String },
    /// The tenant's token-bucket rate limit is exhausted; retry after
    /// the hinted delay (the bucket's refill deficit, rounded up).
    RateLimited { model: String, retry_after_ms: u64 },
    /// The tenant's in-flight concurrency quota is saturated.  The hint
    /// is the tenant's windowed end-to-end p95 — the expected time for
    /// an in-flight slot to free (0 when telemetry has no samples yet).
    QuotaExceeded {
        model: String,
        limit: usize,
        retry_after_ms: u64,
    },
    /// The tenant's tier-1 backlog reached its shed threshold (and no
    /// degraded tier absorbed the request).  The hint is the tenant's
    /// windowed queue-wait p95 (0 when telemetry has no samples yet).
    /// `epc_limited` is true when the pool's most recent grow attempt
    /// was refused by the EPC co-scheduler — the backlog is not going to
    /// scale away, because enclave memory (not capacity policy) is the
    /// binding constraint.
    Shed {
        model: String,
        depth: usize,
        threshold: usize,
        retry_after_ms: u64,
        epc_limited: bool,
    },
}

impl AdmissionError {
    /// Client back-off hint, when the failure is load-dependent.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            AdmissionError::RateLimited { retry_after_ms, .. }
            | AdmissionError::QuotaExceeded { retry_after_ms, .. }
            | AdmissionError::Shed { retry_after_ms, .. } => Some(*retry_after_ms),
            _ => None,
        }
    }
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::UnknownModel { model, known } => {
                write!(f, "no deployment for model `{model}` (have {known:?})")
            }
            AdmissionError::WrongSize {
                model,
                expected,
                got,
            } => write!(
                f,
                "model `{model}` expects {expected}-byte ciphertexts, got {got}"
            ),
            AdmissionError::SessionCollision {
                session,
                bound,
                requested,
            } => write!(
                f,
                "session {session} is bound to model `{bound}`; cannot serve `{requested}`"
            ),
            AdmissionError::SessionExpired {
                session,
                refreshable,
            } => write!(
                f,
                "session {session} expired; {}",
                if *refreshable {
                    "refresh the session (epoch bump) to resume"
                } else {
                    "re-attest to establish a new session"
                }
            ),
            AdmissionError::Unavailable { model } => {
                write!(f, "deployment for model `{model}` is shutting down")
            }
            AdmissionError::RateLimited {
                model,
                retry_after_ms,
            } => write!(
                f,
                "model `{model}` is rate-limited; retry after {retry_after_ms} ms"
            ),
            AdmissionError::QuotaExceeded {
                model,
                limit,
                retry_after_ms,
            } => write!(
                f,
                "model `{model}` has {limit} requests in flight (quota); \
                 retry after {retry_after_ms} ms"
            ),
            AdmissionError::Shed {
                model,
                depth,
                threshold,
                retry_after_ms,
                epc_limited,
            } => write!(
                f,
                "model `{model}` shed the request (queue depth {depth} ≥ {threshold}{}); \
                 retry after {retry_after_ms} ms",
                if *epc_limited {
                    "; tier-1 growth is EPC-limited"
                } else {
                    ""
                }
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Which signal drives scaling decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleMode {
    /// Queue depth only (the PR-2 behavior).
    Depth,
    /// Windowed p95-vs-SLO error, with depth as the cold-start fallback
    /// (before the telemetry window holds enough samples) and as the
    /// shrink guard (never shrink into a standing backlog).
    SloP95,
}

/// Deployment-wide autoscaling policy.
#[derive(Debug, Clone)]
pub struct AutoscalePolicy {
    /// Grow a pool (or the fabric) when its queue depth exceeds
    /// `high × active` workers (lanes).
    pub high_depth_per_worker: usize,
    /// Shrink when depth falls to `low × (active − 1)` — i.e. when the
    /// remaining workers would still sit under the low watermark.
    pub low_depth_per_worker: usize,
    /// Background autoscaler cadence (ms).
    pub tick_ms: u64,
    /// Scaling signal (see [`ScaleMode`]).
    pub mode: ScaleMode,
    /// SLO mode: shrink only once p95 has fallen under
    /// `slo_shrink_margin × SLO` (head-room guard against shrink→breach
    /// →grow oscillation).
    pub slo_shrink_margin: f64,
    /// SLO mode: minimum windowed samples before p95 is trusted; below
    /// it the depth signal decides.
    pub min_window_samples: u64,
    /// Hysteresis: after any scale event on a target, that target holds
    /// for this many ticks before the next event.  A trace oscillating
    /// around a threshold can therefore churn `scale_to` at most once
    /// per cooldown window (regression-pinned).
    pub cooldown_ticks: u64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        Self {
            high_depth_per_worker: 4,
            low_depth_per_worker: 1,
            tick_ms: 20,
            mode: ScaleMode::Depth,
            slo_shrink_margin: 0.5,
            min_window_samples: 8,
            cooldown_ticks: 2,
        }
    }
}

/// The signals one scaling target (a pool or the lane fabric) exposes
/// to the autoscaler each tick.
#[derive(Debug, Clone, Copy)]
pub struct ScaleSignals {
    /// Queued work items at this target.
    pub depth: usize,
    /// Current worker/lane count.
    pub active: usize,
    /// Windowed end-to-end p95 (ms), when telemetry has it.
    pub p95_ms: Option<f64>,
    /// Samples in the telemetry readout window.
    pub window_samples: u64,
    /// The target's latency objective (ms), when configured.
    pub slo_ms: Option<f64>,
    /// Ticks since this target's last scale event (None = never scaled).
    pub ticks_since_scale: Option<u64>,
    /// EPC ceiling: how many *more* workers of this target's enclave
    /// footprint the EPC ledger can fund (None = not EPC-accounted,
    /// e.g. fabric lanes — tier-2 tails hold no enclave state).  A grow
    /// is capped at `active + headroom`; at zero headroom the grow is
    /// suppressed entirely (the deployment tick then tries to reclaim
    /// idle workers from over-provisioned tenants before giving up).
    pub epc_headroom_workers: Option<usize>,
    /// Per-item cost multiplier of this target relative to the baseline
    /// kernels (e.g. [`OBLIVIOUS_COST_MULTIPLIER`] for tenants running
    /// data-oblivious tier-1 stages).  The depth thresholds compare
    /// against `depth × multiplier`: a queue of N oblivious items
    /// represents N× the slowdown factor of work, so the autoscaler
    /// grows earlier instead of discovering the deficit via p95.  `1.0`
    /// is bit-exactly the pre-multiplier behavior.
    ///
    /// [`OBLIVIOUS_COST_MULTIPLIER`]: crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER
    pub cost_multiplier: f64,
}

impl AutoscalePolicy {
    /// Pure per-target scaling decision: the desired size (always a ±1
    /// step from `active`), or None to hold.  Pure so the flap
    /// regression tests and the serving simulator can drive the exact
    /// production decision rule over scripted traces.
    ///
    /// Grows are additionally capped by the EPC ceiling signal
    /// ([`ScaleSignals::epc_headroom_workers`]): however loud the depth
    /// or p95 signal, a target whose ledger headroom is zero holds
    /// instead of growing into a paging storm.  Shrinks are never
    /// EPC-capped — they only return memory.
    pub fn decide(&self, s: &ScaleSignals) -> Option<usize> {
        if let Some(t) = s.ticks_since_scale {
            if t < self.cooldown_ticks {
                return None; // holding after a recent scale event
            }
        }
        let active = s.active.max(1);
        // Effective depth: queued items weighted by the tenant's kernel
        // cost multiplier (1.0 → bit-exactly the unweighted thresholds).
        let eff_depth = s.depth as f64 * s.cost_multiplier.max(1.0);
        let depth_high = eff_depth > self.high_depth_per_worker.saturating_mul(active) as f64;
        let depth_low = eff_depth
            <= self
                .low_depth_per_worker
                .saturating_mul(active.saturating_sub(1)) as f64;
        let want = match (self.mode, s.slo_ms) {
            (ScaleMode::SloP95, Some(slo))
                if slo > 0.0 && s.window_samples >= self.min_window_samples =>
            {
                let p95 = s.p95_ms.unwrap_or(0.0);
                if p95 > slo {
                    Some(active + 1)
                } else if p95 < slo * self.slo_shrink_margin && depth_low && active > 1 {
                    Some(active - 1)
                } else {
                    None
                }
            }
            _ => {
                if depth_high {
                    Some(active + 1)
                } else if depth_low && active > 1 {
                    Some(active - 1)
                } else {
                    None
                }
            }
        };
        match (want, s.epc_headroom_workers) {
            (Some(n), Some(headroom)) if n > active => {
                // saturating: usize::MAX headroom means "not EPC-bound"
                let capped = n.min(active.saturating_add(headroom));
                (capped > active).then_some(capped)
            }
            _ => want,
        }
    }
}

struct ModelEntry {
    /// Arc so the autoscaler can scale (and block on shard joins)
    /// without holding the registry lock across the operation.
    pool: Arc<WorkerPool>,
    sample_bytes: usize,
    /// Weighted-fair fabric share (also the EPC packer's reclaim
    /// priority: workers parked above a tenant's share donate first).
    weight: f64,
    /// Latency objective (ms) the SLO autoscaler holds this model to.
    slo_ms: Option<f64>,
    /// Per-tenant admission gate (rate limit / quota / shed threshold).
    admission: Arc<TenantAdmission>,
    /// What to do with shed requests.
    shed_policy: ShedPolicy,
    /// Tenant a shed request degrades to under [`ShedPolicy::Degrade`]
    /// (a cheaper strategy tier deployed for the same model geometry).
    degrade_to: Option<String>,
    /// The tenant's telemetry (admission counters + retry hints).
    telemetry: Arc<TenantTelemetry>,
    /// Per-item kernel cost multiplier fed to the autoscaler and the
    /// EPC reclaim planner (see [`ScaleSignals::cost_multiplier`]).
    cost_multiplier: f64,
}

/// Hysteresis bookkeeping: the autoscaler's tick counter plus each
/// target's last scale-event tick.
#[derive(Default)]
struct AutoscaleState {
    tick: u64,
    last_pool_scale: HashMap<String, u64>,
    last_fabric_scale: Option<u64>,
}

struct DeploymentCore {
    fabric: LaneFabric,
    models: Mutex<HashMap<String, ModelEntry>>,
    /// Model names with a deploy in flight: makes the whole deploy —
    /// EPC register + charge, fabric attach, pool start — exclusive per
    /// name, so a concurrent duplicate deploy can never overwrite the
    /// winner's ledger footprint between its register and its charge.
    deploying: Mutex<HashSet<String>>,
    /// Session lifecycle state (binding, keystream epoch, expiry): a
    /// sharded table with TTL/LRU eviction, so long-lived deployments
    /// no longer leak memory linearly in distinct session ids (the old
    /// flat `Mutex<HashMap<u64, String>>` retained every binding
    /// forever) and submits from different sessions stripe across
    /// independent locks.  The autoscaler tick doubles as its sweeper.
    sessions: SessionTable,
    policy: AutoscalePolicy,
    /// EPC residency ledger (None = EPC-aware co-scheduling off).  Pools
    /// whose `worker_epc_bytes > 0` charge every worker here; the tick
    /// consults it (and the packer) before any grow.
    epc: Option<Arc<EpcLedger>>,
    /// Per-tenant latency telemetry (shared with the fabric's lanes and
    /// every pool's tier-1 workers).
    telemetry: Arc<TelemetryHub>,
    scale_state: Mutex<AutoscaleState>,
    /// Monotone tenant-band allocator (blinding keyspace): never reused,
    /// so concurrent deploys cannot end up sharing a band.
    next_band: AtomicU64,
    /// Deployment-wide default admission limits (from
    /// [`DeploymentBuilder::admission`]); a [`DeploySpec`] without its
    /// own limits inherits these.
    default_admission: AdmissionLimits,
    /// Clock epoch the admission token buckets run on (wall time as
    /// milliseconds since deployment start; the simulator drives the
    /// same bucket code from its own clock instead).
    epoch: Instant,
}

impl DeploymentCore {
    /// One autoscaler pass: per-pool tier-1 scaling, then fabric lane
    /// scaling from tier-2 demand (its own queue plus the tier-1
    /// backlog about to become tail work).
    ///
    /// In [`ScaleMode::SloP95`] each model scales on its windowed
    /// end-to-end p95 against its SLO (depth remains the cold-start
    /// fallback and the shrink guard); in [`ScaleMode::Depth`] the PR-2
    /// queue-depth rule applies.  Either way a target that just scaled
    /// holds for `cooldown_ticks` ticks (hysteresis).
    ///
    /// Pools are snapshotted out of the registry first: a shrink blocks
    /// until the retired shard drains, and holding the registry lock
    /// through that would stall every submit.
    ///
    /// Under EPC-aware co-scheduling (a deployment built with
    /// [`DeploymentBuilder::epc`]), every grow is checked against the
    /// [`EpcLedger`] first: a grow the free budget cannot fund asks the
    /// [`EpcPacker`] to reclaim idle workers parked above other tenants'
    /// floors (most over-provisioned per fabric share first); if no
    /// reclaim covers the deficit, the grow is *denied* and recorded in
    /// the tenant's [`ScaleCounters`](super::telemetry::ScaleCounters)
    /// — the pool never grows into a paging storm.
    /// Milliseconds since the deployment epoch: the clock the admission
    /// buckets and the session table both run on.
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn tick(&self) {
        // retire expired sessions first — the tick is the table's sweep
        // cadence, so session memory is bounded by (arrival rate × TTL)
        self.sessions.sweep(self.now_ms());
        let p = &self.policy;
        let mut entries: Vec<(String, Arc<WorkerPool>, Option<f64>, f64, f64)> = {
            let g = self.models.lock().unwrap();
            g.iter()
                .map(|(name, e)| {
                    (
                        name.clone(),
                        e.pool.clone(),
                        e.slo_ms,
                        e.weight,
                        e.cost_multiplier,
                    )
                })
                .collect()
        };
        // fixed evaluation order: scaling (and EPC reclaim) decisions
        // must not depend on registry hash order
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        // close the live telemetry window; readouts below cover the
        // retained ring (the last `keep` ticks)
        self.telemetry.rotate_all();
        let (tick_no, last_fabric) = {
            let mut st = self.scale_state.lock().unwrap();
            st.tick += 1;
            (st.tick, st.last_fabric_scale)
        };
        let mut t1_backlog = 0usize;
        // worst p95-vs-SLO pressure across tenants (drives the fabric)
        let mut worst_ratio: Option<f64> = None;
        let mut fabric_samples = 0u64;
        // The fabric may scale on p95 only when *every* tenant declares
        // an SLO: a no-SLO tenant has no latency signal of its own, and
        // weighted-fair popping keeps the SLO tenants healthy even
        // while its backlog diverges — so a mixed deployment must keep
        // the depth rule for the shared lanes.
        let mut all_have_slo = !entries.is_empty();
        let slo_mode = p.mode == ScaleMode::SloP95;
        for (name, pool, slo_ms, _, cost_multiplier) in &entries {
            let depth = pool.queue_depth();
            t1_backlog += depth;
            // one windowed-snapshot merge per tenant, and only for
            // SLO-mode tenants that have an SLO — decide() reads p95
            // nowhere else
            let (p95_ms, window_samples) = match self.telemetry.get(name) {
                Some(t) if slo_mode && slo_ms.is_some() => {
                    let snap = t.snapshot(Stage::EndToEnd);
                    (Some(snap.percentile(95.0)), snap.count())
                }
                _ => (None, 0),
            };
            if slo_ms.is_none() {
                all_have_slo = false;
            }
            if let (Some(p95), Some(slo)) = (p95_ms, *slo_ms) {
                if slo > 0.0 && window_samples >= p.min_window_samples {
                    let r = p95 / slo;
                    worst_ratio = Some(worst_ratio.map_or(r, |w: f64| w.max(r)));
                    fabric_samples += window_samples;
                }
            }
            let prev = pool.active_workers();
            // the EPC ceiling signal: how many more workers the ledger
            // can fund for this tenant right now (None when the pool is
            // not EPC-accounted)
            let headroom = match (&self.epc, pool.worker_epc_bytes()) {
                (Some(ledger), wb) if wb > 0 => Some(ledger.headroom_workers(name)),
                _ => None,
            };
            // headroom returned (another tenant shrank or shut down):
            // the tenant is no longer EPC-limited, even before it next
            // attempts a grow — shed hints must not keep claiming the
            // box is full
            if headroom.is_some_and(|h| h > 0) {
                if let Some(t) = self.telemetry.get(name) {
                    t.scale().clear_epc_limited();
                }
            }
            // read the live map, not a tick-start snapshot: a victim the
            // packer just reclaimed from must see its fresh cooldown
            // stamp when its own turn comes this same tick.  Saturating:
            // a concurrent tick (pump + manual autoscale_tick) can stamp
            // a *later* tick number than this pass captured.
            let ticks_since_scale = {
                let st = self.scale_state.lock().unwrap();
                st.last_pool_scale
                    .get(name)
                    .map(|&l| tick_no.saturating_sub(l))
            };
            let mut signals = ScaleSignals {
                depth,
                active: prev,
                p95_ms,
                window_samples,
                slo_ms: *slo_ms,
                ticks_since_scale,
                epc_headroom_workers: headroom,
                cost_multiplier: *cost_multiplier,
            };
            let mut decision = p.decide(&signals);
            if decision.is_none() && headroom.is_some() {
                // the ceiling may have suppressed a needed grow: re-read
                // the raw intent and try to fund it by packer reclaim.
                // A grow the pool's own max_workers bound would clamp
                // away is a plain hold — never an EPC denial, and never
                // worth dismantling another tenant's idle workers for.
                signals.epc_headroom_workers = None;
                if let Some(n) = p.decide(&signals) {
                    let n = n.clamp(pool.min_workers(), pool.max_workers());
                    let fund =
                        n > prev && self.fund_epc_grow(name, pool, n - prev, &entries, tick_no);
                    if fund {
                        decision = Some(n);
                    }
                    // on failure the denial was recorded in fund_epc_grow
                }
            }
            if let Some(n) = decision {
                let n = n.clamp(pool.min_workers(), pool.max_workers());
                if n == prev {
                    continue; // clamped to a hold (e.g. already at max)
                }
                let now = pool.scale_to(n);
                if now != prev {
                    if n > prev {
                        if let Some(t) = self.telemetry.get(name) {
                            t.scale().clear_epc_limited();
                        }
                    }
                    self.scale_state
                        .lock()
                        .unwrap()
                        .last_pool_scale
                        .insert(name.clone(), tick_no);
                } else if n > prev && pool.worker_epc_bytes() > 0 && self.epc.is_some() {
                    // the ledger refused inside scale_to (a concurrent
                    // charge raced the funding/headroom check above)
                    self.record_epc_denied(name);
                }
            }
        }
        // The fabric serves every tenant, so its SLO signal is the worst
        // tenant's p95/SLO ratio mapped onto a synthetic slo of 1.0 —
        // `decide` then grows lanes whenever any tenant is in breach.
        // With any no-SLO tenant deployed the synthetic SLO is withheld
        // and the lanes stay depth-scaled (see `all_have_slo` above).
        let lanes = self.fabric.lane_count();
        let signals = ScaleSignals {
            depth: self.fabric.queue_depth() + t1_backlog,
            active: lanes,
            p95_ms: worst_ratio,
            window_samples: fabric_samples,
            slo_ms: (all_have_slo && worst_ratio.is_some()).then_some(1.0),
            ticks_since_scale: last_fabric.map(|l| tick_no - l),
            // tier-2 lanes hold no enclave state: never EPC-capped
            epc_headroom_workers: None,
            // per-tenant kernel slowdowns are already folded into each
            // pool's own signal; the shared lanes run baseline tails
            cost_multiplier: 1.0,
        };
        if let Some(n) = p.decide(&signals) {
            if self.fabric.scale_to(n) != lanes {
                self.scale_state.lock().unwrap().last_fabric_scale = Some(tick_no);
            }
        }
    }

    /// Make room in the EPC ledger for `grow_by` more workers of
    /// `model`: free budget first, then packer-planned reclaim of idle
    /// workers from over-provisioned tenants.  Returns false (and
    /// records the denial) when the grow cannot be funded.  The actual
    /// charge stays inside the pool's `scale_to` — this only frees
    /// capacity, so a race can at worst re-deny there, never overcommit.
    ///
    /// The deterministic replay mirrors this step
    /// ([`crate::harness::sim::replay_epc_packing`]) — keep the two in
    /// lockstep.
    fn fund_epc_grow(
        &self,
        model: &str,
        pool: &Arc<WorkerPool>,
        grow_by: usize,
        entries: &[(String, Arc<WorkerPool>, Option<f64>, f64, f64)],
        tick_no: u64,
    ) -> bool {
        let Some(ledger) = &self.epc else {
            return true;
        };
        let wb = pool.worker_epc_bytes();
        if wb == 0 {
            return true;
        }
        let needed = wb.saturating_mul(grow_by as u64);
        let free = ledger.free_bytes();
        if free >= needed {
            return true;
        }
        let candidates: Vec<ReclaimCandidate> = entries
            .iter()
            .filter(|(name, ..)| name != model)
            .map(|(name, vpool, _, weight, cm)| ReclaimCandidate {
                tenant: name.clone(),
                active: vpool.active_workers(),
                floor: vpool.min_workers(),
                queue_depth: vpool.queue_depth(),
                weight: *weight,
                worker_bytes: vpool.worker_epc_bytes(),
                cost_multiplier: *cm,
            })
            .collect();
        let Some(plan) = EpcPacker::plan_reclaim(&candidates, needed - free) else {
            self.record_epc_denied(model);
            return false;
        };
        for (victim, retire) in plan {
            let Some(vpool) = entries
                .iter()
                .find(|(name, ..)| *name == victim)
                .map(|(_, p, ..)| p)
            else {
                continue;
            };
            let active = vpool.active_workers();
            let reclaimed = active.saturating_sub(vpool.scale_to(active.saturating_sub(retire)));
            if reclaimed > 0 {
                if let Some(t) = self.telemetry.get(&victim) {
                    t.scale().record_epc_reclaimed(reclaimed as u64);
                }
                // a donor holds its cooldown like any other scale event,
                // so reclaim cannot ping-pong workers between tenants
                self.scale_state
                    .lock()
                    .unwrap()
                    .last_pool_scale
                    .insert(victim, tick_no);
            }
        }
        // a parallel charge may still have raced the freed budget away;
        // scale_to's transactional charge is the final arbiter
        if ledger.free_bytes() >= needed {
            true
        } else {
            self.record_epc_denied(model);
            false
        }
    }

    fn record_epc_denied(&self, model: &str) {
        if let Some(t) = self.telemetry.get(model) {
            t.scale().record_epc_denied();
        }
    }
}

/// Releases a name's in-flight deploy claim on drop, so every exit
/// path of [`Deployment::deploy_with_admission`] — success or error —
/// frees the name for later deploy attempts.
struct DeployClaim<'a> {
    core: &'a DeploymentCore,
    model: &'a str,
}

impl Drop for DeployClaim<'_> {
    fn drop(&mut self) {
        self.core.deploying.lock().unwrap().remove(self.model);
    }
}

/// Final metrics of a shut-down deployment.
pub struct DeploymentMetrics {
    /// Per-model tier-1 pool metrics.
    pub models: BTreeMap<String, PoolMetrics>,
    /// The shared fabric: per-lane ledgers + per-tenant stats.
    pub fabric: FabricMetrics,
}

/// The multi-tenant serving registry (see module docs).
pub struct Deployment {
    core: Arc<DeploymentCore>,
    pump: Option<JoinHandle<()>>,
    /// Background session sweeper: retires expired sessions on its own
    /// cadence, independent of the autoscaler pump (sessions must be
    /// reaped even with autoscaling off).
    sweeper: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

/// Wall-clock span the windowed telemetry readout targets (ms).  The
/// hub's retained-window count is derived from the autoscaler tick so
/// the p95 window covers roughly this much time at any `tick_ms` — a
/// fixed window *count* would make the readout span (and how long a
/// finished burst haunts scaling decisions) scale with the tick.
const TELEMETRY_WINDOW_MS: u64 = 1_000;

/// Cap retry-after hints at one minute: an empty bucket refilling at a
/// tiny rate would otherwise hint absurd (or non-finite) delays.
const MAX_RETRY_HINT_MS: f64 = 60_000.0;

/// Default session-table stripe count: enough that concurrent submit
/// threads rarely contend on one lock, cheap enough to sweep.
pub const DEFAULT_SESSION_SHARDS: usize = 64;

/// Default session TTL (10 minutes): idle sessions are retired by the
/// autoscaler-tick sweep instead of accumulating forever.
pub const DEFAULT_SESSION_TTL_MS: u64 = 600_000;

/// Default live-session ceiling (per deployment): above it the table
/// LRU-evicts, so an unauthenticated HELLO flood cannot grow session
/// state past this bound even inside one TTL window.
pub const DEFAULT_SESSION_CAP: usize = 1 << 20;

/// Default session-sweep cadence (ms).  The sweeper is its own thread,
/// deliberately decoupled from the autoscaler tick: expired sessions
/// must be reaped even when autoscaling is off.
pub const DEFAULT_SESSION_SWEEP_MS: u64 = 1_000;

fn clamp_hint_ms(ms: f64) -> u64 {
    ms.clamp(0.0, MAX_RETRY_HINT_MS).ceil() as u64
}

/// Builder for [`Deployment`] — the one construction path (the
/// `new`/`new_with_epc`/`new_with_sessions` trio it replaces survives
/// as deprecated shims).
///
/// ```ignore
/// let dep = Deployment::builder(fabric_opts)
///     .policy(autoscale_policy)
///     .epc(epc_options)          // Option or value
///     .sessions(SessionTable::with_capacity(64, 600_000, 1 << 20))
///     .admission(default_limits) // deployment-wide default
///     .build();
/// ```
pub struct DeploymentBuilder {
    fabric: FabricOptions,
    policy: AutoscalePolicy,
    epc: Option<EpcOptions>,
    sessions: Option<SessionTable>,
    admission: AdmissionLimits,
    sweep_ms: u64,
}

impl DeploymentBuilder {
    /// Autoscale policy (default: [`AutoscalePolicy::default`]).
    pub fn policy(mut self, policy: AutoscalePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// EPC-aware co-scheduling: the usable enclave budget (and
    /// overcommit factor) a global [`EpcLedger`] enforces across every
    /// pool whose [`PoolOptions::worker_epc_bytes`] is set.  Deploys
    /// that cannot fit their initial fleet fail up front; autoscaler
    /// grows charge transactionally, reclaim idle workers from
    /// over-provisioned tenants when the budget is short, and are
    /// denied (typed, telemetry-recorded) rather than overcommitting.
    pub fn epc(mut self, epc: impl Into<Option<EpcOptions>>) -> Self {
        self.epc = epc.into();
        self
    }

    /// Explicitly configured session table (shard count, TTL, optional
    /// LRU capacity) — the network front door sizes this from
    /// `--session-shards` / `--session-ttl` / `--session-cap`.
    pub fn sessions(mut self, sessions: SessionTable) -> Self {
        self.sessions = Some(sessions);
        self
    }

    /// Deployment-wide default admission limits: every
    /// [`DeploySpec`] that does not carry its own limits inherits
    /// these (default: unlimited).
    pub fn admission(mut self, limits: AdmissionLimits) -> Self {
        self.admission = limits;
        self
    }

    /// Session-sweep cadence in milliseconds
    /// ([`DEFAULT_SESSION_SWEEP_MS`] by default; `0` disables the
    /// sweeper thread — trusted in-process deployments that drive
    /// [`Deployment::autoscale_tick`] themselves).
    pub fn sweep_every_ms(mut self, sweep_ms: u64) -> Self {
        self.sweep_ms = sweep_ms;
        self
    }

    pub fn build(self) -> Deployment {
        let keep = (TELEMETRY_WINDOW_MS / self.policy.tick_ms.max(1)).clamp(5, 200) as usize;
        let telemetry = Arc::new(TelemetryHub::new(keep));
        let sessions = self.sessions.unwrap_or_else(|| {
            SessionTable::new(DEFAULT_SESSION_SHARDS, DEFAULT_SESSION_TTL_MS)
        });
        let core = Arc::new(DeploymentCore {
            fabric: LaneFabric::start_with_telemetry(self.fabric, Some(telemetry.clone())),
            models: Mutex::new(HashMap::new()),
            deploying: Mutex::new(HashSet::new()),
            sessions,
            policy: self.policy,
            epc: self.epc.map(|o| Arc::new(EpcLedger::new(o))),
            telemetry,
            scale_state: Mutex::new(AutoscaleState::default()),
            next_band: AtomicU64::new(0),
            default_admission: self.admission,
            epoch: Instant::now(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        // The TTL sweeper runs on its own cadence — NOT the autoscaler
        // tick — so expired sessions are reaped even with autoscaling
        // off.  It sleeps in short quanta so shutdown never waits out a
        // full sweep interval.
        let sweeper = (self.sweep_ms > 0).then(|| {
            let core = core.clone();
            let stop = stop.clone();
            let sweep_ms = self.sweep_ms;
            std::thread::Builder::new()
                .name("origami-session-sweep".into())
                .spawn(move || {
                    let quantum = Duration::from_millis(sweep_ms.clamp(1, 20));
                    let mut since_sweep = Duration::ZERO;
                    while !stop.load(Ordering::SeqCst) {
                        std::thread::sleep(quantum);
                        since_sweep += quantum;
                        if since_sweep.as_millis() as u64 >= sweep_ms {
                            core.sessions.sweep(core.now_ms());
                            since_sweep = Duration::ZERO;
                        }
                    }
                })
                .expect("spawn session sweeper")
        });
        Deployment {
            core,
            pump: None,
            sweeper,
            stop,
        }
    }
}

/// Everything one model's registration needs, gathered into a spec so
/// [`Deployment::deploy_model`] takes one argument instead of nine
/// (replaces the `deploy`/`deploy_with_admission` positional pair).
#[derive(Debug, Clone)]
pub struct DeploySpec {
    model: String,
    sample_bytes: usize,
    weight: f64,
    slo_ms: Option<f64>,
    limits: Option<AdmissionLimits>,
    shed_policy: ShedPolicy,
    cost_multiplier: f64,
    pool: PoolOptions,
}

impl DeploySpec {
    /// A spec for `model` whose requests carry ciphertexts of exactly
    /// `sample_bytes`.  Defaults: weight 1.0, no SLO, the deployment's
    /// default admission limits, [`ShedPolicy::Reject`], default pool.
    pub fn new(model: &str, sample_bytes: usize) -> Self {
        Self {
            model: model.to_string(),
            sample_bytes,
            weight: 1.0,
            slo_ms: None,
            limits: None,
            shed_policy: ShedPolicy::Reject,
            cost_multiplier: 1.0,
            pool: PoolOptions::default(),
        }
    }

    /// Weighted-fair share of the shared fabric lanes (default 1.0).
    pub fn weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// End-to-end latency objective: the SLO autoscaler holds the
    /// windowed p95 under it (None = depth-scaled only).
    pub fn slo_ms(mut self, slo_ms: impl Into<Option<f64>>) -> Self {
        self.slo_ms = slo_ms.into();
        self
    }

    /// Per-tenant admission limits (token-bucket rate, in-flight quota,
    /// shed threshold); unset inherits the deployment-wide default from
    /// [`DeploymentBuilder::admission`].
    pub fn admission(mut self, limits: AdmissionLimits) -> Self {
        self.limits = Some(limits);
        self
    }

    /// What happens to shed requests: rejection, or degradation to a
    /// cheaper tier registered with [`Deployment::set_degrade`].
    pub fn shed_policy(mut self, policy: ShedPolicy) -> Self {
        self.shed_policy = policy;
        self
    }

    /// Per-item kernel cost multiplier relative to the baseline
    /// kernels (default 1.0).  An oblivious tenant deploys with
    /// [`OBLIVIOUS_COST_MULTIPLIER`] so the autoscaler weighs its queue
    /// depth accordingly and the EPC packer reclaims its workers last
    /// among equals (see [`ScaleSignals::cost_multiplier`]).
    ///
    /// [`OBLIVIOUS_COST_MULTIPLIER`]: crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER
    pub fn cost_multiplier(mut self, multiplier: f64) -> Self {
        self.cost_multiplier = multiplier;
        self
    }

    /// Tier-1 pool geometry (workers, batching, EPC footprint, …).
    pub fn pool(mut self, pool: PoolOptions) -> Self {
        self.pool = pool;
        self
    }
}

/// Expected time for an in-flight slot to free: the tenant's windowed
/// end-to-end p95 (0 until telemetry has samples).
fn drain_hint_ms(t: &TenantTelemetry) -> u64 {
    clamp_hint_ms(t.percentile(Stage::EndToEnd, 95.0))
}

/// Expected backlog drain time: the tenant's windowed queue-wait p95
/// (0 until telemetry has samples).
fn queue_hint_ms(t: &TenantTelemetry) -> u64 {
    clamp_hint_ms(t.percentile(Stage::QueueWait, 95.0))
}

impl Deployment {
    /// Start building a deployment around a fresh lane fabric — the one
    /// construction path (see [`DeploymentBuilder`]).
    pub fn builder(fabric_opts: FabricOptions) -> DeploymentBuilder {
        DeploymentBuilder {
            fabric: fabric_opts,
            policy: AutoscalePolicy::default(),
            epc: None,
            sessions: None,
            admission: AdmissionLimits::default(),
            sweep_ms: DEFAULT_SESSION_SWEEP_MS,
        }
    }

    /// Create a deployment around a fresh lane fabric.
    #[deprecated(since = "0.9.0", note = "use `Deployment::builder(fabric).policy(p).build()`")]
    pub fn new(fabric_opts: FabricOptions, policy: AutoscalePolicy) -> Self {
        Self::builder(fabric_opts).policy(policy).build()
    }

    /// [`Deployment::new`], plus EPC-aware co-scheduling.
    #[deprecated(
        since = "0.9.0",
        note = "use `Deployment::builder(fabric).policy(p).epc(epc).build()`"
    )]
    pub fn new_with_epc(
        fabric_opts: FabricOptions,
        policy: AutoscalePolicy,
        epc: Option<EpcOptions>,
    ) -> Self {
        Self::builder(fabric_opts).policy(policy).epc(epc).build()
    }

    /// [`Deployment::new_with_epc`], plus an explicitly configured
    /// session table.
    #[deprecated(
        since = "0.9.0",
        note = "use `Deployment::builder(fabric).policy(p).epc(epc).sessions(t).build()`"
    )]
    pub fn new_with_sessions(
        fabric_opts: FabricOptions,
        policy: AutoscalePolicy,
        epc: Option<EpcOptions>,
        sessions: SessionTable,
    ) -> Self {
        Self::builder(fabric_opts)
            .policy(policy)
            .epc(epc)
            .sessions(sessions)
            .build()
    }

    /// The deployment's EPC residency ledger, when EPC-aware
    /// co-scheduling is on.
    pub fn epc_ledger(&self) -> Option<Arc<EpcLedger>> {
        self.core.epc.clone()
    }

    /// Register the model a [`DeploySpec`] describes: attach it to the
    /// fabric as a tenant with the spec's weighted-fair share and start
    /// its tier-1 pool attached to the fabric.  Requests must carry
    /// ciphertexts of exactly the spec's `sample_bytes`; a spec without
    /// its own admission limits inherits the deployment-wide default
    /// from [`DeploymentBuilder::admission`].
    ///
    /// `sched_factory(band, domain)` builds one worker's scheduler:
    /// `band` is the tenant index this deployment assigns from a
    /// monotone allocator — concurrent deploys can never share one —
    /// and `domain` is the pool-unique worker-incarnation index.
    /// Together they must select a globally disjoint blinding keyspace
    /// (the launcher uses `band · BLIND_DOMAIN_STRIDE + domain`).
    pub fn deploy_model<S, F>(
        &self,
        spec: DeploySpec,
        sched_factory: S,
        finisher_factory: F,
    ) -> Result<()>
    where
        S: Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static,
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        let DeploySpec {
            model,
            sample_bytes,
            weight,
            slo_ms,
            limits,
            shed_policy,
            cost_multiplier,
            pool: pool_opts,
        } = spec;
        let model = model.as_str();
        let limits = limits.unwrap_or(self.core.default_admission);
        // Exclusive per-name deploy claim: a concurrent duplicate deploy
        // is refused here, BEFORE the EPC ledger is touched — the
        // register/charge pair below must never interleave with another
        // deploy of the same name, or the loser's `register` could
        // overwrite the winner's per-worker footprint mid-charge.
        // Released on every exit path by the drop guard.
        {
            let mut pending = self.core.deploying.lock().unwrap();
            anyhow::ensure!(
                pending.insert(model.to_string()),
                "model `{model}` deploy already in progress"
            );
        }
        let _claim = DeployClaim {
            core: self.core.as_ref(),
            model,
        };
        // Fast duplicate check, then release: pool startup is slow
        // (factor precompute, artifact compilation) and must not stall
        // admission on a live deployment by pinning the registry lock.
        {
            let g = self.core.models.lock().unwrap();
            anyhow::ensure!(
                !g.contains_key(model),
                "model `{model}` is already deployed"
            );
        }
        // EPC admission happens before any other side effect: register
        // the tenant's per-worker footprint and charge the initial
        // fleet.  A deploy that cannot fit fails here, with nothing to
        // roll back — no fabric tenant, no enclave spawned.
        let epc_account = match (&self.core.epc, pool_opts.worker_epc_bytes) {
            (Some(ledger), wb) if wb > 0 => {
                ledger.register(model, wb);
                let initial = pool_opts.workers.max(1);
                ledger.try_charge(model, initial).map_err(|d| {
                    anyhow!("deploying `{model}` would overcommit usable EPC: {d}")
                })?;
                Some(EpcAccount::new(ledger.clone(), model))
            }
            _ => None,
        };
        // The fabric's tenant table is the atomic claim on the model
        // name: a concurrent duplicate deploy fails here, before any
        // pool is started.
        let handle = match self
            .core
            .fabric
            .attach_with_slo(model, weight, slo_ms, finisher_factory)
        {
            Ok(h) => h,
            Err(e) => {
                // release the EPC charge the failed deploy took
                if let Some(acc) = &epc_account {
                    acc.release(pool_opts.workers.max(1));
                }
                return Err(e);
            }
        };
        let band = self.core.next_band.fetch_add(1, Ordering::SeqCst);
        let tenant_tel = self.core.telemetry.register(model);
        let mut pool_opts = pool_opts;
        if pool_opts.slo_ms <= 0.0 {
            pool_opts.slo_ms = slo_ms.unwrap_or(0.0);
        }
        let pool = Arc::new(WorkerPool::start_attached_with_epc(
            pool_opts,
            move |domain| sched_factory(band, domain),
            handle,
            Some(tenant_tel.clone()),
            epc_account,
        ));
        let mut g = self.core.models.lock().unwrap();
        g.insert(
            model.to_string(),
            ModelEntry {
                pool,
                sample_bytes,
                weight,
                slo_ms,
                admission: Arc::new(TenantAdmission::new(limits)),
                shed_policy,
                degrade_to: None,
                telemetry: tenant_tel,
                cost_multiplier,
            },
        );
        Ok(())
    }

    /// Register `model` (see [`Deployment::deploy_model`]).
    #[deprecated(
        since = "0.9.0",
        note = "use `deploy_model(DeploySpec::new(model, bytes).weight(w).slo_ms(slo).pool(p), …)`"
    )]
    #[allow(clippy::too_many_arguments)]
    pub fn deploy<S, F>(
        &self,
        model: &str,
        sample_bytes: usize,
        weight: f64,
        slo_ms: Option<f64>,
        pool_opts: PoolOptions,
        sched_factory: S,
        finisher_factory: F,
    ) -> Result<()>
    where
        S: Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static,
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        self.deploy_model(
            DeploySpec::new(model, sample_bytes)
                .weight(weight)
                .slo_ms(slo_ms)
                .admission(AdmissionLimits::default())
                .pool(pool_opts),
            sched_factory,
            finisher_factory,
        )
    }

    /// Register `model` with explicit admission limits (see
    /// [`Deployment::deploy_model`]).
    #[deprecated(
        since = "0.9.0",
        note = "use `deploy_model(DeploySpec::new(model, bytes).admission(l).shed_policy(s)…, …)`"
    )]
    #[allow(clippy::too_many_arguments)]
    pub fn deploy_with_admission<S, F>(
        &self,
        model: &str,
        sample_bytes: usize,
        weight: f64,
        slo_ms: Option<f64>,
        limits: AdmissionLimits,
        shed_policy: ShedPolicy,
        pool_opts: PoolOptions,
        sched_factory: S,
        finisher_factory: F,
    ) -> Result<()>
    where
        S: Fn(u64, usize) -> Result<BatchScheduler> + Send + Sync + 'static,
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        self.deploy_model(
            DeploySpec::new(model, sample_bytes)
                .weight(weight)
                .slo_ms(slo_ms)
                .admission(limits)
                .shed_policy(shed_policy)
                .pool(pool_opts),
            sched_factory,
            finisher_factory,
        )
    }

    /// Register `target` as `model`'s degraded tier: under
    /// [`ShedPolicy::Degrade`], requests the shed threshold refuses are
    /// rerouted to `target`'s pool (a cheaper strategy tier serving the
    /// same model geometry) instead of being rejected.  Both tenants
    /// must already be deployed with identical sample sizes.
    pub fn set_degrade(&self, model: &str, target: &str) -> Result<()> {
        anyhow::ensure!(
            model != target,
            "model `{model}` cannot degrade to itself"
        );
        let mut g = self.core.models.lock().unwrap();
        let t = g
            .get(target)
            .ok_or_else(|| anyhow!("degrade target `{target}` is not deployed"))?;
        anyhow::ensure!(
            t.degrade_to.is_none(),
            "degrade target `{target}` degrades further (chains are not allowed)"
        );
        let target_bytes = t.sample_bytes;
        // the mirror-image chain: if `model` already serves as someone's
        // degrade target, giving it a target of its own would chain too
        if let Some((owner, _)) = g
            .iter()
            .find(|(_, e)| e.degrade_to.as_deref() == Some(model))
        {
            anyhow::bail!(
                "model `{model}` is `{owner}`'s degrade target (chains are not allowed)"
            );
        }
        let e = g
            .get_mut(model)
            .ok_or_else(|| anyhow!("model `{model}` is not deployed"))?;
        anyhow::ensure!(
            e.sample_bytes == target_bytes,
            "degrade target `{target}` expects {target_bytes}-byte ciphertexts, \
             model `{model}` expects {}",
            e.sample_bytes
        );
        e.degrade_to = Some(target.to_string());
        Ok(())
    }

    /// The deployment's latency telemetry hub (per-tenant, per-stage
    /// windowed histograms — what the SLO autoscaler reads).
    pub fn telemetry(&self) -> Arc<TelemetryHub> {
        self.core.telemetry.clone()
    }

    /// A model's configured latency objective (ms), if any.
    pub fn slo_ms(&self, model: &str) -> Option<f64> {
        let g = self.core.models.lock().unwrap();
        g.get(model).and_then(|e| e.slo_ms)
    }

    pub fn models(&self) -> Vec<String> {
        let g = self.core.models.lock().unwrap();
        let mut v: Vec<String> = g.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn model_count(&self) -> usize {
        self.core.models.lock().unwrap().len()
    }

    /// Admission-checked submit; typed rejections, never a hang.
    ///
    /// Gate order: route + size, session binding, then the tenant's
    /// admission policy (shed threshold, in-flight quota, token-bucket
    /// rate limit).  Any denial after this attempt created the session
    /// binding releases it again, so a refused session can retry against
    /// any model without a phantom collision (regression-pinned).
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> std::result::Result<Channel<InferResponse>, AdmissionError> {
        // snapshot the route, then drop the registry lock — a pool
        // submit can block on ingress backpressure and must not stall
        // other models' admission
        let (pool, admission, shed_policy, degrade_to, telemetry) = {
            let g = self.core.models.lock().unwrap();
            let entry = g.get(model).ok_or_else(|| AdmissionError::UnknownModel {
                model: model.to_string(),
                known: {
                    let mut v: Vec<String> = g.keys().cloned().collect();
                    v.sort();
                    v
                },
            })?;
            if ciphertext.len() != entry.sample_bytes {
                return Err(AdmissionError::WrongSize {
                    model: model.to_string(),
                    expected: entry.sample_bytes,
                    got: ciphertext.len(),
                });
            }
            (
                entry.pool.clone(),
                entry.admission.clone(),
                entry.shed_policy,
                entry.degrade_to.clone(),
                entry.telemetry.clone(),
            )
        };
        // Session binding: first touch claims the id for this model.
        // The table owns the full lifecycle — an expired implicit
        // binding recycles in place, an expired *attested* session is
        // rejected with a typed error until the client refreshes, and
        // the sweep keeps the table bounded by (arrival rate × TTL).
        let table_now_ms = self.core.now_ms();
        let binding = match self.core.sessions.bind(session, model, table_now_ms) {
            Ok(b) => b,
            Err(SessionError::Collision { bound }) => {
                return Err(AdmissionError::SessionCollision {
                    session,
                    bound,
                    requested: model.to_string(),
                });
            }
            Err(SessionError::Expired {
                session,
                refreshable,
            }) => {
                return Err(AdmissionError::SessionExpired {
                    session,
                    refreshable,
                });
            }
            // `bind` never performs control-MAC auth, so `Unauthorized`
            // cannot surface here; keep the mapping total regardless.
            Err(SessionError::Unknown { session })
            | Err(SessionError::Unauthorized { session }) => {
                return Err(AdmissionError::SessionExpired {
                    session,
                    refreshable: false,
                });
            }
        };
        let newly_bound = binding.newly_bound;
        // The keystream nonce the enclave derives is the epoch-folded
        // session word, so a refreshed session never replays a retired
        // keystream (epoch 0 is bit-identical to the bare id).
        let session_word = crypto::session_word(session, binding.epoch);
        let unbind = |this: &Self| {
            if newly_bound {
                this.core.sessions.unbind(session);
            }
        };
        // Admission gate: the bucket clock is wall milliseconds since
        // the deployment epoch; depth is the tenant's tier-1 backlog.
        let now_ms = self.core.epoch.elapsed().as_secs_f64() * 1e3;
        let permit = match admission.admit(now_ms, pool.queue_depth()) {
            Ok(permit) => permit,
            Err(AdmissionDenial::Shed { depth, threshold })
                if shed_policy == ShedPolicy::Degrade && degrade_to.is_some() =>
            {
                // Degrade: serve the request from the cheaper tier's
                // pool — through that tenant's OWN admission gate, so a
                // quota/rate/shed limit configured on the degraded tier
                // still bounds the spillover.  The degraded tenant tags
                // its own tasks, so fabric fairness and telemetry
                // account it separately.
                let target = degrade_to.unwrap();
                let degraded = {
                    let g = self.core.models.lock().unwrap();
                    g.get(&target)
                        .map(|e| (e.pool.clone(), e.admission.clone(), e.telemetry.clone()))
                };
                let shed = |this: &Self| {
                    telemetry.admission().record_shed();
                    unbind(this);
                    AdmissionError::Shed {
                        model: model.to_string(),
                        depth,
                        threshold,
                        retry_after_ms: queue_hint_ms(&telemetry),
                        epc_limited: telemetry.scale().epc_limited(),
                    }
                };
                let Some((dpool, dadm, dtel)) = degraded else {
                    return Err(shed(self));
                };
                let Ok(dpermit) = dadm.admit(now_ms, dpool.queue_depth()) else {
                    // the degraded tier is saturated too: a plain shed
                    return Err(shed(self));
                };
                return match dpool.submit_with_permit(&target, ciphertext, session_word, dpermit)
                {
                    Ok(reply) => {
                        telemetry.admission().record_degraded();
                        dtel.admission().record_admitted();
                        Ok(reply)
                    }
                    Err(_) => {
                        unbind(self);
                        Err(AdmissionError::Unavailable {
                            model: model.to_string(),
                        })
                    }
                };
            }
            Err(denial) => {
                unbind(self);
                return Err(match denial {
                    AdmissionDenial::RateLimited { retry_after_ms } => {
                        telemetry.admission().record_rate_limited();
                        AdmissionError::RateLimited {
                            model: model.to_string(),
                            retry_after_ms: clamp_hint_ms(retry_after_ms),
                        }
                    }
                    AdmissionDenial::QuotaExceeded { limit, .. } => {
                        telemetry.admission().record_quota_rejected();
                        AdmissionError::QuotaExceeded {
                            model: model.to_string(),
                            limit,
                            retry_after_ms: drain_hint_ms(&telemetry),
                        }
                    }
                    AdmissionDenial::Shed { depth, threshold } => {
                        telemetry.admission().record_shed();
                        AdmissionError::Shed {
                            model: model.to_string(),
                            depth,
                            threshold,
                            retry_after_ms: queue_hint_ms(&telemetry),
                            epc_limited: telemetry.scale().epc_limited(),
                        }
                    }
                });
            }
        };
        match pool.submit_with_permit(model, ciphertext, session_word, permit) {
            Ok(reply) => {
                // counted only once the request actually entered the
                // pool — a shutdown-time failure must not inflate the
                // admitted audit trail
                telemetry.admission().record_admitted();
                Ok(reply)
            }
            Err(_) => {
                // the request never entered the pool: release a binding
                // this attempt created so the session can retry anywhere
                // (the in-flight permit was dropped with the request)
                unbind(self);
                Err(AdmissionError::Unavailable {
                    model: model.to_string(),
                })
            }
        }
    }

    /// The deployment's session table (binding, epoch, expiry state).
    pub fn sessions(&self) -> &SessionTable {
        &self.core.sessions
    }

    /// Milliseconds on the deployment clock — the session table's and
    /// the admission buckets' shared time base.
    pub fn now_ms(&self) -> u64 {
        self.core.now_ms()
    }

    /// Is `model` deployed?  The front door checks this before minting
    /// attestation evidence or session state for a HELLO.
    pub fn has_model(&self, model: &str) -> bool {
        self.core.models.lock().unwrap().contains_key(model)
    }

    /// Issue a fresh attested session bound to `model`, holding `auth`
    /// as its control-frame MAC key (the network front door calls this
    /// after a successful attestation handshake).
    pub fn establish_session(&self, model: &str, auth: [u8; 32]) -> SessionGrant {
        self.core.sessions.establish(model, auth, self.core.now_ms())
    }

    /// Bump the session's keystream epoch and extend its TTL (trusted
    /// in-process path; the wire uses the MAC-gated variant).
    pub fn refresh_session(
        &self,
        session: u64,
    ) -> std::result::Result<SessionGrant, SessionError> {
        self.core.sessions.refresh(session, self.core.now_ms())
    }

    /// [`Deployment::refresh_session`] gated on the session's control
    /// MAC — the only refresh path the network front door exposes.
    pub fn refresh_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<SessionGrant, SessionError> {
        self.core.sessions.refresh_authed(session, tag, self.core.now_ms())
    }

    /// Drop a session outright; returns whether it existed (trusted
    /// in-process path; the wire uses the MAC-gated variant).
    pub fn revoke_session(&self, session: u64) -> bool {
        self.core.sessions.revoke(session)
    }

    /// [`Deployment::revoke_session`] gated on the session's control
    /// MAC — the only revoke path the network front door exposes.
    pub fn revoke_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<bool, SessionError> {
        self.core.sessions.revoke_authed(session, tag)
    }

    /// The session's live keystream epoch (the client must encrypt
    /// under the matching session word), or why it cannot serve.
    pub fn session_epoch(&self, session: u64) -> std::result::Result<u32, SessionError> {
        self.core.sessions.epoch_of(session, self.core.now_ms())
    }

    /// A tenant's admission counters (admitted / rate-limited / quota /
    /// shed / degraded), when deployed.
    pub fn admission_snapshot(&self, model: &str) -> Option<AdmissionSnapshot> {
        let g = self.core.models.lock().unwrap();
        g.get(model).map(|e| e.telemetry.admission().snapshot())
    }

    /// A tenant's autoscale counters (EPC-denied grows, workers
    /// reclaimed from it, the live EPC-limited flag), when deployed.
    pub fn scale_snapshot(&self, model: &str) -> Option<ScaleSnapshot> {
        let g = self.core.models.lock().unwrap();
        g.get(model).map(|e| e.telemetry.scale().snapshot())
    }

    /// Blocking convenience (records client latency in the model's pool).
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let reply = self.submit(model, ciphertext, session)?;
        let resp = reply
            .recv()
            .ok_or_else(|| anyhow!("reply channel closed"))?;
        let pool = {
            let g = self.core.models.lock().unwrap();
            g.get(model).map(|e| e.pool.clone())
        };
        if let Some(pool) = pool {
            pool.metrics
                .lock()
                .unwrap()
                .latency_ms
                .record(resp.latency_ms);
        }
        Ok(resp)
    }

    /// Pending work: tier-1 backlogs of every pool plus the fabric's
    /// tier-2 queue.
    pub fn queue_depth(&self) -> usize {
        let g = self.core.models.lock().unwrap();
        let t1: usize = g.values().map(|e| e.pool.queue_depth()).sum();
        t1 + self.core.fabric.queue_depth()
    }

    /// Current fabric lane count.
    pub fn lane_count(&self) -> usize {
        self.core.fabric.lane_count()
    }

    /// A model's current tier-1 worker count (0 if unknown).
    pub fn active_workers(&self, model: &str) -> usize {
        let g = self.core.models.lock().unwrap();
        g.get(model).map(|e| e.pool.active_workers()).unwrap_or(0)
    }

    /// Run one autoscaler pass now (the background pump calls this on
    /// its cadence; tests call it directly for determinism).
    pub fn autoscale_tick(&self) {
        self.core.tick();
    }

    /// Start the background autoscaler (idempotent).
    pub fn enable_autoscaler(&mut self) {
        if self.pump.is_some() {
            return;
        }
        let core = self.core.clone();
        let stop = self.stop.clone();
        let tick = Duration::from_millis(self.core.policy.tick_ms.max(1));
        self.pump = Some(
            std::thread::Builder::new()
                .name("origami-deploy-autoscale".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        core.tick();
                        std::thread::sleep(tick);
                    }
                })
                .expect("spawn deployment autoscaler"),
        );
    }

    /// Stop the autoscaler, drain and shut down every pool, then the
    /// fabric; returns the final metrics.
    pub fn shutdown(mut self) -> DeploymentMetrics {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        // the sweeper holds a core clone: join it before try_unwrap
        if let Some(s) = self.sweeper.take() {
            let _ = s.join();
        }
        let core = self.core.clone();
        drop(self); // releases the struct's Arc (pump already stopped)
        match Arc::try_unwrap(core) {
            Ok(core) => {
                let mut models = BTreeMap::new();
                for (name, e) in core.models.into_inner().unwrap() {
                    let pm = match Arc::try_unwrap(e.pool) {
                        Ok(pool) => pool.shutdown(),
                        // a straggling tick still holds the pool (it will
                        // stop via Drop when released): snapshot metrics
                        Err(arc) => arc.metrics.lock().unwrap().clone(),
                    };
                    models.insert(name, pm);
                }
                DeploymentMetrics {
                    models,
                    fabric: core.fabric.shutdown(),
                }
            }
            // unreachable: nothing else holds the core once the pump is
            // joined; degrade to empty metrics rather than panic
            Err(_) => DeploymentMetrics {
                models: BTreeMap::new(),
                fabric: FabricMetrics::default(),
            },
        }
    }
}

impl Drop for Deployment {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
        if let Some(s) = self.sweeper.take() {
            let _ = s.join();
        }
    }
}

/// The client-facing submission surface, abstracted over *where* the
/// serving happens: a local [`Deployment`] and the multi-node
/// [`ClusterRouter`](super::cluster::ClusterRouter) both implement it,
/// and the wire front door ([`NetServer`](super::net::NetServer)) and
/// the simulator talk to the trait — single-node and clustered serving
/// are interchangeable behind one interface.
///
/// Object-safe on purpose: servers hold an `Arc<dyn Frontend>`.
pub trait Frontend: Send + Sync {
    /// Admission-checked submit; typed rejections, never a hang (see
    /// [`Deployment::submit`]).
    fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> std::result::Result<Channel<InferResponse>, AdmissionError>;

    /// Blocking convenience around [`Frontend::submit`].
    fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse>;

    /// Is `model` served here?  The front door checks this before
    /// minting attestation evidence or session state for a HELLO.
    fn has_model(&self, model: &str) -> bool;

    /// Every model served, sorted.
    fn models(&self) -> Vec<String>;

    /// Milliseconds on the serving clock (the session tables' and
    /// admission buckets' shared time base).
    fn now_ms(&self) -> u64;

    /// The session TTL granted at establish/refresh time.
    fn session_ttl_ms(&self) -> u64;

    /// Issue a fresh attested session bound to `model`, holding `auth`
    /// as its control-frame MAC key.
    fn establish_session(&self, model: &str, auth: [u8; 32]) -> SessionGrant;

    /// MAC-gated epoch bump + TTL extension (the only refresh path the
    /// wire exposes).
    fn refresh_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<SessionGrant, SessionError>;

    /// MAC-gated session drop (the only revoke path the wire exposes).
    fn revoke_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<bool, SessionError>;

    /// The session's live keystream epoch, or why it cannot serve.
    fn session_epoch(&self, session: u64) -> std::result::Result<u32, SessionError>;

    /// The model a live session is bound to, if any.
    fn bound_model(&self, session: u64) -> Option<String>;
}

impl Frontend for Deployment {
    fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> std::result::Result<Channel<InferResponse>, AdmissionError> {
        Deployment::submit(self, model, ciphertext, session)
    }

    fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        Deployment::infer_blocking(self, model, ciphertext, session)
    }

    fn has_model(&self, model: &str) -> bool {
        Deployment::has_model(self, model)
    }

    fn models(&self) -> Vec<String> {
        Deployment::models(self)
    }

    fn now_ms(&self) -> u64 {
        Deployment::now_ms(self)
    }

    fn session_ttl_ms(&self) -> u64 {
        self.core.sessions.ttl_ms()
    }

    fn establish_session(&self, model: &str, auth: [u8; 32]) -> SessionGrant {
        Deployment::establish_session(self, model, auth)
    }

    fn refresh_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<SessionGrant, SessionError> {
        Deployment::refresh_session_authed(self, session, tag)
    }

    fn revoke_session_authed(
        &self,
        session: u64,
        tag: &[u8; 32],
    ) -> std::result::Result<bool, SessionError> {
        Deployment::revoke_session_authed(self, session, tag)
    }

    fn session_epoch(&self, session: u64) -> std::result::Result<u32, SessionError> {
        Deployment::session_epoch(self, session)
    }

    fn bound_model(&self, session: u64) -> Option<String> {
        self.core.sessions.bound_model(session, self.core.now_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_rejected() {
        let r = Router::new();
        assert!(r.submit("nope", vec![], 0).is_err());
        assert!(r.models().is_empty());
    }

    #[test]
    fn empty_deployment_rejects_with_typed_error() {
        let dep = Deployment::builder(FabricOptions::default()).build();
        let err = dep.submit("nope", vec![], 0).unwrap_err();
        assert_eq!(
            err,
            AdmissionError::UnknownModel {
                model: "nope".into(),
                known: vec![],
            }
        );
        assert_eq!(dep.model_count(), 0);
        let m = dep.shutdown();
        assert!(m.models.is_empty());
    }

    fn signals(depth: usize, active: usize) -> ScaleSignals {
        ScaleSignals {
            depth,
            active,
            p95_ms: None,
            window_samples: 0,
            slo_ms: None,
            ticks_since_scale: None,
            epc_headroom_workers: None,
            cost_multiplier: 1.0,
        }
    }

    #[test]
    fn depth_decide_matches_watermarks() {
        let p = AutoscalePolicy::default(); // high 4, low 1
        assert_eq!(p.decide(&signals(9, 2)), Some(3), "9 > 4×2 grows");
        assert_eq!(p.decide(&signals(8, 2)), None, "8 = 4×2 holds");
        assert_eq!(p.decide(&signals(1, 2)), Some(1), "1 ≤ 1×(2−1) shrinks");
        assert_eq!(p.decide(&signals(2, 2)), None);
        assert_eq!(p.decide(&signals(0, 1)), None, "floor: never below 1");
    }

    #[test]
    fn cost_multiplier_weighs_depth_in_decide() {
        let p = AutoscalePolicy::default(); // high 4, low 1
        // Pinned: the same queue that holds at baseline cost grows once
        // the tenant runs oblivious kernels — 4 ≤ 4×1 holds, but
        // 4 × OBLIVIOUS_COST_MULTIPLIER = 6 > 4 grows.
        let mut s = signals(4, 1);
        assert_eq!(p.decide(&s), None, "4 = 4×1 holds at baseline cost");
        s.cost_multiplier = crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER;
        assert_eq!(p.decide(&s), Some(2), "6 effective > 4 grows");
        // ...and the same near-idle queue that would shrink at baseline
        // is held: 1 ≤ 1×(2−1) shrinks, 1.5 effective does not.
        let mut s = signals(1, 2);
        assert_eq!(p.decide(&s), Some(1), "1 ≤ 1×1 shrinks at baseline");
        s.cost_multiplier = crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER;
        assert_eq!(p.decide(&s), None, "1.5 effective blocks the shrink");
        // sub-1.0 multipliers are clamped: never cheaper than baseline
        let mut s = signals(9, 2);
        s.cost_multiplier = 0.1;
        assert_eq!(p.decide(&s), Some(3), "0.1 clamps to 1.0: 9 > 8 grows");
    }

    #[test]
    fn slo_decide_scales_on_p95_error_with_depth_fallback() {
        let p = AutoscalePolicy {
            mode: ScaleMode::SloP95,
            min_window_samples: 4,
            slo_shrink_margin: 0.5,
            ..AutoscalePolicy::default()
        };
        let mut s = signals(0, 2);
        s.slo_ms = Some(20.0);
        s.window_samples = 10;
        s.p95_ms = Some(25.0);
        assert_eq!(p.decide(&s), Some(3), "p95 over SLO grows");
        s.p95_ms = Some(15.0);
        assert_eq!(p.decide(&s), None, "inside SLO, above shrink margin");
        s.p95_ms = Some(5.0);
        assert_eq!(p.decide(&s), Some(1), "far under SLO with empty queue shrinks");
        s.depth = 3;
        assert_eq!(p.decide(&s), None, "standing backlog blocks the shrink");
        // cold start: too few samples → depth rule decides
        s.depth = 9;
        s.window_samples = 2;
        s.p95_ms = Some(25.0);
        assert_eq!(p.decide(&s), Some(3), "depth fallback grows");
        // no SLO configured → depth rule even in SLO mode
        let mut s2 = signals(9, 2);
        s2.window_samples = 100;
        s2.p95_ms = Some(1.0);
        assert_eq!(p.decide(&s2), Some(3));
    }

    #[test]
    fn epc_headroom_caps_grows_but_never_shrinks() {
        let p = AutoscalePolicy::default(); // high 4, low 1
        // a loud depth signal grows freely with headroom…
        let mut s = signals(100, 2);
        s.epc_headroom_workers = Some(3);
        assert_eq!(p.decide(&s), Some(3));
        // …and is suppressed entirely at zero headroom
        s.epc_headroom_workers = Some(0);
        assert_eq!(p.decide(&s), None, "no grow into a paging storm");
        // None = not EPC-accounted (fabric lanes): unchanged behavior
        s.epc_headroom_workers = None;
        assert_eq!(p.decide(&s), Some(3));
        // shrinks are never EPC-capped — they only return memory
        let mut s = signals(0, 3);
        s.epc_headroom_workers = Some(0);
        assert_eq!(p.decide(&s), Some(2));
        // p95 mode honors the cap too
        let p95 = AutoscalePolicy {
            mode: ScaleMode::SloP95,
            min_window_samples: 1,
            ..AutoscalePolicy::default()
        };
        let mut s = signals(0, 2);
        s.slo_ms = Some(10.0);
        s.window_samples = 8;
        s.p95_ms = Some(50.0);
        s.epc_headroom_workers = Some(0);
        assert_eq!(p95.decide(&s), None, "SLO breach cannot override EPC");
        s.epc_headroom_workers = Some(1);
        assert_eq!(p95.decide(&s), Some(3));
    }

    #[test]
    fn cooldown_holds_after_a_scale_event() {
        let p = AutoscalePolicy {
            cooldown_ticks: 3,
            ..AutoscalePolicy::default()
        };
        let mut s = signals(100, 2);
        s.ticks_since_scale = Some(1);
        assert_eq!(p.decide(&s), None, "inside the cooldown window");
        s.ticks_since_scale = Some(2);
        assert_eq!(p.decide(&s), None);
        s.ticks_since_scale = Some(3);
        assert_eq!(p.decide(&s), Some(3), "cooldown expired");
        s.ticks_since_scale = None;
        assert_eq!(p.decide(&s), Some(3), "never-scaled targets act at once");
    }

    #[test]
    fn admission_errors_display_precisely() {
        let e = AdmissionError::WrongSize {
            model: "m".into(),
            expected: 8,
            got: 3,
        };
        assert_eq!(
            e.to_string(),
            "model `m` expects 8-byte ciphertexts, got 3"
        );
        let e = AdmissionError::SessionCollision {
            session: 7,
            bound: "a".into(),
            requested: "b".into(),
        };
        assert!(e.to_string().contains("session 7"));
        // typed errors flow into anyhow for callers that want that
        let any: anyhow::Error = e.into();
        assert!(format!("{any}").contains("bound to model `a`"));
    }

    #[test]
    fn admission_denials_carry_retry_hints() {
        let e = AdmissionError::RateLimited {
            model: "m".into(),
            retry_after_ms: 12,
        };
        assert_eq!(e.retry_after_ms(), Some(12));
        assert!(e.to_string().contains("retry after 12 ms"));

        let e = AdmissionError::QuotaExceeded {
            model: "m".into(),
            limit: 64,
            retry_after_ms: 7,
        };
        assert_eq!(e.retry_after_ms(), Some(7));
        assert!(e.to_string().contains("64 requests in flight"));

        let e = AdmissionError::Shed {
            model: "m".into(),
            depth: 9,
            threshold: 8,
            retry_after_ms: 0,
            epc_limited: false,
        };
        assert_eq!(e.retry_after_ms(), Some(0));
        assert!(e.to_string().contains("queue depth 9"));
        assert!(!e.to_string().contains("EPC"));

        // an EPC-limited tenant says so in its shed hint: the backlog
        // will not scale away, enclave memory is the binding constraint
        let e = AdmissionError::Shed {
            model: "m".into(),
            depth: 9,
            threshold: 8,
            retry_after_ms: 4,
            epc_limited: true,
        };
        assert!(e.to_string().contains("tier-1 growth is EPC-limited"));

        let e = AdmissionError::Unavailable { model: "m".into() };
        assert_eq!(e.retry_after_ms(), None, "shutdowns are not load hints");
    }

    #[test]
    fn hint_clamping_is_finite_and_rounds_up() {
        assert_eq!(clamp_hint_ms(0.0), 0);
        assert_eq!(clamp_hint_ms(0.2), 1, "sub-ms deficits still hint 1 ms");
        assert_eq!(clamp_hint_ms(12.0), 12);
        assert_eq!(clamp_hint_ms(f64::INFINITY), MAX_RETRY_HINT_MS as u64);
        assert_eq!(clamp_hint_ms(-5.0), 0);
    }

    #[test]
    fn set_degrade_requires_deployed_tenants() {
        let dep = Deployment::builder(FabricOptions::default()).build();
        assert!(dep.set_degrade("a", "a").is_err(), "self-degrade refused");
        assert!(
            dep.set_degrade("a", "b").is_err(),
            "unknown tenants refused"
        );
        dep.shutdown();
    }
}
