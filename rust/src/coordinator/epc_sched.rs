//! EPC-aware co-scheduling of tier-1 enclave pools: a global residency
//! ledger plus a packing policy the deployment autoscaler consults
//! before growing any pool.
//!
//! Enclave memory — not FLOPs — is the resource that decides how many
//! models an SGX server can host: every tier-1 worker pins its model's
//! resident footprint (base runtime + resident params + peak feature
//! maps + blinding buffers, the Table-I decomposition in
//! [`crate::strategies::memory`]) inside a ~93 MB usable EPC, and
//! overcommitting that budget triggers per-page encrypted paging that
//! erases the speedup the tier split buys (paper §I).  The queue-depth
//! and p95 autoscalers are blind to this: two paper-scale tenants
//! scaling on backlog alone will happily grow into a mutual paging
//! storm.
//!
//! Three pieces make residency a first-class scheduling input:
//!
//! - [`EpcLedger`] — the global accountant.  Every tier-1 worker is
//!   charged its model's per-worker footprint on spawn and credited on
//!   retire; charges are transactional ([`EpcLedger::try_charge`] is
//!   all-or-nothing), so the ledger can never drift from the worker
//!   fleet it describes.  Capacity is `usable EPC × overcommit`
//!   (`--epc-overcommit`; 1.0 packs exactly, above 1.0 tolerates
//!   bounded paging).
//! - [`EpcPacker`] — the reclaim policy.  When a grow would overcommit,
//!   the packer looks for *idle* workers parked above their pool's
//!   floor on other tenants and frees just enough of them, taking first
//!   from the tenant most over-provisioned relative to its weighted
//!   fabric share.  If no reclaim covers the deficit the grow is denied
//!   — never partially applied.
//! - [`ScaleDenied`] — the typed denial.  Denials land in per-tenant
//!   telemetry ([`ScaleCounters`](super::telemetry::ScaleCounters)),
//!   and a tenant whose growth is EPC-limited says so in its shed
//!   hints, so a client seeing `AdmissionError::Shed` can tell "the
//!   autoscaler is behind" from "the box is full".
//!
//! The ledger is pure bookkeeping over an external clock-free state, so
//! the deterministic serving simulator
//! ([`crate::harness::sim::replay_epc_packing`]) replays the exact
//! production charge/reclaim/deny decisions over scripted traces — what
//! `benches/fig18_epc_packing.rs` measures.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// EPC scheduling geometry: the usable budget and the overcommit factor.
#[derive(Debug, Clone, Copy)]
pub struct EpcOptions {
    /// Usable EPC bytes (after SGX metadata overhead; see
    /// [`Config::usable_epc_bytes`](crate::config::Config::usable_epc_bytes)).
    pub usable_bytes: u64,
    /// Capacity multiplier: 1.0 packs workers exactly into the usable
    /// budget; above 1.0 tolerates that much overcommit (bounded
    /// paging); must be > 0.
    pub overcommit: f64,
}

impl EpcOptions {
    /// The ledger capacity these options describe.
    pub fn capacity_bytes(&self) -> u64 {
        (self.usable_bytes as f64 * self.overcommit.max(0.0)) as u64
    }
}

/// A grow the EPC co-scheduler refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleDenied {
    /// Charging the requested workers would overcommit the usable EPC
    /// (after any reclaim the packer could find).
    EpcExhausted {
        /// Tenant whose grow was refused.
        tenant: String,
        /// Bytes the refused charge needed.
        needed_bytes: u64,
        /// Ledger capacity (usable EPC × overcommit).
        capacity_bytes: u64,
        /// Bytes already charged across all tenants.
        charged_bytes: u64,
    },
}

impl fmt::Display for ScaleDenied {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScaleDenied::EpcExhausted {
                tenant,
                needed_bytes,
                capacity_bytes,
                charged_bytes,
            } => write!(
                f,
                "tenant `{tenant}` grow denied: {needed_bytes} B needed, \
                 {charged_bytes}/{capacity_bytes} B of usable EPC charged"
            ),
        }
    }
}

impl std::error::Error for ScaleDenied {}

#[derive(Default)]
struct LedgerInner {
    charged: u64,
    tenants: HashMap<String, TenantCharge>,
}

struct TenantCharge {
    worker_bytes: u64,
    workers: usize,
}

/// The global EPC residency accountant (see module docs).  Shared by a
/// deployment and every pool it starts; all operations are
/// transactional under one lock.
pub struct EpcLedger {
    capacity: u64,
    inner: Mutex<LedgerInner>,
}

impl EpcLedger {
    pub fn new(opts: EpcOptions) -> Self {
        Self {
            capacity: opts.capacity_bytes().max(1),
            inner: Mutex::new(LedgerInner::default()),
        }
    }

    /// Ledger capacity (usable EPC × overcommit).
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity
    }

    /// Bytes currently charged across all tenants.
    pub fn charged_bytes(&self) -> u64 {
        self.inner.lock().unwrap().charged
    }

    /// Uncharged capacity.
    pub fn free_bytes(&self) -> u64 {
        self.capacity.saturating_sub(self.charged_bytes())
    }

    /// Declare a tenant's per-worker resident footprint.  Idempotent;
    /// re-registering updates the footprint only while the tenant has
    /// **no charged workers** — a live tenant's rate is immutable, so
    /// [`EpcLedger::release`] always credits exactly what was charged
    /// and the ledger can never leak or mint capacity through a
    /// mid-flight rate change.
    pub fn register(&self, tenant: &str, worker_bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        g.tenants
            .entry(tenant.to_string())
            .and_modify(|t| {
                if t.workers == 0 {
                    t.worker_bytes = worker_bytes;
                }
            })
            .or_insert(TenantCharge {
                worker_bytes,
                workers: 0,
            });
    }

    /// Charge `n` more workers of `tenant`'s footprint — all or nothing.
    pub fn try_charge(&self, tenant: &str, n: usize) -> Result<(), ScaleDenied> {
        if n == 0 {
            return Ok(());
        }
        let mut g = self.inner.lock().unwrap();
        let Some(t) = g.tenants.get(tenant) else {
            return Ok(()); // unregistered tenants are not EPC-accounted
        };
        let needed = t.worker_bytes.saturating_mul(n as u64);
        if g.charged.saturating_add(needed) > self.capacity {
            return Err(ScaleDenied::EpcExhausted {
                tenant: tenant.to_string(),
                needed_bytes: needed,
                capacity_bytes: self.capacity,
                charged_bytes: g.charged,
            });
        }
        g.charged += needed;
        g.tenants.get_mut(tenant).unwrap().workers += n;
        Ok(())
    }

    /// Credit `n` retired workers of `tenant` back to the ledger.
    /// Releasing more workers than are charged is a no-op beyond zero —
    /// the ledger can never go negative or double-credit.
    pub fn release(&self, tenant: &str, n: usize) {
        if n == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        let Some(t) = g.tenants.get_mut(tenant) else {
            return;
        };
        let freed = n.min(t.workers);
        t.workers -= freed;
        let bytes = t.worker_bytes.saturating_mul(freed as u64);
        g.charged = g.charged.saturating_sub(bytes);
    }

    /// Workers currently charged for a tenant.
    pub fn workers(&self, tenant: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .tenants
            .get(tenant)
            .map(|t| t.workers)
            .unwrap_or(0)
    }

    /// How many *more* workers of `tenant`'s footprint the free capacity
    /// funds right now (`usize::MAX` for unregistered or zero-footprint
    /// tenants — they are not EPC-bound).
    pub fn headroom_workers(&self, tenant: &str) -> usize {
        let g = self.inner.lock().unwrap();
        let Some(t) = g.tenants.get(tenant) else {
            return usize::MAX;
        };
        if t.worker_bytes == 0 {
            return usize::MAX;
        }
        (self.capacity.saturating_sub(g.charged) / t.worker_bytes) as usize
    }
}

/// One tenant's state offered to the packer as a reclaim candidate.
#[derive(Debug, Clone)]
pub struct ReclaimCandidate {
    pub tenant: String,
    /// Workers currently running.
    pub active: usize,
    /// Autoscale floor — reclaim never shrinks below it.
    pub floor: usize,
    /// The tenant's queued tier-1 requests; only idle (depth 0) tenants
    /// donate workers.
    pub queue_depth: usize,
    /// Weighted-fair fabric share (reclaim order: most over-provisioned
    /// per unit of share donates first).
    pub weight: f64,
    /// Per-worker resident footprint.
    pub worker_bytes: u64,
    /// Per-item kernel cost multiplier (1.0 = baseline; oblivious
    /// tenants carry [`OBLIVIOUS_COST_MULTIPLIER`]).  A donor's
    /// effective share is `weight × multiplier`: a tenant whose workers
    /// clear their queue more slowly is proportionally less
    /// over-provisioned at the same worker count, so it donates later.
    ///
    /// [`OBLIVIOUS_COST_MULTIPLIER`]: crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER
    pub cost_multiplier: f64,
}

/// The packing policy: given a byte deficit and the other tenants'
/// states, pick which idle workers to reclaim (see module docs).  Pure —
/// the deployment tick applies the plan, and the simulator replays it.
pub struct EpcPacker;

impl EpcPacker {
    /// Plan reclaims freeing at least `needed_bytes`: per-tenant retire
    /// counts, or `None` when even taking every eligible worker falls
    /// short (then the grow is denied instead of half-dismantling idle
    /// pools for nothing).
    ///
    /// Eligible donors are idle (`queue_depth == 0`) with `active >
    /// floor`; donors give one worker at a time, always taking next from
    /// the tenant with the highest `active / (weight × cost_multiplier)`
    /// (ties: lexicographic tenant order, so plans are deterministic).
    pub fn plan_reclaim(
        candidates: &[ReclaimCandidate],
        needed_bytes: u64,
    ) -> Option<Vec<(String, usize)>> {
        if needed_bytes == 0 {
            return Some(Vec::new());
        }
        // (remaining donatable, active, effective share, bytes, tenant);
        // effective share = weight × cost multiplier (clamped ≥ 1.0),
        // so slower-kernel tenants donate later among equals
        let mut donors: Vec<(usize, usize, f64, u64, &str)> = candidates
            .iter()
            .filter(|c| {
                c.queue_depth == 0 && c.active > c.floor && c.worker_bytes > 0 && c.weight > 0.0
            })
            .map(|c| {
                (
                    c.active - c.floor,
                    c.active,
                    c.weight * c.cost_multiplier.max(1.0),
                    c.worker_bytes,
                    c.tenant.as_str(),
                )
            })
            .collect();
        // no pre-sort needed: the pick below tie-breaks on tenant name,
        // so donor selection is independent of candidate order
        let mut freed = 0u64;
        let mut taken: HashMap<&str, usize> = HashMap::new();
        while freed < needed_bytes {
            // next donor: highest active-per-share among those with
            // workers left to give
            let pick = donors
                .iter_mut()
                .filter(|d| d.0 > 0)
                .max_by(|a, b| {
                    let ra = a.1 as f64 / a.2;
                    let rb = b.1 as f64 / b.2;
                    ra.partial_cmp(&rb).unwrap().then(b.4.cmp(a.4))
                })?;
            pick.0 -= 1;
            pick.1 -= 1;
            freed += pick.3;
            *taken.entry(pick.4).or_insert(0) += 1;
        }
        let mut plan: Vec<(String, usize)> = taken
            .into_iter()
            .map(|(t, n)| (t.to_string(), n))
            .collect();
        plan.sort();
        Some(plan)
    }
}

/// A pool's handle on the shared ledger: the tenant name it charges
/// under.  The pool's `scale_to` charges grows and credits retires
/// through this, making worker spawn/retire and EPC accounting one
/// transaction.
#[derive(Clone)]
pub struct EpcAccount {
    ledger: Arc<EpcLedger>,
    tenant: String,
}

impl EpcAccount {
    pub fn new(ledger: Arc<EpcLedger>, tenant: &str) -> Self {
        Self {
            ledger,
            tenant: tenant.to_string(),
        }
    }

    pub fn try_charge(&self, n: usize) -> Result<(), ScaleDenied> {
        self.ledger.try_charge(&self.tenant, n)
    }

    pub fn release(&self, n: usize) {
        self.ledger.release(&self.tenant, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger(capacity: u64) -> EpcLedger {
        EpcLedger::new(EpcOptions {
            usable_bytes: capacity,
            overcommit: 1.0,
        })
    }

    #[test]
    fn charges_are_transactional_and_bounded() {
        let l = ledger(100);
        l.register("a", 40);
        l.register("b", 30);
        assert!(l.try_charge("a", 2).is_ok(), "80 of 100 fits");
        assert_eq!(l.charged_bytes(), 80);
        // all-or-nothing: b×1 (30 B) does not fit, and nothing sticks
        let denied = l.try_charge("b", 1).unwrap_err();
        match &denied {
            ScaleDenied::EpcExhausted {
                tenant,
                needed_bytes,
                capacity_bytes,
                charged_bytes,
            } => {
                assert_eq!(tenant, "b");
                assert_eq!(*needed_bytes, 30);
                assert_eq!(*capacity_bytes, 100);
                assert_eq!(*charged_bytes, 80);
            }
        }
        assert!(denied.to_string().contains("80/100"));
        assert_eq!(l.charged_bytes(), 80, "denied charge left no residue");
        assert_eq!(l.workers("b"), 0);
        // freeing one `a` worker funds the `b` grow
        l.release("a", 1);
        assert_eq!(l.charged_bytes(), 40);
        assert!(l.try_charge("b", 1).is_ok());
        assert_eq!(l.charged_bytes(), 70);
        assert_eq!(l.workers("a"), 1);
        assert_eq!(l.workers("b"), 1);
    }

    #[test]
    fn release_never_leaks_or_double_credits() {
        // the retire-path regression: releasing more than charged (a
        // double release, mirroring a drop-guard misfire) must clamp
        let l = ledger(100);
        l.register("a", 25);
        l.try_charge("a", 3).unwrap();
        l.release("a", 2);
        l.release("a", 2); // one over — clamps at zero workers
        assert_eq!(l.workers("a"), 0);
        assert_eq!(l.charged_bytes(), 0, "no negative/underflowed charge");
        l.release("a", 1); // fully idle: still a no-op
        assert_eq!(l.charged_bytes(), 0);
        // and a charge/release cycle returns to the exact baseline
        l.try_charge("a", 4).unwrap();
        l.release("a", 4);
        assert_eq!(l.charged_bytes(), 0);
        assert_eq!(l.free_bytes(), 100);
    }

    #[test]
    fn live_tenants_keep_their_registered_rate() {
        // a re-register while workers are charged must not change the
        // rate: release always credits exactly what charge debited
        let l = ledger(100);
        l.register("a", 40);
        l.try_charge("a", 2).unwrap();
        l.register("a", 10); // ignored: 2 workers are live at 40 B
        l.release("a", 2);
        assert_eq!(l.charged_bytes(), 0, "credits match the charges");
        // idle again: the new rate now takes
        l.register("a", 10);
        l.try_charge("a", 3).unwrap();
        assert_eq!(l.charged_bytes(), 30);
        l.release("a", 3);
        assert_eq!(l.charged_bytes(), 0);
    }

    #[test]
    fn unregistered_tenants_are_not_accounted() {
        let l = ledger(10);
        assert!(l.try_charge("ghost", 100).is_ok());
        assert_eq!(l.charged_bytes(), 0);
        assert_eq!(l.headroom_workers("ghost"), usize::MAX);
        l.register("zero", 0);
        assert_eq!(l.headroom_workers("zero"), usize::MAX);
    }

    #[test]
    fn headroom_counts_whole_workers() {
        let l = ledger(100);
        l.register("a", 30);
        assert_eq!(l.headroom_workers("a"), 3);
        l.try_charge("a", 2).unwrap();
        assert_eq!(l.headroom_workers("a"), 1, "40 B free funds one worker");
        l.try_charge("a", 1).unwrap();
        assert_eq!(l.headroom_workers("a"), 0);
    }

    #[test]
    fn overcommit_scales_the_capacity() {
        let l = EpcLedger::new(EpcOptions {
            usable_bytes: 100,
            overcommit: 1.5,
        });
        assert_eq!(l.capacity_bytes(), 150);
        l.register("a", 50);
        assert!(l.try_charge("a", 3).is_ok(), "overcommit admits 150 B");
        assert!(l.try_charge("a", 1).is_err());
    }

    fn cand(
        tenant: &str,
        active: usize,
        floor: usize,
        depth: usize,
        weight: f64,
        bytes: u64,
    ) -> ReclaimCandidate {
        ReclaimCandidate {
            tenant: tenant.into(),
            active,
            floor,
            queue_depth: depth,
            weight,
            worker_bytes: bytes,
            cost_multiplier: 1.0,
        }
    }

    #[test]
    fn packer_takes_idle_workers_most_overprovisioned_first() {
        // `a` runs 3 workers on weight 1 (3 per share); `b` runs 2 on
        // weight 2 (1 per share).  Both idle.  Freeing 20 B takes both
        // from `a`.
        let cands = vec![cand("a", 3, 1, 0, 1.0, 10), cand("b", 2, 1, 0, 2.0, 10)];
        let plan = EpcPacker::plan_reclaim(&cands, 20).unwrap();
        assert_eq!(plan, vec![("a".to_string(), 2)]);
        // a third worker must come from `b`
        let plan = EpcPacker::plan_reclaim(&cands, 30).unwrap();
        assert_eq!(plan, vec![("a".to_string(), 2), ("b".to_string(), 1)]);
    }

    #[test]
    fn packer_never_touches_busy_or_floored_tenants() {
        let cands = vec![
            cand("busy", 4, 1, 7, 1.0, 10), // has a backlog: ineligible
            cand("floored", 1, 1, 0, 1.0, 10), // at its floor: ineligible
            cand("idle", 2, 1, 0, 1.0, 10),
        ];
        let plan = EpcPacker::plan_reclaim(&cands, 10).unwrap();
        assert_eq!(plan, vec![("idle".to_string(), 1)]);
        // deficit beyond the one eligible worker: deny, reclaim nothing
        assert_eq!(EpcPacker::plan_reclaim(&cands, 20), None);
        // zero deficit: trivially satisfiable without touching anyone
        assert_eq!(EpcPacker::plan_reclaim(&cands, 0), Some(Vec::new()));
    }

    #[test]
    fn packer_reclaims_oblivious_tenants_last_among_equals() {
        // Pinned: two tenants identical but for the cost multiplier.
        // At 1.0 the tie breaks lexicographic and `a-oblv` donates;
        // with OBLIVIOUS_COST_MULTIPLIER its effective share grows
        // (weight × 1.5), its active-per-share drops below `z-cheap`'s,
        // and the baseline tenant donates first.
        let mut oblv = cand("a-oblv", 3, 1, 0, 1.0, 10);
        oblv.cost_multiplier = crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER;
        let cheap = cand("z-cheap", 3, 1, 0, 1.0, 10);
        let plan = EpcPacker::plan_reclaim(&[oblv.clone(), cheap.clone()], 10).unwrap();
        assert_eq!(
            plan,
            vec![("z-cheap".to_string(), 1)],
            "baseline tenant donates before the oblivious one"
        );
        // control: at multiplier 1.0 the tie breaks lexicographic and
        // the `a-*` tenant would have donated instead
        let mut control = oblv;
        control.cost_multiplier = 1.0;
        let plan = EpcPacker::plan_reclaim(&[control, cheap], 10).unwrap();
        assert_eq!(plan, vec![("a-oblv".to_string(), 1)]);
    }

    #[test]
    fn packer_is_deterministic_under_ties() {
        let cands = vec![cand("b", 2, 1, 0, 1.0, 10), cand("a", 2, 1, 0, 1.0, 10)];
        let p1 = EpcPacker::plan_reclaim(&cands, 10).unwrap();
        let rev: Vec<ReclaimCandidate> = cands.iter().rev().cloned().collect();
        let p2 = EpcPacker::plan_reclaim(&rev, 10).unwrap();
        assert_eq!(p1, p2, "candidate order must not change the plan");
        assert_eq!(p1, vec![("a".to_string(), 1)], "ties break lexicographic");
    }

    #[test]
    fn account_charges_under_its_tenant() {
        let l = Arc::new(ledger(50));
        l.register("m", 20);
        let acc = EpcAccount::new(l.clone(), "m");
        acc.try_charge(2).unwrap();
        assert_eq!(l.workers("m"), 2);
        assert!(acc.try_charge(1).is_err());
        acc.release(1);
        assert_eq!(l.charged_bytes(), 20);
    }
}
