//! Enclave tracks: groups of enclaves sharing blinding/session key
//! material, with a genesis/join key-handoff protocol.
//!
//! The paper's serving model assumes one enclave host; production-scale
//! traffic needs many.  Replicas can only share a client's session
//! keystream (and pick up each other's sessions on drain) if they hold
//! the *same* key material — and handing that material to a replica is
//! exactly the attestation problem the front door already solves for
//! clients.  A **track** is the unit of key sharing:
//!
//! * the first enclave to claim a track name is the **genesis** member —
//!   it generates the track's blinding-domain seed and session-key root
//!   under the registry lock (one genesis per track, ever);
//! * later members **join** over an attested channel: the joiner quotes
//!   its measurement over a fresh challenge, the genesis verifies the
//!   evidence and replies with its own quote plus the track keys sealed
//!   under a key derived from the joiner's verified report.  A forged
//!   join — wrong measurement, stale report, bad MAC — is denied before
//!   any key material is sealed;
//! * different tracks hold different keys, so compromising one track
//!   never unblinds another's traffic (blast-radius isolation).
//!
//! Members carry a **monotone incarnation** per track: a crashed node
//! that rejoins gets a strictly higher incarnation, and blinding domains
//! fold the incarnation (`incarnation · BLIND_DOMAIN_STRIDE + worker`),
//! so a respawn can never replay a pad stream its previous life already
//! spent — the PR-2 single-node invariant, extended across nodes.
//!
//! The join exchange is expressed over the front door's framing
//! (`u32 LE length ‖ u8 type ‖ payload`, the PR-8 machinery in
//! [`net`](super::net)) as pure request/response byte frames, so the
//! multi-node simulator and the tests replay the production protocol
//! in-memory — CI never opens a socket.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::crypto;
use crate::enclave::attestation::{self, Report};

use super::net::{put_str, read_frame, write_frame, Cursor};

/// Join request (joiner → genesis): track, node, challenge, joiner quote.
pub const MSG_TRACK_JOIN: u8 = 0x11;
/// Join grant (genesis → joiner): genesis quote, incarnation, sealed keys.
pub const MSG_TRACK_GRANT: u8 = 0x91;
/// Join denial (genesis → joiner): reason string; no key material.
pub const MSG_TRACK_DENY: u8 = 0x93;

/// Per-worker stride of the blinding keyspace (matches
/// [`crate::launcher::BLIND_DOMAIN_STRIDE`]): each member incarnation
/// owns one stride-wide band of domains.
pub const TRACK_DOMAIN_STRIDE: u64 = 1 << 32;

/// Attestation parameters a track runs under (same defaults as the
/// front door: the handshake machinery is shared).
#[derive(Debug, Clone)]
pub struct TrackOptions {
    /// The enclave measurement every member must prove.
    pub measurement: [u8; 32],
    /// Shared platform MAC key (the quoting-enclave key stand-in).
    pub platform_key: Vec<u8>,
    /// Validity window of join-handshake reports (ms).
    pub attest_ttl_ms: u64,
}

impl Default for TrackOptions {
    fn default() -> Self {
        Self {
            measurement: crypto::sha256(b"origami-enclave-v1"),
            platform_key: b"origami-platform-key".to_vec(),
            attest_ttl_ms: 60_000,
        }
    }
}

/// The key material every member of a track shares.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrackKeys {
    /// The track name the keys were generated for.
    pub track: String,
    /// Seed of the track's blinding-domain keyspace: every member's
    /// schedulers derive pads from this seed, so a session's blinded
    /// traffic is servable by any sibling.
    pub blind_seed: [u8; 32],
    /// Root of the track's session-key derivation: attested session
    /// control keys derive from it, so a sibling can authenticate
    /// control frames for sessions it adopted on drain.
    pub session_root: [u8; 32],
}

impl TrackKeys {
    /// The blinding domain one worker of one member incarnation owns:
    /// `incarnation · TRACK_DOMAIN_STRIDE + worker_domain`.  Incarnations
    /// are monotone per track, so domains are disjoint across every
    /// member and every respawn — pads are never reused inside a track,
    /// and different tracks hold different `blind_seed`s entirely.
    pub fn blind_domain(&self, incarnation: u64, worker_domain: usize) -> u64 {
        incarnation
            .saturating_mul(TRACK_DOMAIN_STRIDE)
            .saturating_add(worker_domain as u64)
    }
}

/// What a node holds after claiming or joining a track.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TrackMembership {
    pub keys: TrackKeys,
    /// This member's monotone incarnation (0 = genesis).
    pub incarnation: u64,
    pub node: String,
    /// True when this membership created the track.
    pub genesis: bool,
}

/// Joiner-side join failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrackError {
    /// The genesis refused the join (reason echoed from the wire).
    Denied(String),
    /// The frame was malformed.
    Protocol(String),
    /// The genesis' own evidence failed verification — the joiner will
    /// not accept key material from an enclave it cannot identify.
    Attestation(String),
}

impl std::fmt::Display for TrackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrackError::Denied(m) => write!(f, "join denied: {m}"),
            TrackError::Protocol(m) => write!(f, "join protocol error: {m}"),
            TrackError::Attestation(m) => write!(f, "join attestation rejected: {m}"),
        }
    }
}

impl std::error::Error for TrackError {}

struct TrackState {
    keys: TrackKeys,
    /// Next incarnation to mint — strictly monotone, never reused, so a
    /// respawned member can never collide with its previous life's
    /// blinding band.
    next_incarnation: u64,
    /// Live members: node → incarnation (a rejoin replaces the entry
    /// with the fresh incarnation).
    members: HashMap<String, u64>,
}

/// The track registry one coordinator host runs: genesis claims under
/// its lock, join requests verified and answered against its state.
pub struct TrackRegistry {
    opts: TrackOptions,
    /// Master key material track keys derive from (the genesis
    /// enclave's hardware-RNG stand-in; deterministic under test).
    master: [u8; 32],
    tracks: Mutex<HashMap<String, TrackState>>,
}

impl TrackRegistry {
    pub fn new(master_seed: u64, opts: TrackOptions) -> Self {
        let mut material = b"origami-track-master".to_vec();
        material.extend_from_slice(&master_seed.to_le_bytes());
        Self {
            opts,
            master: crypto::sha256(&material),
            tracks: Mutex::new(HashMap::new()),
        }
    }

    pub fn options(&self) -> &TrackOptions {
        &self.opts
    }

    /// Claim `track` for `node`: the first claim generates the track's
    /// key material under the registry lock (exactly one genesis per
    /// track); later claims by the same host's registry are local joins
    /// — they mint a fresh monotone incarnation without a wire
    /// handshake, which is what a same-host respawn uses.
    pub fn claim(&self, track: &str, node: &str) -> TrackMembership {
        let mut g = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        let fresh = !g.contains_key(track);
        let st = g.entry(track.to_string()).or_insert_with(|| TrackState {
            keys: derive_track_keys(&self.master, track),
            next_incarnation: 0,
            members: HashMap::new(),
        });
        let incarnation = st.next_incarnation;
        st.next_incarnation += 1;
        st.members.insert(node.to_string(), incarnation);
        TrackMembership {
            keys: st.keys.clone(),
            incarnation,
            node: node.to_string(),
            genesis: fresh,
        }
    }

    /// Live member count of `track` (0 if the track does not exist).
    pub fn member_count(&self, track: &str) -> usize {
        let g = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        g.get(track).map(|s| s.members.len()).unwrap_or(0)
    }

    /// A member's live incarnation, if it is in the track.
    pub fn incarnation_of(&self, track: &str, node: &str) -> Option<u64> {
        let g = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        g.get(track).and_then(|s| s.members.get(node).copied())
    }

    /// Retire a member (crash, drain-out).  The incarnation is *not*
    /// returned to the pool — a future rejoin mints a fresh one.
    pub fn retire(&self, track: &str, node: &str) -> bool {
        let mut g = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        g.get_mut(track)
            .map(|s| s.members.remove(node).is_some())
            .unwrap_or(false)
    }

    /// Genesis side of the wire join: decode a [`MSG_TRACK_JOIN`]
    /// frame, verify the joiner's evidence (measurement, challenge
    /// echo, freshness, MAC), and answer with a [`MSG_TRACK_GRANT`]
    /// carrying this registry's own quote plus the track keys sealed
    /// under the joiner's verified report — or a [`MSG_TRACK_DENY`]
    /// that mints *zero* key material and *zero* membership state.
    ///
    /// The track must already exist on this registry (the genesis — or
    /// any member that completed its own join — answers); a join for an
    /// unknown track is denied, since a non-member holds nothing to
    /// hand off.
    pub fn handle_join(&self, frame: &[u8], now_ms: u64) -> Vec<u8> {
        let decoded = (|| -> std::io::Result<(String, String, u64, Report)> {
            let (ty, payload) = read_frame(&mut &frame[..])?;
            if ty != MSG_TRACK_JOIN {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("expected TRACK_JOIN, got {ty:#x}"),
                ));
            }
            let mut c = Cursor::new(&payload);
            let track = c.str()?;
            let node = c.str()?;
            let challenge = c.u64()?;
            let report = Report {
                measurement: c.arr32()?,
                challenge: c.u64()?,
                issued_at_ms: c.u64()?,
                ttl_ms: c.u64()?,
                tag: c.arr32()?,
            };
            Ok((track, node, challenge, report))
        })();
        let (track, node, challenge, report) = match decoded {
            Ok(d) => d,
            Err(e) => return deny_frame(&format!("malformed join: {e}")),
        };
        // Verify the joiner's evidence BEFORE touching any track state:
        // a forged join (wrong measurement, stale report, bad MAC) must
        // mint no incarnation and see no key material.
        if !attestation::verify(
            &self.opts.platform_key,
            &report,
            &self.opts.measurement,
            challenge,
            now_ms,
        ) {
            return deny_frame(if report.measurement != self.opts.measurement {
                "measurement mismatch (wrong enclave)"
            } else if !attestation::is_fresh(&report, now_ms) {
                "stale join evidence"
            } else {
                "bad challenge or MAC"
            });
        }
        let (keys, incarnation) = {
            let mut g = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
            let Some(st) = g.get_mut(&track) else {
                return deny_frame(&format!("track `{track}` has no genesis here"));
            };
            let incarnation = st.next_incarnation;
            st.next_incarnation += 1;
            st.members.insert(node.clone(), incarnation);
            (st.keys.clone(), incarnation)
        };
        // Our own evidence over the joiner's challenge: the joiner must
        // be able to refuse keys from an enclave it cannot identify.
        let genesis_report = attestation::quote(
            &self.opts.platform_key,
            self.opts.measurement,
            challenge,
            now_ms,
            self.opts.attest_ttl_ms,
        );
        // The handoff key derives from the joiner's *verified* report:
        // only an enclave holding the platform key (and the report it
        // actually sent) can open the sealed track keys.
        let (wrap_enc, wrap_mac) = wrap_keys(&self.opts.platform_key, &report);
        let mut plain = Vec::with_capacity(72);
        plain.extend_from_slice(&keys.blind_seed);
        plain.extend_from_slice(&keys.session_root);
        plain.extend_from_slice(&incarnation.to_le_bytes());
        let sealed = crypto::seal(&wrap_enc, &wrap_mac, challenge, &plain);
        let mut p = Vec::with_capacity(96 + 16 + sealed.len());
        encode_report(&mut p, &genesis_report);
        p.extend_from_slice(&(sealed.len() as u32).to_le_bytes());
        p.extend_from_slice(&sealed);
        let mut out = Vec::with_capacity(p.len() + 5);
        write_frame(&mut out, MSG_TRACK_GRANT, &p).expect("grant frame");
        out
    }
}

/// Joiner side, step 1: build the [`MSG_TRACK_JOIN`] frame.  `challenge`
/// must be fresh per attempt; the joiner quotes its own measurement over
/// it (a node with the wrong measurement cannot mint valid evidence).
pub fn join_request(
    opts: &TrackOptions,
    track: &str,
    node: &str,
    challenge: u64,
    now_ms: u64,
) -> Vec<u8> {
    let report = attestation::quote(
        &opts.platform_key,
        opts.measurement,
        challenge,
        now_ms,
        opts.attest_ttl_ms,
    );
    let mut p = Vec::with_capacity(8 + track.len() + node.len() + 96);
    put_str(&mut p, track);
    put_str(&mut p, node);
    p.extend_from_slice(&challenge.to_le_bytes());
    encode_report(&mut p, &report);
    let mut out = Vec::with_capacity(p.len() + 5);
    write_frame(&mut out, MSG_TRACK_JOIN, &p).expect("join frame");
    out
}

/// Joiner side, step 2: verify the grant and open the sealed track
/// keys.  The genesis' report must carry the expected measurement and
/// echo our challenge; the sealed blob must open under the key derived
/// from *our* report — so a grant replayed to a different joiner (or a
/// tampered blob) is rejected.
pub fn accept_grant(
    opts: &TrackOptions,
    track: &str,
    node: &str,
    challenge: u64,
    frame: &[u8],
    now_ms: u64,
) -> Result<TrackMembership, TrackError> {
    let (ty, payload) = read_frame(&mut &frame[..])
        .map_err(|e| TrackError::Protocol(format!("bad frame: {e}")))?;
    let mut c = Cursor::new(&payload);
    match ty {
        MSG_TRACK_DENY => {
            let reason = c
                .str()
                .map_err(|e| TrackError::Protocol(format!("bad deny: {e}")))?;
            Err(TrackError::Denied(reason))
        }
        MSG_TRACK_GRANT => {
            let genesis_report = decode_report(&mut c)
                .map_err(|e| TrackError::Protocol(format!("bad report: {e}")))?;
            if !attestation::verify(
                &opts.platform_key,
                &genesis_report,
                &opts.measurement,
                challenge,
                now_ms,
            ) {
                return Err(TrackError::Attestation(
                    "genesis evidence failed verification".into(),
                ));
            }
            let sealed = c
                .bytes_u32()
                .map_err(|e| TrackError::Protocol(format!("bad sealed blob: {e}")))?;
            // Recompute our own report deterministically (quote is a MAC
            // over fixed inputs) to derive the same wrap key the genesis
            // sealed under.
            let my_report = attestation::quote(
                &opts.platform_key,
                opts.measurement,
                challenge,
                now_ms,
                opts.attest_ttl_ms,
            );
            let (wrap_enc, wrap_mac) = wrap_keys(&opts.platform_key, &my_report);
            let plain = crypto::open(&wrap_enc, &wrap_mac, challenge, &sealed)
                .ok_or_else(|| TrackError::Attestation("sealed keys failed to open".into()))?;
            if plain.len() != 72 {
                return Err(TrackError::Protocol(format!(
                    "sealed payload is {} bytes, want 72",
                    plain.len()
                )));
            }
            let keys = TrackKeys {
                track: track.to_string(),
                blind_seed: plain[..32].try_into().unwrap(),
                session_root: plain[32..64].try_into().unwrap(),
            };
            let incarnation = u64::from_le_bytes(plain[64..72].try_into().unwrap());
            Ok(TrackMembership {
                keys,
                incarnation,
                node: node.to_string(),
                genesis: false,
            })
        }
        other => Err(TrackError::Protocol(format!(
            "expected TRACK_GRANT or TRACK_DENY, got {other:#x}"
        ))),
    }
}

/// The caveat the joiner's quote depends on: `accept_grant` re-quotes at
/// its own `now_ms`, so the joiner must pass the same timestamp to
/// `join_request` and `accept_grant` (the simulator's per-node clock
/// does exactly that).  Changing the timestamp between the two calls
/// changes the report — and the wrap key — and the open fails closed.
fn wrap_keys(platform_key: &[u8], joiner_report: &Report) -> ([u8; 16], [u8; 32]) {
    let sk = attestation::session_key(platform_key, joiner_report);
    let enc = crypto::derive_aes_key(&sk, "origami-track-wrap-enc");
    let mac = crypto::derive_key(&sk, "origami-track-wrap-mac");
    (enc, mac)
}

/// Wall-clock milliseconds since the UNIX epoch — the shared clock base
/// of the *real-socket* join path (two hosts need a common domain to
/// judge report freshness; `attest_ttl_ms` bounds the tolerated skew).
/// The simulator never calls this: it passes its own per-node clocks to
/// [`TrackRegistry::handle_join`] / [`accept_grant`] directly.
pub fn wall_now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

fn derive_track_keys(master: &[u8; 32], track: &str) -> TrackKeys {
    TrackKeys {
        track: track.to_string(),
        blind_seed: crypto::derive_key(master, &format!("origami-track-blind:{track}")),
        session_root: crypto::derive_key(master, &format!("origami-track-session:{track}")),
    }
}

fn deny_frame(reason: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(2 + reason.len());
    put_str(&mut p, reason);
    let mut out = Vec::with_capacity(p.len() + 5);
    write_frame(&mut out, MSG_TRACK_DENY, &p).expect("deny frame");
    out
}

fn encode_report(out: &mut Vec<u8>, r: &Report) {
    out.extend_from_slice(&r.measurement);
    out.extend_from_slice(&r.challenge.to_le_bytes());
    out.extend_from_slice(&r.issued_at_ms.to_le_bytes());
    out.extend_from_slice(&r.ttl_ms.to_le_bytes());
    out.extend_from_slice(&r.tag);
}

fn decode_report(c: &mut Cursor<'_>) -> std::io::Result<Report> {
    Ok(Report {
        measurement: c.arr32()?,
        challenge: c.u64()?,
        issued_at_ms: c.u64()?,
        ttl_ms: c.u64()?,
        tag: c.arr32()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> TrackRegistry {
        TrackRegistry::new(2019, TrackOptions::default())
    }

    #[test]
    fn genesis_claims_once_and_rejoins_mint_monotone_incarnations() {
        let reg = registry();
        let a = reg.claim("prod", "node-a");
        assert!(a.genesis);
        assert_eq!(a.incarnation, 0);
        let b = reg.claim("prod", "node-b");
        assert!(!b.genesis, "the track already has a genesis");
        assert_eq!(b.incarnation, 1);
        assert_eq!(a.keys, b.keys, "members of one track share keys");
        // crash-and-respawn: node-a rejoins with a strictly higher
        // incarnation — its old blinding band is never reissued
        let a2 = reg.claim("prod", "node-a");
        assert!(a2.incarnation > b.incarnation);
        assert_eq!(reg.member_count("prod"), 2);
    }

    #[test]
    fn tracks_isolate_key_material() {
        let reg = registry();
        let prod = reg.claim("prod", "n");
        let canary = reg.claim("canary", "n");
        assert_ne!(prod.keys.blind_seed, canary.keys.blind_seed);
        assert_ne!(prod.keys.session_root, canary.keys.session_root);
    }

    #[test]
    fn blind_domains_are_disjoint_across_incarnations() {
        let keys = registry().claim("prod", "n").keys;
        // incarnation 0's worker band and incarnation 1's never overlap
        let hi0 = keys.blind_domain(0, (TRACK_DOMAIN_STRIDE - 1) as usize);
        let lo1 = keys.blind_domain(1, 0);
        assert!(hi0 < lo1, "bands must be disjoint: {hi0} vs {lo1}");
    }

    #[test]
    fn wire_join_round_trip_hands_off_keys() {
        let reg = registry();
        let genesis = reg.claim("prod", "node-a");
        let opts = TrackOptions::default();
        let req = join_request(&opts, "prod", "node-b", 77, 1_000);
        let reply = reg.handle_join(&req, 1_000);
        let joined = accept_grant(&opts, "prod", "node-b", 77, &reply, 1_000).unwrap();
        assert_eq!(joined.keys, genesis.keys, "joiner holds the track keys");
        assert_eq!(joined.incarnation, 1);
        assert!(!joined.genesis);
        assert_eq!(reg.member_count("prod"), 2);
    }

    #[test]
    fn forged_join_mints_zero_key_material() {
        let reg = registry();
        reg.claim("prod", "node-a");
        // wrong measurement: the forger's enclave is not the track's
        let forged = TrackOptions {
            measurement: crypto::sha256(b"evil-enclave"),
            ..TrackOptions::default()
        };
        let req = join_request(&forged, "prod", "mallory", 9, 500);
        let reply = reg.handle_join(&req, 500);
        let err = accept_grant(&forged, "prod", "mallory", 9, &reply, 500).unwrap_err();
        assert!(matches!(err, TrackError::Denied(_)), "got {err:?}");
        assert_eq!(
            reg.member_count("prod"),
            1,
            "a denied join must mint no membership state"
        );
        assert_eq!(reg.incarnation_of("prod", "mallory"), None);

        // stale evidence: a captured join replayed past the report TTL
        let honest = TrackOptions::default();
        let old = join_request(&honest, "prod", "node-b", 11, 0);
        let reply = reg.handle_join(&old, honest.attest_ttl_ms + 1);
        assert!(matches!(
            accept_grant(&honest, "prod", "node-b", 11, &reply, 0),
            Err(TrackError::Denied(_))
        ));
        assert_eq!(reg.member_count("prod"), 1);
    }

    #[test]
    fn join_for_an_unknown_track_is_denied() {
        let reg = registry();
        let opts = TrackOptions::default();
        let req = join_request(&opts, "ghost", "node-b", 3, 100);
        let reply = reg.handle_join(&req, 100);
        assert!(matches!(
            accept_grant(&opts, "ghost", "node-b", 3, &reply, 100),
            Err(TrackError::Denied(_))
        ));
        assert_eq!(reg.member_count("ghost"), 0);
    }

    #[test]
    fn grant_for_another_joiner_fails_to_open() {
        let reg = registry();
        reg.claim("prod", "node-a");
        let opts = TrackOptions::default();
        let req = join_request(&opts, "prod", "node-b", 42, 1_000);
        let reply = reg.handle_join(&req, 1_000);
        // an eavesdropper (different challenge → different wrap key)
        // cannot open the sealed keys
        assert!(matches!(
            accept_grant(&opts, "prod", "eve", 43, &reply, 1_000),
            Err(TrackError::Attestation(_))
        ));
    }

    #[test]
    fn retire_keeps_incarnations_monotone() {
        let reg = registry();
        reg.claim("prod", "node-a");
        let b1 = reg.claim("prod", "node-b");
        assert!(reg.retire("prod", "node-b"));
        assert_eq!(reg.member_count("prod"), 1);
        let b2 = reg.claim("prod", "node-b");
        assert!(b2.incarnation > b1.incarnation, "retired incarnations never recycle");
    }
}
