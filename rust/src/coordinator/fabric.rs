//! Shared multi-tenant tier-2 lane fabric.
//!
//! ```text
//!  model A pool ─ tier-1 (enclaves, pads) ─┐        ┌─ lane 0 (cpu)  ─┐
//!  model B pool ─ tier-1 (enclaves, pads) ─┼→ fair  ├─ lane 1 (gpu)  ─┼→ replies
//!  model C pool ─ tier-1 (enclaves, pads) ─┘  queue └─ lane N (cpu)  ─┘
//!                     (Tier2Task, tenant-tagged, weighted-fair pop)
//! ```
//!
//! Origami's tier split means the tier-2 tail is *plain accelerator
//! work*: no enclave, no session keys, no blinding state.  That is why
//! tails from different models can share one fleet of device lanes — the
//! capacity-sharing opportunity the paper's two-tier design creates and
//! per-pool lanes waste.  The fabric makes that substrate first-class:
//!
//! 1. **Multi-tenant fair queue.**  Every [`Tier2Task`] is tagged with
//!    its model; the queue pops by least weighted virtual service
//!    (batches served ÷ tenant weight), so a hot model cannot starve a
//!    cold one's tails.  A tenant returning from idle is floored to the
//!    queue-wide virtual clock so it cannot replay its idle credit as a
//!    burst.
//! 2. **Device-aware lanes.**  Each lane is pinned to an *explicit*
//!    [`Device`] from the fabric's device cycle — not the config device
//!    the model inherited — so a deployment can mix CPU and modeled-GPU
//!    lanes and each lane's cost ledger reflects its own hardware
//!    profile.  Numerics never change (the modeled GPU computes on the
//!    CPU), so pooled outputs stay bit-identical to the serial path.
//! 3. **Lane autoscaling.**  [`LaneFabric::scale_to`] grows or retires
//!    lanes between configurable min/max bounds; the deployment
//!    autoscaler drives it from queue depth.  Retired lanes finish
//!    their in-flight task, then exit; queued tasks are never dropped.
//!
//! Per-tenant finishers are constructed lazily *inside* each lane
//! thread (the PJRT path holds thread-local handles), then cached for
//! the lane's lifetime.
//!
//! Two latency-SLO mechanisms live here as of PR 3:
//!
//! 4. **Tail-batch splitting** ([`SplitPolicy`]).  A queued tail over
//!    the configured cost/size ceiling is split into chunked sub-tasks
//!    ([`Tier2Task::split`]) *before* it enters the fair queue, so the
//!    weighted-fair clock interleaves at chunk granularity: a cold
//!    tenant's single tail pops after at most one chunk of a hot burst,
//!    never behind a whole batch-8 tail.  The fair clock charges pops by
//!    request count, so splitting changes *preemption granularity*, not
//!    a tenant's aggregate share — and outputs stay bit-identical to
//!    the unsplit path (tail stages are per-sample maps).
//! 5. **Latency telemetry** ([`super::telemetry`]).  Lanes record each
//!    task's fabric queue wait, tier-2 cost and per-request end-to-end
//!    latency into the deployment's [`TelemetryHub`]; the SLO autoscaler
//!    reads windowed p95s from it.
//! 6. **Deadline-aware fair popping** (PR 4).  Within a tenant's
//!    weighted-fair entitlement, the queue pops the task with the least
//!    SLO slack (earliest rider submit instant + tenant SLO − now)
//!    instead of FIFO — tier-1 shards complete out of order, so arrival
//!    order is not urgency order.  Cross-tenant shares are untouched
//!    (the fair clock never sees *which* of a tenant's tasks popped),
//!    property-tested in `harness/prop.rs`; tenants without an SLO stay
//!    FIFO.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::api::reply_error;
use super::scheduler::{Tier2Finisher, Tier2Task};
use super::telemetry::{Stage, TelemetryHub};
use crate::runtime::Device;
use crate::util::arena::{ArenaStats, TensorArena};

/// Weighted-fair virtual-clock bookkeeping, extracted so the live
/// fabric queue, the fairness property tests (`harness/prop.rs`) and
/// the deterministic serving simulator (`harness/sim.rs`) all run the
/// *same* policy code.
///
/// Tenants accumulate virtual time `cost / weight` per dequeue; the
/// next tenant is always the backlogged one with the least virtual
/// time (ties break lexicographically, so orders are deterministic).
/// A tenant returning from idle is floored to the queue-wide virtual
/// clock, so idle periods can never be banked as burst credit.
#[derive(Debug, Default)]
pub struct FairClock {
    tenants: BTreeMap<String, ClockTenant>,
    /// Highest virtual time any dequeue has reached.
    vclock: f64,
}

#[derive(Debug)]
struct ClockTenant {
    weight: f64,
    vtime: f64,
    queued: usize,
}

impl FairClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a tenant (idempotent; updates the weight).
    pub fn register(&mut self, tenant: &str, weight: f64) {
        let t = self
            .tenants
            .entry(tenant.to_string())
            .or_insert(ClockTenant {
                weight: 1.0,
                vtime: 0.0,
                queued: 0,
            });
        t.weight = weight.max(1e-6);
    }

    /// Note one item entering `tenant`'s queue.  A tenant whose queue
    /// was empty is floored to the queue-wide virtual clock.
    pub fn on_enqueue(&mut self, tenant: &str) {
        let vclock = self.vclock;
        let t = self
            .tenants
            .entry(tenant.to_string())
            .or_insert(ClockTenant {
                weight: 1.0,
                vtime: 0.0,
                queued: 0,
            });
        if t.queued == 0 {
            t.vtime = t.vtime.max(vclock);
        }
        t.queued += 1;
    }

    /// The backlogged tenant with the least virtual time, if any.
    pub fn pick(&self) -> Option<String> {
        let mut best: Option<(&String, f64)> = None;
        for (name, t) in &self.tenants {
            if t.queued == 0 {
                continue;
            }
            if best.map(|(_, v)| t.vtime < v).unwrap_or(true) {
                best = Some((name, t.vtime));
            }
        }
        best.map(|(name, _)| name.clone())
    }

    /// Charge `tenant` for one dequeued item of `cost` service units.
    pub fn on_dequeue(&mut self, tenant: &str, cost: f64) {
        if let Some(t) = self.tenants.get_mut(tenant) {
            t.vtime += cost.max(0.0) / t.weight;
            t.queued = t.queued.saturating_sub(1);
            self.vclock = self.vclock.max(t.vtime);
        }
    }

    /// Items currently queued for `tenant`.
    pub fn queued(&self, tenant: &str) -> usize {
        self.tenants.get(tenant).map(|t| t.queued).unwrap_or(0)
    }

    /// A tenant's accumulated virtual time.
    pub fn vtime(&self, tenant: &str) -> f64 {
        self.tenants.get(tenant).map(|t| t.vtime).unwrap_or(0.0)
    }
}

/// Tail-batch splitting policy (bounds the worst-case head-of-line
/// blocking one queued tail can inflict on other tenants).
#[derive(Debug, Clone, PartialEq)]
pub struct SplitPolicy {
    /// Target ceiling for one tier-2 task's simulated cost (ms).  Once a
    /// tenant has a learned per-request cost estimate, its tasks are
    /// chunked so each sub-task stays under this.  0 disables cost-based
    /// chunk sizing.
    pub max_task_ms: f64,
    /// Hard per-task request ceiling, applied even before any cost
    /// estimate exists (cold start).  0 disables.
    pub max_chunk: usize,
}

impl SplitPolicy {
    /// No splitting at all (the PR-2 behavior).
    pub fn disabled() -> Self {
        Self {
            max_task_ms: 0.0,
            max_chunk: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_task_ms > 0.0 || self.max_chunk > 0
    }
}

impl Default for SplitPolicy {
    fn default() -> Self {
        Self::disabled()
    }
}

/// Fabric geometry and policy.
#[derive(Debug, Clone)]
pub struct FabricOptions {
    /// Initial lane count.
    pub lanes: usize,
    /// Autoscale floor (0 → `lanes`).
    pub min_lanes: usize,
    /// Autoscale ceiling (0 → `lanes`).
    pub max_lanes: usize,
    /// Device cycle: lane *i* is pinned to `lane_devices[i % len]`.
    /// Empty → every lane on the untrusted CPU.
    pub lane_devices: Vec<Device>,
    /// Per-tenant queue bound (backpressure toward that tenant's tier-1
    /// workers; other tenants are unaffected).
    pub queue_cap: usize,
    /// Tail-batch splitting (see [`SplitPolicy`]).
    pub split: SplitPolicy,
}

impl Default for FabricOptions {
    fn default() -> Self {
        Self {
            lanes: 2,
            min_lanes: 0,
            max_lanes: 0,
            lane_devices: vec![Device::UntrustedCpu],
            queue_cap: 64,
            split: SplitPolicy::disabled(),
        }
    }
}

/// Per-tenant serving statistics.
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Tier-2 batches finished for this tenant.
    pub batches: u64,
    /// Requests replied to across those batches.
    pub requests: u64,
    /// Failed batches / orphaned requests.
    pub errors: u64,
    /// Simulated ms spent in this tenant's tier-2 tails alone.
    pub tier2_sim_ms: f64,
    /// Simulated ms across both tiers (tier-1 ledgers ride along in the
    /// task and are merged at finish time).
    pub sim_ms_total: f64,
}

/// Aggregated fabric metrics: per-lane ledgers + per-tenant stats.
#[derive(Debug, Clone, Default)]
pub struct FabricMetrics {
    /// Simulated tier-2 busy ms of each lane (the lane cost ledger).
    pub lane_sim_ms: Vec<f64>,
    /// Batches each lane finished.
    pub lane_batches: Vec<u64>,
    /// The device each lane is pinned to.
    pub lane_device: Vec<Device>,
    /// Per-tenant serving stats, keyed by model.
    pub tenants: BTreeMap<String, TenantStats>,
    /// Autoscale events.
    pub grow_events: u64,
    pub shrink_events: u64,
    /// Highest concurrent lane count reached.
    pub peak_lanes: usize,
    /// Failed batches across all tenants.
    pub errors: u64,
    /// Tail batches that were split on submit.
    pub split_tasks: u64,
    /// Sub-tasks those splits produced (≥ 2 × `split_tasks`).
    pub split_subtasks: u64,
}

impl FabricMetrics {
    /// Busiest lane on the simulated timeline — the fabric's makespan
    /// (each lane is an independent device stream).
    pub fn makespan_ms(&self) -> f64 {
        self.lane_sim_ms.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Total simulated tier-2 ms served across all tenants.
    pub fn tier2_total_ms(&self) -> f64 {
        self.tenants.values().map(|t| t.tier2_sim_ms).sum()
    }

    /// Tier-2 substrate throughput: work served per unit of busiest-lane
    /// time.  Comparing this at equal lane budgets is the fabric-sharing
    /// experiment (`benches/fig15_fabric_sharing.rs`).
    pub fn lane_throughput(&self) -> f64 {
        let makespan = self.makespan_ms();
        if makespan <= 0.0 {
            return 0.0;
        }
        self.tier2_total_ms() / makespan
    }
}

/// One queued tier-2 task plus its scheduling stamps.
struct QueuedTask {
    /// When the task entered the fair queue (queue-wait telemetry).
    enqueued: Instant,
    /// SLO deadline: the earliest rider request's submit instant plus
    /// the tenant's SLO.  None when the tenant declares no SLO — those
    /// tenants pop FIFO.
    deadline: Option<Instant>,
    task: Tier2Task,
}

struct FairQueueInner {
    /// Weighted-fair policy state (queue-wide virtual clock + per-tenant
    /// vtimes): tenants returning from idle are floored to the clock
    /// even when every deque happens to be empty at that instant (depth
    /// oscillates through zero constantly while lanes are in flight),
    /// so idle time can never be banked as a burst credit.
    clock: FairClock,
    /// Per-tenant deques of queued tasks.
    tenants: BTreeMap<String, VecDeque<QueuedTask>>,
    /// Per-tenant latency objectives (ms): tasks of an SLO tenant pop
    /// least-slack-first *within* that tenant's weighted-fair
    /// entitlement, so deadline ordering never changes cross-tenant
    /// shares (property-tested in `harness/prop.rs`).
    slos: HashMap<String, f64>,
    len: usize,
    closed: bool,
}

/// What a timed pop produced: a task plus the wall ms it spent queued.
enum Pop {
    Task(Tier2Task, f64),
    TimedOut,
    Closed,
}

/// Bounded multi-tenant queue with a weighted-fair pop.  Pops are
/// charged by *request count*, so an 8-request tail consumes eight
/// times the virtual service of a single-request tail — which is what
/// makes tail-batch splitting fairness-neutral: the chunks of a split
/// task cost exactly what the unsplit task would have.
///
/// Within one tenant's entitlement, pops are deadline-aware: the task
/// with the least SLO slack (earliest rider submit instant + tenant SLO
/// − now) goes first.  Tier-1 shards complete batches out of order, so
/// fabric-arrival order is not urgency order — least-slack popping
/// serves the oldest-started work first without touching the fair
/// clock's cross-tenant arithmetic (no-SLO tenants stay FIFO).
struct FairQueue {
    inner: Mutex<FairQueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl FairQueue {
    fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(FairQueueInner {
                clock: FairClock::new(),
                tenants: BTreeMap::new(),
                slos: HashMap::new(),
                len: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Declare a tenant (idempotent; updates the weight and SLO).
    fn register(&self, model: &str, weight: f64, slo_ms: Option<f64>) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.clock.register(model, weight);
        g.tenants.entry(model.to_string()).or_default();
        match slo_ms {
            Some(slo) if slo > 0.0 => {
                g.slos.insert(model.to_string(), slo);
            }
            _ => {
                g.slos.remove(model);
            }
        }
    }

    /// Blocking push with per-tenant backpressure; Err(task) when closed.
    fn push(&self, task: Tier2Task) -> std::result::Result<(), Tier2Task> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if g.closed {
                return Err(task);
            }
            // an unregistered tenant counts as depth 0 (it is created on
            // first push below), so the per-tenant cap applies to every
            // producer — attached or not
            let depth = g
                .tenants
                .get(&task.model)
                .map(|t| t.len())
                .unwrap_or(0);
            if depth < self.cap {
                break;
            }
            g = self.not_full.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.clock.on_enqueue(&task.model);
        let deadline = g.slos.get(&task.model).map(|&slo| {
            // slack anchors at the oldest rider's *submit* instant: that
            // is the wall clock the tenant's SLO is written against
            task.requests
                .iter()
                .map(|r| r.submitted_at)
                .min()
                .unwrap_or(task.started)
                + Duration::from_secs_f64(slo / 1e3)
        });
        let deque = g.tenants.entry(task.model.clone()).or_default();
        deque.push_back(QueuedTask {
            enqueued: Instant::now(),
            deadline,
            task,
        });
        g.len += 1;
        self.not_empty.notify_one();
        Ok(())
    }

    /// Weighted-fair pop: the non-empty tenant with the least weighted
    /// virtual service goes first (ties break lexicographically, so the
    /// order is deterministic); within that tenant, the task with the
    /// least SLO slack (FIFO for no-SLO tenants).
    fn pop_timeout(&self, timeout: Duration) -> Pop {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(name) = g.clock.pick() {
                let deque = g
                    .tenants
                    .get_mut(&name)
                    .expect("fair clock and deques agree on backlog");
                // least SLO slack first; entries without deadlines (the
                // tenant has no SLO) keep their FIFO position.  Ties
                // break on queue position, so the order is stable.
                let idx = deque
                    .iter()
                    .enumerate()
                    .filter_map(|(i, e)| e.deadline.map(|d| (d, i)))
                    .min()
                    .map(|(_, i)| i)
                    .unwrap_or(0);
                let entry = deque
                    .remove(idx)
                    .expect("fair clock and deques agree on backlog");
                let cost = entry.task.requests.len().max(1) as f64;
                g.clock.on_dequeue(&name, cost);
                g.len -= 1;
                self.not_full.notify_all();
                let wait_ms = entry.enqueued.elapsed().as_secs_f64() * 1e3;
                return Pop::Task(entry.task, wait_ms);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            g = guard;
        }
    }

    fn depth(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len
    }

    fn close(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Per-tenant registration: how a lane builds this model's finisher.
struct TenantEntry {
    factory: Arc<dyn Fn(usize) -> Result<Tier2Finisher> + Send + Sync>,
}

/// State shared between the fabric handle, its lanes and the owner.
struct FabricShared {
    queue: FairQueue,
    tenants: Mutex<HashMap<String, TenantEntry>>,
    desired: AtomicUsize,
    /// Lanes currently processing a task (occupancy probe: "starved"
    /// means an idle lane exists *and* nothing is queued — an empty
    /// queue alone just means the lanes are keeping up).
    busy_lanes: AtomicUsize,
    metrics: Mutex<FabricMetrics>,
    devices: Vec<Device>,
    /// Tail-batch splitting policy (applied on submit).
    split: SplitPolicy,
    /// Learned per-request tier-2 cost (simulated ms, EWMA) per tenant —
    /// converts the split policy's ms ceiling into a chunk size.
    cost_est: Mutex<HashMap<String, f64>>,
    /// Latency telemetry sink (None outside a deployment).
    telemetry: Option<Arc<TelemetryHub>>,
    /// Feature-map buffer pool: submit-side tail splitting draws chunk
    /// buffers from it, lanes return spent feature maps after each tail
    /// — steady-state chunking allocates nothing.  Off the lane compute
    /// path, so one mutex-guarded pool serves the whole fabric.
    arena: Mutex<TensorArena>,
}

impl FabricShared {
    /// Chunk size the split policy implies for this task (0 = don't
    /// split).  Final and failed tasks never split — there is no tail
    /// stage to chunk.
    fn chunk_for(&self, task: &Tier2Task) -> usize {
        let p = &self.split;
        if !p.enabled() || task.stage.is_none() || task.error.is_some() {
            return 0;
        }
        let n = task.requests.len();
        if n <= 1 {
            return 0;
        }
        let mut chunk = if p.max_chunk > 0 { p.max_chunk } else { usize::MAX };
        if p.max_task_ms > 0.0 {
            if let Some(&per_req) = self.cost_est.lock().unwrap_or_else(|e| e.into_inner()).get(&task.model) {
                if per_req > 0.0 {
                    let by_cost = (p.max_task_ms / per_req).floor() as usize;
                    chunk = chunk.min(by_cost.max(1));
                }
            }
        }
        if chunk >= n {
            0
        } else {
            chunk
        }
    }
}

/// Cloneable submission handle an attached pool holds.
#[derive(Clone)]
pub struct FabricHandle {
    shared: Arc<FabricShared>,
}

impl FabricHandle {
    /// Enqueue a tier-1-complete task; Err(task) when the fabric is
    /// shut down (the caller replies an error to each request).
    ///
    /// When the fabric's [`SplitPolicy`] flags the task as an oversized
    /// tail, it is split into chunked sub-tasks first; each chunk
    /// enqueues as its own fair-queue entry.  If the fabric closes
    /// between chunks, the not-yet-queued chunks get error replies here
    /// (already-queued chunks still drain normally), so every request
    /// receives exactly one reply either way.
    pub fn submit(&self, task: Tier2Task) -> std::result::Result<(), Tier2Task> {
        let chunk = self.shared.chunk_for(&task);
        if chunk == 0 {
            return self.shared.queue.push(task);
        }
        let parts = {
            let mut arena = self.shared.arena.lock().unwrap_or_else(|e| e.into_inner());
            task.split_into(chunk, &mut arena)
        };
        let total = parts.len();
        let mut parts = parts.into_iter();
        while let Some(part) = parts.next() {
            if let Err(failed) = self.shared.queue.push(part) {
                for rejected in std::iter::once(failed).chain(parts) {
                    for req in &rejected.requests {
                        reply_error(req, "tier-2 lane fabric is shut down");
                    }
                }
                return Ok(());
            }
        }
        // count the split only once every chunk is actually queued —
        // shutdown-time rejections must not inflate the accounting
        if total > 1 {
            let mut m = self.shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
            m.split_tasks += 1;
            m.split_subtasks += total as u64;
        }
        Ok(())
    }

    /// Queued tier-2 batches across all tenants.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// True when at least one lane sits idle with nothing queued — the
    /// signal the occupancy-aware batcher flushes on.  (Queue depth
    /// alone is the wrong signal: it passes through zero constantly
    /// while every lane is busy.)
    pub fn starved(&self) -> bool {
        self.shared.queue.depth() == 0
            && self.shared.busy_lanes.load(Ordering::SeqCst)
                < self.shared.desired.load(Ordering::SeqCst)
    }
}

/// The shared, device-aware tier-2 lane fleet (see module docs).
pub struct LaneFabric {
    shared: Arc<FabricShared>,
    slots: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// Serializes concurrent scale_to calls: an unserialized shrink can
    /// block joining a lane whose `desired` check a concurrent grow just
    /// un-tripped, and a concurrent grow could double-spawn a slot.
    scale_lock: Mutex<()>,
    min_lanes: usize,
    max_lanes: usize,
}

impl LaneFabric {
    /// Start the fabric with its initial lane fleet.
    pub fn start(opts: FabricOptions) -> Self {
        Self::start_with_telemetry(opts, None)
    }

    /// Start the fabric with a telemetry sink: lanes record per-task
    /// queue wait, tier-2 cost and per-request end-to-end latency into
    /// the hub (the deployment shares one hub across fabric + pools).
    pub fn start_with_telemetry(
        opts: FabricOptions,
        telemetry: Option<Arc<TelemetryHub>>,
    ) -> Self {
        let lanes = opts.lanes.max(1);
        let min_lanes = if opts.min_lanes == 0 {
            lanes
        } else {
            opts.min_lanes.min(lanes).max(1)
        };
        let max_lanes = if opts.max_lanes == 0 {
            lanes
        } else {
            opts.max_lanes.max(lanes)
        };
        let devices = if opts.lane_devices.is_empty() {
            vec![Device::UntrustedCpu]
        } else {
            opts.lane_devices.clone()
        };
        let shared = Arc::new(FabricShared {
            queue: FairQueue::new(opts.queue_cap),
            tenants: Mutex::new(HashMap::new()),
            desired: AtomicUsize::new(lanes),
            busy_lanes: AtomicUsize::new(0),
            metrics: Mutex::new(FabricMetrics {
                peak_lanes: lanes,
                ..FabricMetrics::default()
            }),
            devices,
            split: opts.split.clone(),
            cost_est: Mutex::new(HashMap::new()),
            telemetry,
            arena: Mutex::new(TensorArena::new()),
        });
        let fabric = Self {
            shared,
            slots: Mutex::new(Vec::new()),
            scale_lock: Mutex::new(()),
            min_lanes,
            max_lanes,
        };
        fabric.ensure_lanes(lanes);
        fabric
    }

    /// Register a tenant: `factory(lane)` builds the model's finisher
    /// inside a lane thread; the lane re-pins it to its own device.
    /// Returns the submission handle its pool attaches with.
    pub fn attach<F>(&self, model: &str, weight: f64, factory: F) -> Result<FabricHandle>
    where
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        self.attach_with_slo(model, weight, None, factory)
    }

    /// [`LaneFabric::attach`] with a latency objective: the fair queue
    /// pops this tenant's tasks least-SLO-slack-first within its
    /// weighted entitlement (cross-tenant shares are unchanged; see
    /// `harness/prop.rs`).  `None` (or a non-positive SLO) keeps FIFO.
    pub fn attach_with_slo<F>(
        &self,
        model: &str,
        weight: f64,
        slo_ms: Option<f64>,
        factory: F,
    ) -> Result<FabricHandle>
    where
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        {
            let mut g = self.shared.tenants.lock().unwrap_or_else(|e| e.into_inner());
            anyhow::ensure!(
                !g.contains_key(model),
                "model `{model}` is already attached to the fabric"
            );
            g.insert(
                model.to_string(),
                TenantEntry {
                    factory: Arc::new(factory),
                },
            );
        }
        self.shared.queue.register(model, weight, slo_ms);
        Ok(self.handle())
    }

    /// A cloneable submission handle.
    pub fn handle(&self) -> FabricHandle {
        FabricHandle {
            shared: self.shared.clone(),
        }
    }

    /// Cumulative feature-map arena counters: how many chunk buffers the
    /// split path took, how many were pool hits vs fresh allocations.
    pub fn arena_stats(&self) -> ArenaStats {
        self.shared.arena.lock().unwrap_or_else(|e| e.into_inner()).stats()
    }

    /// Current (desired) lane count.
    pub fn lane_count(&self) -> usize {
        self.shared.desired.load(Ordering::SeqCst)
    }

    /// Queued tier-2 batches.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Grow/retire lanes toward `n` (clamped to the configured bounds);
    /// returns the resulting lane count.  Retired lanes finish their
    /// in-flight task and are joined before this returns; queued tasks
    /// stay queued for the surviving lanes.
    pub fn scale_to(&self, n: usize) -> usize {
        let _guard = self.scale_lock.lock().unwrap_or_else(|e| e.into_inner());
        let n = n.clamp(self.min_lanes, self.max_lanes).max(1);
        let cur = self.shared.desired.load(Ordering::SeqCst);
        if n == cur {
            return cur;
        }
        self.shared.desired.store(n, Ordering::SeqCst);
        {
            let mut m = self.shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
            if n > cur {
                m.grow_events += 1;
                m.peak_lanes = m.peak_lanes.max(n);
            } else {
                m.shrink_events += 1;
            }
        }
        if n > cur {
            self.ensure_lanes(n);
        } else {
            let handles: Vec<JoinHandle<()>> = {
                let mut g = self.slots.lock().unwrap_or_else(|e| e.into_inner());
                (n..g.len()).filter_map(|i| g[i].take()).collect()
            };
            for h in handles {
                let _ = h.join();
            }
        }
        n
    }

    /// Make sure lanes `0..n` are running (spawning any that are missing
    /// or previously retired).
    fn ensure_lanes(&self, n: usize) {
        let mut g = self.slots.lock().unwrap_or_else(|e| e.into_inner());
        while g.len() < n {
            g.push(None);
        }
        for i in 0..n {
            let respawn = match &g[i] {
                None => true,
                Some(h) => h.is_finished(),
            };
            if !respawn {
                continue;
            }
            if let Some(h) = g[i].take() {
                let _ = h.join();
            }
            let device = self.shared.devices[i % self.shared.devices.len()];
            {
                let mut m = self.shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
                if m.lane_sim_ms.len() <= i {
                    m.lane_sim_ms.resize(i + 1, 0.0);
                    m.lane_batches.resize(i + 1, 0);
                    m.lane_device.resize(i + 1, Device::UntrustedCpu);
                }
                m.lane_device[i] = device;
            }
            let shared = self.shared.clone();
            g[i] = Some(
                std::thread::Builder::new()
                    .name(format!("origami-fabric-lane{i}"))
                    .spawn(move || lane_main(shared, i, device))
                    .expect("spawn fabric lane"),
            );
        }
    }

    fn stop(&self) {
        self.shared.queue.close();
        let handles: Vec<JoinHandle<()>> = {
            let mut g = self.slots.lock().unwrap_or_else(|e| e.into_inner());
            g.iter_mut().filter_map(|s| s.take()).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    /// Drain the queue, stop every lane, return the final metrics.
    pub fn shutdown(self) -> FabricMetrics {
        self.stop();
        let m = self.shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.clone()
    }
}

impl Drop for LaneFabric {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Give a lane this many attempts at building a tenant's finisher
/// before writing the tenant off for the lane's lifetime — a transient
/// factory failure (runtime init hiccup) heals on a later task instead
/// of turning the lane into a permanent error source for that model.
const FINISHER_BUILD_ATTEMPTS: u32 = 3;

/// One lane: pop fairly, lazily build (and cache) the tenant's finisher
/// pinned to this lane's device, finish, account.
fn lane_main(shared: Arc<FabricShared>, lane: usize, device: Device) {
    let mut finishers: HashMap<String, Option<Tier2Finisher>> = HashMap::new();
    let mut build_attempts: HashMap<String, u32> = HashMap::new();
    // per-lane telemetry cache: after a tenant's first task the hub's
    // registry mutex is never touched again on this lane's hot path
    let mut telemetry: HashMap<String, Arc<super::telemetry::TenantTelemetry>> = HashMap::new();
    loop {
        if lane >= shared.desired.load(Ordering::SeqCst) {
            break; // retired by a scale-down
        }
        let (task, queue_wait_ms) = match shared.queue.pop_timeout(Duration::from_millis(20)) {
            Pop::Task(t, wait) => (t, wait),
            Pop::TimedOut => continue,
            Pop::Closed => break,
        };
        shared.busy_lanes.fetch_add(1, Ordering::SeqCst);
        let model = task.model.clone();
        let tenant_tel = shared.telemetry.as_ref().map(|hub| {
            telemetry
                .entry(model.clone())
                .or_insert_with(|| hub.register(&model))
                .clone()
        });
        if let Some(tel) = &tenant_tel {
            tel.record(Stage::QueueWait, queue_wait_ms);
        }
        if !finishers.contains_key(&model) {
            let factory = shared
                .tenants
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .get(&model)
                .map(|e| e.factory.clone());
            // an unknown tenant is not cached: it may attach later
            if let Some(f) = factory {
                match f(lane) {
                    Ok(fin) => {
                        finishers.insert(model.clone(), Some(fin.with_device(device)));
                    }
                    Err(e) => {
                        let a = build_attempts.entry(model.clone()).or_insert(0);
                        *a += 1;
                        eprintln!(
                            "[fabric] lane {lane}: finisher for `{model}` failed \
                             (attempt {a}/{FINISHER_BUILD_ATTEMPTS}): {e:#}"
                        );
                        if *a >= FINISHER_BUILD_ATTEMPTS {
                            finishers.insert(model.clone(), None);
                        }
                    }
                }
            }
        }
        match finishers.get(&model).and_then(|f| f.as_ref()) {
            Some(fin) => {
                let out = fin.finish(task);
                // recycle the spent feature map into the fabric pool
                if let Some(spent) = out.spent_features {
                    shared.arena.lock().unwrap_or_else(|e| e.into_inner()).give(spent);
                }
                if let Some(tel) = &tenant_tel {
                    tel.record(Stage::Tier2, out.tier2_sim_ms);
                    for &lat in &out.latencies_ms {
                        tel.record(Stage::EndToEnd, lat);
                    }
                }
                // refresh the learned per-request tail cost (feeds the
                // split policy's ms → chunk-size conversion)
                if out.tier2_sim_ms > 0.0 && out.record.batch > 0 {
                    let per_req = out.tier2_sim_ms / out.record.batch as f64;
                    let mut est = shared.cost_est.lock().unwrap_or_else(|e| e.into_inner());
                    let e = est.entry(model.clone()).or_insert(per_req);
                    *e = 0.8 * *e + 0.2 * per_req;
                }
                let mut g = shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
                g.lane_sim_ms[lane] += out.tier2_sim_ms;
                g.lane_batches[lane] += 1;
                let t = g.tenants.entry(model).or_default();
                t.batches += 1;
                t.requests += out.record.batch as u64;
                t.tier2_sim_ms += out.tier2_sim_ms;
                t.sim_ms_total += out.record.sim_ms;
                if !out.ok {
                    t.errors += 1;
                    g.errors += 1;
                }
            }
            None => {
                for req in &task.requests {
                    reply_error(req, "no tier-2 finisher available for this model");
                }
                let mut g = shared.metrics.lock().unwrap_or_else(|e| e.into_inner());
                g.errors += 1;
                let t = g.tenants.entry(model).or_default();
                t.errors += task.requests.len() as u64;
            }
        }
        shared.busy_lanes.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::api::InferRequest;
    use crate::enclave::cost::{CostModel, Ledger};
    use crate::runtime::{ReferenceBackend, StageExecutor};
    use std::time::Instant;

    fn task(
        model: &str,
    ) -> (
        Tier2Task,
        crate::util::threadpool::Channel<crate::coordinator::api::InferResponse>,
    ) {
        task_sized(model, 1)
    }

    /// A task carrying `n` requests (fair pops charge by request count).
    fn task_sized(
        model: &str,
        n: usize,
    ) -> (
        Tier2Task,
        crate::util::threadpool::Channel<crate::coordinator::api::InferResponse>,
    ) {
        let mut requests = Vec::new();
        let mut reply = None;
        for i in 0..n.max(1) {
            let (req, r) = InferRequest::new(i as u64 + 1, model, vec![], 0);
            requests.push(req);
            if reply.is_none() {
                reply = Some(r);
            }
        }
        (
            Tier2Task {
                model: model.to_string(),
                requests,
                exec_batch: n.max(1),
                stage: None,
                features: vec![0.5; 2 * n.max(1)],
                ledger: Ledger::new(),
                queue_ms: 0.0,
                started: Instant::now(),
                home_worker: 0,
                error: None,
                artifact_batches: vec![],
            },
            reply.unwrap(),
        )
    }

    fn pop_model(q: &FairQueue) -> String {
        match q.pop_timeout(Duration::from_millis(100)) {
            Pop::Task(t, _wait) => t.model,
            _ => panic!("expected a task"),
        }
    }

    #[test]
    fn fair_queue_interleaves_equal_weights() {
        let q = FairQueue::new(16);
        q.register("a", 1.0, None);
        q.register("b", 1.0, None);
        let mut keep = Vec::new();
        for m in ["a", "a", "a", "a", "b", "b"] {
            let (t, r) = task(m);
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        let order: Vec<String> = (0..6).map(|_| pop_model(&q)).collect();
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "a"]);
    }

    #[test]
    fn fair_queue_respects_weights() {
        let q = FairQueue::new(16);
        q.register("a", 2.0, None);
        q.register("b", 1.0, None);
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (t, r) = task("a");
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
            let (t, r) = task("b");
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        let order: Vec<String> = (0..6).map(|_| pop_model(&q)).collect();
        // weight 2 tenant gets ~2 pops per weight-1 pop
        assert_eq!(order, vec!["a", "b", "a", "a", "b", "a"]);
    }

    #[test]
    fn returning_tenant_is_floored_not_bursty() {
        let q = FairQueue::new(16);
        q.register("a", 1.0, None);
        q.register("b", 1.0, None);
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (t, r) = task("b");
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        // b serves alone for a while…
        assert_eq!(pop_model(&q), "b");
        assert_eq!(pop_model(&q), "b");
        // …then a returns from idle: it must be floored to b's virtual
        // time and alternate, not drain its backlog first
        for _ in 0..2 {
            let (t, r) = task("a");
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        let order: Vec<String> = (0..4).map(|_| pop_model(&q)).collect();
        assert_eq!(order, vec!["a", "b", "a", "b"]);
    }

    #[test]
    fn idle_credit_does_not_survive_an_empty_queue_instant() {
        // The queue routinely drains to zero while lanes are in flight;
        // a tenant returning at such an instant must still be floored
        // (to the queue-wide virtual clock), or it would bank its idle
        // time and lock out the hot tenant for a long burst.
        let q = FairQueue::new(16);
        q.register("hot", 1.0, None);
        q.register("idle", 1.0, None);
        let mut keep = Vec::new();
        for _ in 0..4 {
            let (t, r) = task("hot");
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        for _ in 0..4 {
            assert_eq!(pop_model(&q), "hot"); // hot vtime climbs to 4
        }
        // queue is now EMPTY; the idle tenant wakes up…
        for m in ["idle", "hot", "idle", "hot"] {
            let (t, r) = task(m);
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        // …and must alternate with the hot tenant, not drain first
        let order: Vec<String> = (0..4).map(|_| pop_model(&q)).collect();
        assert_eq!(order, vec!["hot", "idle", "hot", "idle"]);
    }

    /// Age a task's riders so its SLO deadline sits `ms` in the past
    /// relative to a fresh task (tier-1 shards finish out of order, so
    /// an older request can reach the fabric *after* a younger one).
    fn age_task(task: &mut Tier2Task, ms: u64) {
        for req in &mut task.requests {
            req.submitted_at = req
                .submitted_at
                .checked_sub(Duration::from_millis(ms))
                .expect("clock has been up longer than the test offset");
        }
    }

    #[test]
    fn slo_tenant_pops_least_slack_first_no_slo_stays_fifo() {
        let q = FairQueue::new(16);
        q.register("slo", 1.0, Some(50.0));
        q.register("fifo", 1.0, None);
        let mut keep = Vec::new();
        // "slo": a fresh task enqueues BEFORE an older (more urgent) one
        let (mut young, r) = task_sized("slo", 1);
        keep.push(r);
        let (mut old, r) = task_sized("slo", 1);
        keep.push(r);
        young.requests[0].id = 101;
        old.requests[0].id = 102;
        age_task(&mut old, 40); // 40 ms less slack than `young`
        let young_id = young.requests[0].id;
        let old_id = old.requests[0].id;
        q.push(young).map_err(|_| ()).unwrap();
        q.push(old).map_err(|_| ()).unwrap();
        // "fifo": same arrival shape, but no SLO — enqueue order rules
        let (mut f_young, r) = task_sized("fifo", 1);
        keep.push(r);
        let (mut f_old, r) = task_sized("fifo", 1);
        keep.push(r);
        f_young.requests[0].id = 201;
        f_old.requests[0].id = 202;
        age_task(&mut f_old, 40);
        let f_young_id = f_young.requests[0].id;
        q.push(f_young).map_err(|_| ()).unwrap();
        q.push(f_old).map_err(|_| ()).unwrap();

        let mut popped = Vec::new();
        for _ in 0..4 {
            match q.pop_timeout(Duration::from_millis(100)) {
                Pop::Task(t, _) => popped.push((t.model.clone(), t.requests[0].id)),
                _ => panic!("expected a task"),
            }
        }
        // cross-tenant order is the fair clock's (lexicographic tie
        // break), untouched by deadlines
        assert_eq!(popped[0].0, "fifo");
        assert_eq!(popped[1].0, "slo");
        // within "slo", the aged task overtakes the fresh one…
        let slo_ids: Vec<u64> = popped
            .iter()
            .filter(|(m, _)| m == "slo")
            .map(|&(_, id)| id)
            .collect();
        assert_eq!(slo_ids, vec![old_id, young_id], "least slack first");
        // …while "fifo" keeps enqueue order despite the same age skew
        let fifo_first = popped
            .iter()
            .find(|(m, _)| m == "fifo")
            .map(|&(_, id)| id)
            .unwrap();
        assert_eq!(fifo_first, f_young_id, "no-SLO tenants stay FIFO");
    }

    #[test]
    fn deadline_popping_leaves_cross_tenant_interleave_unchanged() {
        // Same workload through an SLO-bearing and a FIFO registration:
        // the *tenant* pop sequence must be identical — deadlines only
        // reorder within a tenant's own deque.
        let run = |slo: Option<f64>| -> Vec<String> {
            let q = FairQueue::new(16);
            q.register("a", 1.0, slo);
            q.register("b", 1.0, slo);
            let mut keep = Vec::new();
            for m in ["a", "a", "b", "a", "b", "a"] {
                let (t, r) = task_sized(m, 1);
                q.push(t).map_err(|_| ()).unwrap();
                keep.push(r);
            }
            (0..6).map(|_| pop_model(&q)).collect()
        };
        assert_eq!(run(None), run(Some(10.0)));
    }

    #[test]
    fn closed_queue_rejects_push_and_drains_pops() {
        let q = FairQueue::new(4);
        q.register("a", 1.0, None);
        let (t, _r) = task("a");
        q.push(t).map_err(|_| ()).unwrap();
        q.close();
        let (t2, _r2) = task("a");
        assert!(q.push(t2).is_err(), "push after close fails");
        assert!(matches!(
            q.pop_timeout(Duration::from_millis(10)),
            Pop::Task(_, _)
        ));
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Closed));
    }

    #[test]
    fn fair_clock_charges_cost_over_weight_and_floors_idlers() {
        let mut c = FairClock::new();
        c.register("a", 2.0);
        c.register("b", 1.0);
        assert_eq!(c.pick(), None, "no backlog, nothing to pick");
        c.on_enqueue("a");
        c.on_enqueue("b");
        assert_eq!(c.pick().as_deref(), Some("a"), "ties break lexicographically");
        c.on_dequeue("a", 4.0); // vtime a = 2.0
        assert_eq!(c.pick().as_deref(), Some("b"));
        c.on_dequeue("b", 1.0); // vtime b = 1.0, vclock = 2.0
        assert_eq!(c.queued("a"), 0);
        assert_eq!(c.queued("b"), 0);
        // an idle newcomer is floored to the queue-wide clock
        c.on_enqueue("late");
        assert!((c.vtime("late") - 2.0).abs() < 1e-12, "floored to vclock");
        assert_eq!(c.pick().as_deref(), Some("late"));
    }

    #[test]
    fn fair_pops_charge_by_request_count() {
        // One 4-request batch from `a` costs as much virtual service as
        // four 1-request batches from `b`: after a's big pop, all of
        // b's singles go first.
        let q = FairQueue::new(16);
        q.register("a", 1.0, None);
        q.register("b", 1.0, None);
        let mut keep = Vec::new();
        let (t, r) = task_sized("a", 4);
        q.push(t).map_err(|_| ()).unwrap();
        keep.push(r);
        let (t, r) = task_sized("a", 1);
        q.push(t).map_err(|_| ()).unwrap();
        keep.push(r);
        for _ in 0..4 {
            let (t, r) = task_sized("b", 1);
            q.push(t).map_err(|_| ()).unwrap();
            keep.push(r);
        }
        let order: Vec<String> = (0..6).map(|_| pop_model(&q)).collect();
        assert_eq!(order, vec!["a", "b", "b", "b", "b", "a"]);
    }

    #[test]
    fn split_policy_chunks_by_size_then_by_learned_cost() {
        let fabric = LaneFabric::start(FabricOptions {
            lanes: 1,
            split: SplitPolicy {
                max_task_ms: 4.5,
                max_chunk: 2,
            },
            ..FabricOptions::default()
        });
        let tiered = |n: usize| {
            let (mut t, _r) = task_sized("m", n);
            t.stage = Some("tail_p06".into());
            t
        };
        // cold start: no cost estimate yet → the hard request ceiling
        assert_eq!(fabric.shared.chunk_for(&tiered(4)), 2);
        assert_eq!(fabric.shared.chunk_for(&tiered(2)), 0, "already small enough");
        // a learned 3 ms/request estimate tightens the chunk: 4.5 ms
        // ceiling / 3 ms per request → 1-request chunks
        fabric.shared.cost_est.lock().unwrap_or_else(|e| e.into_inner()).insert("m".into(), 3.0);
        assert_eq!(fabric.shared.chunk_for(&tiered(4)), 1);
        // Final and failed tasks never split
        let (final_task, _r) = task_sized("m", 4);
        assert_eq!(fabric.shared.chunk_for(&final_task), 0);
        let mut failed = tiered(4);
        failed.error = Some("boom".into());
        assert_eq!(fabric.shared.chunk_for(&failed), 0);
        // disabled policy never splits
        let plain = LaneFabric::start(FabricOptions {
            lanes: 1,
            ..FabricOptions::default()
        });
        assert_eq!(plain.shared.chunk_for(&tiered(8)), 0);
    }

    #[test]
    fn fabric_finishes_final_tasks_and_scales() {
        let fabric = LaneFabric::start(FabricOptions {
            lanes: 1,
            min_lanes: 1,
            max_lanes: 3,
            lane_devices: vec![Device::UntrustedCpu, Device::Gpu],
            ..FabricOptions::default()
        });
        let handle = fabric
            .attach("sim8", 1.0, |_lane| {
                let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
                Ok(Tier2Finisher::new(
                    Arc::new(StageExecutor::reference(rb, CostModel::default())),
                    "sim8",
                    Device::UntrustedCpu,
                ))
            })
            .unwrap();
        assert_eq!(fabric.lane_count(), 1);
        assert_eq!(fabric.scale_to(10), 3, "clamped to max_lanes");
        assert_eq!(fabric.scale_to(0), 1, "clamped to min_lanes");
        assert_eq!(fabric.scale_to(2), 2);

        // duplicate tenants are rejected
        assert!(fabric.attach("sim8", 1.0, |_| anyhow::bail!("unused")).is_err());

        let mut replies = Vec::new();
        for _ in 0..6 {
            let (t, r) = task("sim8");
            handle.submit(t).map_err(|_| ()).unwrap();
            replies.push(r);
        }
        for r in replies {
            let resp = r.recv().expect("reply");
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.probs, vec![0.5, 0.5], "Final task passes through");
        }
        let m = fabric.shutdown();
        let t = m.tenants.get("sim8").expect("tenant stats");
        assert_eq!(t.batches, 6);
        assert_eq!(t.requests, 6);
        assert_eq!(t.errors, 0);
        assert_eq!(m.grow_events, 2, "1→3 and 1→2");
        assert_eq!(m.shrink_events, 1, "3→1");
        assert_eq!(m.peak_lanes, 3);
        assert_eq!(m.lane_device[0], Device::UntrustedCpu);
        assert_eq!(m.lane_device[1], Device::Gpu, "device cycle respected");
    }

    #[test]
    fn unattached_tenant_gets_error_replies_not_hangs() {
        let fabric = LaneFabric::start(FabricOptions {
            lanes: 1,
            ..FabricOptions::default()
        });
        let handle = fabric.handle();
        let (t, r) = task("ghost-model");
        handle.submit(t).map_err(|_| ()).unwrap();
        let resp = r.recv().expect("error reply arrives");
        assert!(resp.error.is_some());
        let m = fabric.shutdown();
        assert_eq!(m.errors, 1);
    }
}
