//! Sharded multi-worker serving pool with pipelined Origami tiers.
//!
//! ```text
//!                       ┌─ worker 0: [batcher]→ tier-1 (enclave w0) ─┐
//! clients → ingress → dispatcher (session-affinity shard)           ├→ tier-2 sink
//!                       └─ worker N: [batcher]→ tier-1 (enclave wN) ─┘      │
//!                 owned lanes (open device) ◀── or ──▶ shared LaneFabric ◀──┘
//! ```
//!
//! Three properties the single-engine serving loop lacks:
//!
//! 1. **Session-affinity sharding.**  The dispatcher routes a request to
//!    worker `session % active`, so a session's tier-1 — the part that
//!    touches blinding state — executes on one enclave at any given pool
//!    size.  Each worker's pad stream lives in a disjoint keyspace
//!    (`Config::blind_domain` = worker index), so pooling never reuses a
//!    one-time pad across workers.
//! 2. **Tier pipelining.**  Inside a worker, tier-1 of batch *k+1*
//!    (enclave: decrypt, blind, unblind, non-linear) overlaps tier-2 of
//!    batch *k* (open device: the fused tail) — the overlap Origami's
//!    two-tier split creates and a serial `Strategy::infer` loop wastes.
//! 3. **A pluggable tier-2 sink.**  Tier-2 tasks carry no enclave state,
//!    so they drain either through the pool's own work-stealing lanes
//!    ([`WorkerPool::start`]) or — the multi-tenant shape — through a
//!    shared, device-aware [`LaneFabric`](super::fabric::LaneFabric)
//!    other models' pools attach to as well
//!    ([`WorkerPool::start_attached`]).
//!
//! Pools resize at runtime: [`WorkerPool::scale_to`] grows or retires
//! tier-1 shards between the configured min/max bounds (the deployment
//! autoscaler drives it from queue depth).  Re-homing a session on a
//! resize is *safe*: any enclave can re-derive any session's keys from
//! the deployment master, and blinding pads stay disjoint because every
//! worker *incarnation* draws a fresh pad domain from a monotone
//! counter — a shard retired and later respawned at the same slot index
//! never reuses its predecessor's pad stream (its epoch counter restarts
//! at zero, so sharing the domain would re-emit consumed one-time pads).
//! Affinity is a locality property, not a correctness one.
//!
//! Outputs are bit-identical to the serial single-worker path: tier
//! splitting reorders *when* work happens, never *what* is computed.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::admission::InflightPermit;
use super::api::{reply_error, BatchRecord, InferRequest, InferResponse};
use super::batcher::{DynamicBatcher, SLO_WINDOW_FRACTION};
use super::epc_sched::EpcAccount;
use super::fabric::FabricHandle;
use super::scheduler::{BatchScheduler, Tier2Finisher, Tier2Task};
use super::telemetry::{Stage, TenantTelemetry};
use crate::blinding::FactorPoolStats;
use crate::util::stats::Summary;
use crate::util::threadpool::Channel;

/// Pool geometry and policy.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Initial worker shards (one strategy instance + enclave each).
    pub workers: usize,
    /// Autoscale floor (0 → `workers`).
    pub min_workers: usize,
    /// Autoscale ceiling (0 → `workers`).
    pub max_workers: usize,
    /// Dynamic batcher: max batch per shard.
    pub max_batch: usize,
    /// Dynamic batcher: max queueing delay (ms).
    pub max_delay_ms: f64,
    /// Overlap tier-1/tier-2 (double-buffered tiers + tier-2 lanes).
    pub pipeline: bool,
    /// Occupancy-aware batching: flush partial batches early while the
    /// tier-2 side is starved (no point coalescing into an idle lane).
    pub occupancy_flush: bool,
    /// Shared ingress bound (client backpressure).
    pub ingress_cap: usize,
    /// Per-worker queue bound (shard backpressure).
    pub worker_queue_cap: usize,
    /// End-to-end latency objective (ms); > 0 caps the batcher's delay
    /// window at [`SLO_WINDOW_FRACTION`] of it, so batch coalescing can
    /// never eat the whole latency budget.  0 = no SLO.
    pub slo_ms: f64,
    /// Per-worker resident enclave footprint (bytes) charged against the
    /// deployment's EPC ledger on spawn and credited on retire.  0 = the
    /// model is not EPC-accounted (the default; the launcher fills this
    /// from the Table-I memory analytics when `--epc-overcommit` is on).
    pub worker_epc_bytes: u64,
}

impl Default for PoolOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            min_workers: 0,
            max_workers: 0,
            max_batch: 8,
            max_delay_ms: 2.0,
            pipeline: true,
            occupancy_flush: false,
            ingress_cap: 256,
            worker_queue_cap: 64,
            slo_ms: 0.0,
            worker_epc_bytes: 0,
        }
    }
}

/// Aggregated pool metrics, including per-lane simulated busy time.
#[derive(Clone)]
pub struct PoolMetrics {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exec_wall_ms: Summary,
    pub batch_size: Summary,
    /// Sum of every batch's simulated cost — what one serial worker
    /// would spend on the same traffic.
    pub sim_ms_total: f64,
    /// Simulated busy time of each worker's tier-1 (enclave) lane.
    pub tier1_sim_ms: Vec<f64>,
    /// Simulated busy time of each *owned* tier-2 lane (attached pools
    /// leave this empty — the fabric keeps per-lane ledgers instead).
    pub tier2_sim_ms: Vec<f64>,
    /// Sessions whose tier-1 ran on each worker (affinity audit: at a
    /// fixed pool size the sets must be pairwise disjoint; a resize may
    /// legitimately re-home a session's residue class).
    pub sessions_per_worker: Vec<BTreeSet<u64>>,
    /// Tier-2 batches finished by a lane other than the home worker's.
    pub stolen_batches: u64,
    /// Autoscale events.
    pub grow_events: u64,
    pub shrink_events: u64,
    /// Grow requests whose EPC charge was refused *inside* `scale_to` —
    /// direct pool drivers, or a deployment grow whose funding check
    /// lost a race to a concurrent charge.  Deployment-tick denials are
    /// decided before `scale_to` runs and land in the tenant's
    /// [`ScaleCounters`](super::telemetry::ScaleCounters) instead.
    pub epc_denied_grows: u64,
    /// Highest concurrent tier-1 worker count reached.
    pub peak_workers: usize,
}

impl PoolMetrics {
    fn new(workers: usize) -> Self {
        Self {
            requests: 0,
            batches: 0,
            errors: 0,
            latency_ms: Summary::new(),
            queue_ms: Summary::new(),
            exec_wall_ms: Summary::new(),
            batch_size: Summary::new(),
            sim_ms_total: 0.0,
            tier1_sim_ms: vec![0.0; workers],
            tier2_sim_ms: vec![0.0; workers],
            sessions_per_worker: vec![BTreeSet::new(); workers],
            stolen_batches: 0,
            grow_events: 0,
            shrink_events: 0,
            epc_denied_grows: 0,
            peak_workers: workers,
        }
    }

    fn record_batch(&mut self, rec: &BatchRecord) {
        self.batches += 1;
        self.requests += rec.batch as u64;
        self.queue_ms.record(rec.queue_ms);
        self.exec_wall_ms.record(rec.exec_wall_ms);
        self.batch_size.record(rec.batch as f64);
        self.sim_ms_total += rec.sim_ms;
    }

    /// Pool makespan on the simulated timeline: each tier-1 lane is an
    /// independent enclave and each tier-2 lane an independent device
    /// stream, so the makespan is the busiest lane.
    pub fn simulated_makespan_ms(&self) -> f64 {
        self.tier1_sim_ms
            .iter()
            .chain(self.tier2_sim_ms.iter())
            .fold(0.0f64, |a, &b| a.max(b))
    }

    /// Throughput speedup of the pool over one serial worker, on the
    /// simulated-cost timeline (deterministic; independent of host core
    /// count).
    pub fn simulated_speedup(&self) -> f64 {
        let makespan = self.simulated_makespan_ms();
        if makespan <= 0.0 {
            return 1.0;
        }
        self.sim_ms_total / makespan
    }

    /// True when no session's tier-1 ran on two different workers.
    pub fn affinity_held(&self) -> bool {
        let mut seen = BTreeSet::new();
        for set in &self.sessions_per_worker {
            for s in set {
                if !seen.insert(*s) {
                    return false;
                }
            }
        }
        true
    }
}

/// Grow-on-demand indexing for per-worker metric vectors (worker slots
/// beyond the initial count appear when the pool scales up).
fn at<T: Default + Clone>(v: &mut Vec<T>, i: usize) -> &mut T {
    if v.len() <= i {
        v.resize(i + 1, T::default());
    }
    &mut v[i]
}

type SchedFactory = Arc<dyn Fn(usize) -> Result<BatchScheduler> + Send + Sync>;
type FinisherFactory = Arc<dyn Fn(usize) -> Result<Tier2Finisher> + Send + Sync>;

/// Where a worker's tier-1 output goes.
#[derive(Clone)]
enum Tier2Sink {
    /// Pool-owned work-stealing lanes drain a private queue.
    Owned {
        queue: Channel<Tier2Task>,
        /// Lanes currently finishing a task (occupancy probe).
        busy: Arc<AtomicUsize>,
        lanes: usize,
    },
    /// Tails are handed to a shared multi-tenant lane fabric.
    Fabric(FabricHandle),
}

impl Tier2Sink {
    fn send(&self, task: Tier2Task) -> std::result::Result<(), Tier2Task> {
        match self {
            Tier2Sink::Owned { queue, .. } => queue.send(task),
            Tier2Sink::Fabric(h) => h.submit(task),
        }
    }

    /// True when a tier-2 lane sits idle with nothing queued — the
    /// batcher's flush signal.  An empty queue alone is *not* starvation
    /// (depth oscillates through zero while every lane is busy).
    fn starved(&self) -> bool {
        match self {
            Tier2Sink::Owned { queue, busy, lanes } => {
                queue.is_empty() && busy.load(Ordering::SeqCst) < *lanes
            }
            Tier2Sink::Fabric(h) => h.starved(),
        }
    }
}

/// One tier-1 shard: its request queue and (while running) its thread.
struct WorkerSlot {
    queue: Channel<InferRequest>,
    handle: Option<JoinHandle<()>>,
}

/// The multi-worker serving pool.
pub struct WorkerPool {
    ingress: Channel<InferRequest>,
    slots: Arc<Mutex<Vec<WorkerSlot>>>,
    active: Arc<AtomicUsize>,
    dispatcher: Option<JoinHandle<()>>,
    lane_threads: Vec<JoinHandle<()>>,
    sink: Tier2Sink,
    sched_factory: SchedFactory,
    opts: PoolOptions,
    /// Serializes concurrent scale_to calls (autoscaler vs. operator).
    scale_lock: Mutex<()>,
    /// Monotone blinding-domain allocator: every worker incarnation —
    /// initial, grown, or respawned after a retire — gets a domain index
    /// no previous incarnation of this pool ever used (OTP safety; see
    /// module docs).
    next_domain: Arc<AtomicUsize>,
    /// Tenant latency sink (tier-1 stage recording; deployment-attached
    /// pools only).
    telemetry: Option<Arc<TenantTelemetry>>,
    /// EPC ledger account: grows charge through it, retires credit it
    /// (deployment-attached pools under EPC-aware scheduling only).  The
    /// initial fleet's charge is taken by the deployment *before* the
    /// pool starts; `stop` credits whatever is still active.
    epc: Option<EpcAccount>,
    pub metrics: Arc<Mutex<PoolMetrics>>,
    next_id: AtomicU64,
    configured_workers: usize,
}

impl WorkerPool {
    /// Start a self-contained pool that owns its tier-2 lanes.
    ///
    /// `sched_factory(domain)` builds a worker's [`BatchScheduler`]
    /// inside its tier-1 thread (strategies hold thread-local runtime
    /// handles).  `domain` is a pool-unique blinding-domain index —
    /// equal to the worker index for the initial fleet, and strictly
    /// increasing for every later spawn — and the factory must configure
    /// the strategy with `blind_domain = domain` so pad streams stay
    /// disjoint across workers *and* across incarnations of the same
    /// slot — the launcher's factories do.
    /// `finisher_factory(w)` builds lane *w*'s [`Tier2Finisher`] inside
    /// its tier-2 thread (only used when `opts.pipeline`).  Owned lanes
    /// are provisioned up to `max_workers` so a later [`scale_to`] grow
    /// has matching tier-2 capacity — an idle lane just blocks on the
    /// queue; with no autoscale bounds configured this is exactly one
    /// lane per worker, as before.
    ///
    /// [`scale_to`]: WorkerPool::scale_to
    pub fn start<S, F>(opts: PoolOptions, sched_factory: S, finisher_factory: F) -> Self
    where
        S: Fn(usize) -> Result<BatchScheduler> + Send + Sync + 'static,
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        let workers = opts.workers.max(1);
        let max_workers = workers.max(opts.max_workers);
        // Double-buffer depth: one in-flight tier-2 task per (potential)
        // worker keeps every enclave lane busy without unbounded
        // feature-map buildup.
        let t2q: Channel<Tier2Task> = Channel::bounded(max_workers.max(2));
        Self::start_inner(
            opts,
            Arc::new(sched_factory),
            Tier2Sink::Owned {
                queue: t2q.clone(),
                busy: Arc::new(AtomicUsize::new(0)),
                lanes: max_workers,
            },
            Some((t2q, Arc::new(finisher_factory) as FinisherFactory)),
            None,
            None,
        )
    }

    /// Start a pool whose tier-2 tails drain through a shared
    /// [`LaneFabric`](super::fabric::LaneFabric) instead of owned lanes.
    /// The pool's model must already be attached to the fabric (the
    /// handle comes from [`LaneFabric::attach`](super::fabric::LaneFabric::attach)).
    /// `telemetry` is the tenant's latency sink: tier-1 workers record
    /// per-batch enclave time into it (the fabric's lanes record the
    /// queue-wait/tier-2/end-to-end stages).
    pub fn start_attached<S>(
        opts: PoolOptions,
        sched_factory: S,
        fabric: FabricHandle,
        telemetry: Option<Arc<TenantTelemetry>>,
    ) -> Self
    where
        S: Fn(usize) -> Result<BatchScheduler> + Send + Sync + 'static,
    {
        Self::start_attached_with_epc(opts, sched_factory, fabric, telemetry, None)
    }

    /// [`WorkerPool::start_attached`], charging worker residency against
    /// a shared EPC ledger: `epc` is the pool's ledger account, under
    /// which the *initial* fleet must already be charged (the deployment
    /// charges before starting the pool, so a deploy that cannot fit
    /// fails before any enclave spawns).  From then on [`scale_to`] is
    /// ledger-transactional — grows charge first and are refused when
    /// the charge is denied; retires credit after the drain — and `stop`
    /// credits whatever is still active.
    ///
    /// [`scale_to`]: WorkerPool::scale_to
    pub fn start_attached_with_epc<S>(
        opts: PoolOptions,
        sched_factory: S,
        fabric: FabricHandle,
        telemetry: Option<Arc<TenantTelemetry>>,
        epc: Option<EpcAccount>,
    ) -> Self
    where
        S: Fn(usize) -> Result<BatchScheduler> + Send + Sync + 'static,
    {
        Self::start_inner(
            opts,
            Arc::new(sched_factory),
            Tier2Sink::Fabric(fabric),
            None,
            telemetry,
            epc,
        )
    }

    fn start_inner(
        opts: PoolOptions,
        sched_factory: SchedFactory,
        sink: Tier2Sink,
        owned: Option<(Channel<Tier2Task>, FinisherFactory)>,
        telemetry: Option<Arc<TenantTelemetry>>,
        epc: Option<EpcAccount>,
    ) -> Self {
        let mut opts = opts;
        let workers = opts.workers.max(1);
        opts.workers = workers;
        opts.min_workers = if opts.min_workers == 0 {
            workers
        } else {
            opts.min_workers.min(workers).max(1)
        };
        opts.max_workers = if opts.max_workers == 0 {
            workers
        } else {
            opts.max_workers.max(workers)
        };

        let ingress: Channel<InferRequest> = Channel::bounded(opts.ingress_cap.max(1));
        let metrics = Arc::new(Mutex::new(PoolMetrics::new(workers)));
        let slots: Arc<Mutex<Vec<WorkerSlot>>> = Arc::new(Mutex::new(Vec::new()));
        let active = Arc::new(AtomicUsize::new(0));
        let dispatcher = Some(spawn_dispatcher(
            ingress.clone(),
            slots.clone(),
            active.clone(),
        ));

        // Startup barrier: the caller's first request must not pay for
        // factory setup (artifact compilation, factor precompute).
        let ready: Channel<()> = Channel::bounded(workers + opts.max_workers);
        let mut expected_ready = workers;
        let next_domain = Arc::new(AtomicUsize::new(0));
        {
            let mut g = slots.lock().unwrap();
            for w in 0..workers {
                let queue: Channel<InferRequest> = Channel::bounded(opts.worker_queue_cap.max(1));
                let domain = next_domain.fetch_add(1, Ordering::SeqCst);
                let handle = spawn_worker(
                    w,
                    domain,
                    queue.clone(),
                    sink.clone(),
                    metrics.clone(),
                    sched_factory.clone(),
                    opts.clone(),
                    telemetry.clone(),
                    Some(ready.clone()),
                );
                g.push(WorkerSlot {
                    queue,
                    handle: Some(handle),
                });
            }
        }
        active.store(workers, Ordering::SeqCst);

        // Owned tier-2 lanes: keyless finishers draining the private
        // queue (work stealing: any lane takes any worker's tail).
        // Provisioned up to the autoscale ceiling so scaled-up tier-1
        // shards are not serialized behind a smaller lane fleet.
        let lane_count = opts.max_workers;
        let mut lane_threads = Vec::new();
        if opts.pipeline {
            if let Some((t2q, fin_factory)) = owned {
                let lane_busy = match &sink {
                    Tier2Sink::Owned { busy, .. } => busy.clone(),
                    Tier2Sink::Fabric(_) => Arc::new(AtomicUsize::new(0)),
                };
                expected_ready += lane_count;
                for w in 0..lane_count {
                    let t2q = t2q.clone();
                    let m = metrics.clone();
                    let factory = fin_factory.clone();
                    let r = ready.clone();
                    let busy = lane_busy.clone();
                    lane_threads.push(
                        std::thread::Builder::new()
                            .name(format!("origami-pool-w{w}-t2"))
                            .spawn(move || {
                                let fin = match factory(w) {
                                    Ok(f) => {
                                        let _ = r.send(());
                                        Some(f)
                                    }
                                    Err(e) => {
                                        eprintln!("[pool] tier-2 lane {w} failed: {e:#}");
                                        m.lock().unwrap().errors += 1;
                                        let _ = r.send(());
                                        None
                                    }
                                };
                                while let Some(task) = t2q.recv() {
                                    busy.fetch_add(1, Ordering::SeqCst);
                                    match fin.as_ref() {
                                        None => {
                                            for req in &task.requests {
                                                reply_error(
                                                    req,
                                                    "tier-2 lane failed to start",
                                                );
                                            }
                                        }
                                        Some(fin) => {
                                            let home = task.home_worker;
                                            let out = fin.finish(task);
                                            let mut g = m.lock().unwrap();
                                            *at(&mut g.tier2_sim_ms, w) += out.tier2_sim_ms;
                                            if home != w {
                                                g.stolen_batches += 1;
                                            }
                                            if !out.ok {
                                                g.errors += 1;
                                            }
                                            g.record_batch(&out.record);
                                        }
                                    }
                                    busy.fetch_sub(1, Ordering::SeqCst);
                                }
                            })
                            .expect("spawn tier-2 lane"),
                    );
                }
            }
        }

        for _ in 0..expected_ready {
            let _ = ready.recv();
        }

        Self {
            ingress,
            slots,
            active,
            dispatcher,
            lane_threads,
            sink,
            sched_factory,
            opts,
            scale_lock: Mutex::new(()),
            next_domain,
            telemetry,
            epc,
            metrics,
            next_id: AtomicU64::new(1),
            configured_workers: workers,
        }
    }

    /// The worker count the pool was configured with.
    pub fn worker_count(&self) -> usize {
        self.configured_workers
    }

    /// The pool's autoscale floor (reclaim never shrinks below it).
    pub fn min_workers(&self) -> usize {
        self.opts.min_workers
    }

    /// The pool's autoscale ceiling (`scale_to` clamps to it).
    pub fn max_workers(&self) -> usize {
        self.opts.max_workers
    }

    /// The per-worker enclave footprint the pool charges to the EPC
    /// ledger (0 = not EPC-accounted).
    pub fn worker_epc_bytes(&self) -> u64 {
        self.opts.worker_epc_bytes
    }

    /// Tier-1 workers currently running.
    pub fn active_workers(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Grow/retire tier-1 shards toward `n` (clamped to the configured
    /// min/max bounds); returns the resulting worker count.  Retired
    /// shards drain their queued requests first — nothing is dropped —
    /// and their residue classes re-home to the surviving shards (safe:
    /// see the module docs).
    ///
    /// Under EPC-aware scheduling the transition is ledger-transactional:
    /// a grow charges `worker_epc_bytes` per new shard *before* any
    /// enclave spawns (a denied charge leaves the pool unchanged and
    /// counts in [`PoolMetrics::epc_denied_grows`]), and a shrink
    /// credits the ledger only after the retired shards have drained —
    /// the ledger always bounds *live* enclave residency, never a
    /// hoped-for future state.
    pub fn scale_to(&self, n: usize) -> usize {
        let _guard = self.scale_lock.lock().unwrap();
        let n = n
            .clamp(self.opts.min_workers, self.opts.max_workers)
            .max(1);
        let cur = self.active.load(Ordering::SeqCst);
        if n == cur {
            return cur;
        }
        if n > cur {
            if let Some(acc) = &self.epc {
                if acc.try_charge(n - cur).is_err() {
                    self.metrics.lock().unwrap().epc_denied_grows += 1;
                    return cur;
                }
            }
            {
                let mut g = self.slots.lock().unwrap();
                for w in cur..n {
                    let queue: Channel<InferRequest> =
                        Channel::bounded(self.opts.worker_queue_cap.max(1));
                    // fresh pad domain per incarnation: a respawned slot
                    // must never replay its predecessor's pad stream
                    let domain = self.next_domain.fetch_add(1, Ordering::SeqCst);
                    let handle = spawn_worker(
                        w,
                        domain,
                        queue.clone(),
                        self.sink.clone(),
                        self.metrics.clone(),
                        self.sched_factory.clone(),
                        self.opts.clone(),
                        self.telemetry.clone(),
                        None,
                    );
                    let slot = WorkerSlot {
                        queue,
                        handle: Some(handle),
                    };
                    if w < g.len() {
                        g[w] = slot;
                    } else {
                        g.push(slot);
                    }
                }
            }
            self.active.store(n, Ordering::SeqCst);
            let mut m = self.metrics.lock().unwrap();
            m.grow_events += 1;
            m.peak_workers = m.peak_workers.max(n);
        } else {
            // stop routing first, then drain + join the retired shards
            self.active.store(n, Ordering::SeqCst);
            let handles: Vec<JoinHandle<()>> = {
                let mut g = self.slots.lock().unwrap();
                let upper = cur.min(g.len());
                (n..upper)
                    .filter_map(|w| {
                        g[w].queue.close();
                        g[w].handle.take()
                    })
                    .collect()
            };
            for h in handles {
                let _ = h.join();
            }
            if let Some(acc) = &self.epc {
                acc.release(cur - n);
            }
            self.metrics.lock().unwrap().shrink_events += 1;
        }
        n
    }

    /// Submit an encrypted request; returns the reply channel.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        self.submit_with_permit(model, ciphertext, session, None)
    }

    /// Submit a request carrying its deployment admission permit.  The
    /// permit rides inside the request for its whole life — through the
    /// batcher, tier-1 and the tier-2 sink — and is released when the
    /// request drops (reply sent, error path, or the failed send below),
    /// so the deployment's in-flight quota can never leak a slot.
    pub fn submit_with_permit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
        permit: Option<InflightPermit>,
    ) -> Result<Channel<InferResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (mut req, reply) = InferRequest::new(id, model, ciphertext, session);
        req.permit = permit;
        self.ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker pool is shut down"))?;
        Ok(reply)
    }

    /// Submit and block for the response (records client latency).
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let reply = self.submit(model, ciphertext, session)?;
        let resp = reply
            .recv()
            .ok_or_else(|| anyhow::anyhow!("reply channel closed"))?;
        self.metrics
            .lock()
            .unwrap()
            .latency_ms
            .record(resp.latency_ms);
        Ok(resp)
    }

    /// Pending work across the pool: queued *requests* (ingress + shard
    /// queues) plus — for owned lanes — queued tier-2 *batches*.  An
    /// attached pool's tier-2 backlog lives in the shared fabric and is
    /// accounted there (the deployment sums both).
    pub fn queue_depth(&self) -> usize {
        let shard: usize = {
            let g = self.slots.lock().unwrap();
            g.iter().map(|s| s.queue.len()).sum()
        };
        let t2 = match &self.sink {
            Tier2Sink::Owned { queue, .. } => queue.len(),
            Tier2Sink::Fabric(_) => 0,
        };
        self.ingress.len() + shard + t2
    }

    fn stop(&mut self) {
        self.ingress.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut g = self.slots.lock().unwrap();
            g.iter_mut()
                .filter_map(|s| {
                    s.queue.close();
                    s.handle.take()
                })
                .collect()
        };
        for h in handles {
            let _ = h.join();
        }
        if let Tier2Sink::Owned { queue, .. } = &self.sink {
            queue.close();
        }
        for h in self.lane_threads.drain(..) {
            let _ = h.join();
        }
        // credit every still-active worker back to the EPC ledger —
        // taking the account makes the (shutdown + Drop) double-stop
        // path release exactly once
        if let Some(acc) = self.epc.take() {
            acc.release(self.active.load(Ordering::SeqCst));
        }
    }

    /// Drain and stop everything; returns the final metrics.
    pub fn shutdown(mut self) -> PoolMetrics {
        self.stop();
        let metrics = std::mem::replace(
            &mut self.metrics,
            Arc::new(Mutex::new(PoolMetrics::new(0))),
        );
        Arc::try_unwrap(metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| arc.lock().unwrap().clone())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Dispatcher: session-affinity sharding with backpressure.  On a send
/// that fails because a shard retired mid-flight, the request reroutes
/// under the new active count instead of erroring.
fn spawn_dispatcher(
    ingress: Channel<InferRequest>,
    slots: Arc<Mutex<Vec<WorkerSlot>>>,
    active: Arc<AtomicUsize>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("origami-pool-dispatch".into())
        .spawn(move || {
            while let Some(mut req) = ingress.recv() {
                loop {
                    let n = active.load(Ordering::SeqCst).max(1);
                    let w = (req.session % n as u64) as usize;
                    let q = {
                        let g = slots.lock().unwrap();
                        g.get(w).map(|s| s.queue.clone())
                    };
                    let Some(q) = q else {
                        reply_error(&req, "worker pool has no worker for this shard");
                        break;
                    };
                    match q.send(req) {
                        Ok(()) => break,
                        Err(r) => {
                            req = r;
                            if ingress.is_closed() {
                                reply_error(&req, "worker pool is shutting down");
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            }
            let g = slots.lock().unwrap();
            for s in g.iter() {
                s.queue.close();
            }
        })
        .expect("spawn dispatcher")
}

fn spawn_worker(
    w: usize,
    domain: usize,
    queue: Channel<InferRequest>,
    sink: Tier2Sink,
    metrics: Arc<Mutex<PoolMetrics>>,
    factory: SchedFactory,
    opts: PoolOptions,
    telemetry: Option<Arc<TenantTelemetry>>,
    ready: Option<Channel<()>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("origami-pool-w{w}-t1"))
        .spawn(move || {
            worker_main(w, domain, queue, sink, metrics, factory, opts, telemetry, ready)
        })
        .expect("spawn tier-1 worker")
}

fn worker_main(
    w: usize,
    domain: usize,
    queue: Channel<InferRequest>,
    sink: Tier2Sink,
    m: Arc<Mutex<PoolMetrics>>,
    factory: SchedFactory,
    opts: PoolOptions,
    telemetry: Option<Arc<TenantTelemetry>>,
    ready: Option<Channel<()>>,
) {
    let batcher = {
        let mut b = DynamicBatcher::new(queue, opts.max_batch, opts.max_delay_ms);
        if opts.slo_ms > 0.0 {
            // never let batch coalescing alone eat the latency budget
            b = b.with_deadline_cap(std::time::Duration::from_secs_f64(
                opts.slo_ms * SLO_WINDOW_FRACTION / 1e3,
            ));
        }
        if opts.occupancy_flush && opts.pipeline {
            let s = sink.clone();
            b.with_flush_probe(Arc::new(move || s.starved()))
        } else {
            b
        }
    };
    let mut sched = match factory(domain) {
        Ok(s) => {
            if let Some(r) = &ready {
                let _ = r.send(());
            }
            Some(s)
        }
        Err(e) => {
            eprintln!("[pool] worker {w} failed to start: {e:#}");
            m.lock().unwrap().errors += 1;
            if let Some(r) = &ready {
                let _ = r.send(());
            }
            None
        }
    };
    // last-seen cumulative factor-pool counters, for per-batch deltas
    let mut last_pool = FactorPoolStats::default();
    while let Some(batch) = batcher.next_batch() {
        let Some(sched) = sched.as_mut() else {
            for req in &batch {
                reply_error(req, "worker failed to start");
            }
            continue;
        };
        // Admission: a mis-sized ciphertext would fail the whole
        // concatenated batch (and the batch's reply channels would be
        // dropped, hanging the peers' clients) — reject it alone
        // instead.  Reachable because the pool can be driven directly,
        // without the Router/Deployment size check.
        let (batch, rejected): (Vec<InferRequest>, Vec<InferRequest>) = batch
            .into_iter()
            .partition(|r| r.ciphertext.len() == sched.sample_bytes);
        if !rejected.is_empty() {
            let mut g = m.lock().unwrap();
            g.errors += rejected.len() as u64;
            drop(g);
            for req in &rejected {
                reply_error(req, "ciphertext has the wrong length");
            }
        }
        if batch.is_empty() {
            continue;
        }
        {
            let mut g = m.lock().unwrap();
            let set = at(&mut g.sessions_per_worker, w);
            for req in &batch {
                set.insert(req.session);
            }
        }
        if opts.pipeline {
            match sched.execute_tier1(batch, w) {
                Ok(tasks) => {
                    for task in tasks {
                        // tier-1 failures are counted once, by the
                        // finisher (ok=false)
                        let tier1_ms = task.ledger.grand_total_ms();
                        if let Some(tel) = &telemetry {
                            tel.record(Stage::Tier1, tier1_ms);
                        }
                        let mut g = m.lock().unwrap();
                        *at(&mut g.tier1_sim_ms, w) += tier1_ms;
                        drop(g);
                        if let Err(task) = sink.send(task) {
                            for req in &task.requests {
                                reply_error(req, "tier-2 lanes are shut down");
                            }
                        }
                    }
                }
                Err(e) => {
                    // unreachable after admission; keep the pool alive
                    // if it ever fires
                    eprintln!("[pool] w{w} tier-1 failed: {e:#}");
                    m.lock().unwrap().errors += 1;
                }
            }
        } else {
            match sched.execute(batch) {
                Ok(rec) => {
                    if let Some(tel) = &telemetry {
                        tel.record(Stage::Tier1, rec.sim_ms);
                        // one sample per request (matching the pipelined
                        // path's weighting), at the batch-level latency —
                        // execute() replies inline, so per-request wall
                        // clocks are not observable here
                        for _ in 0..rec.batch {
                            tel.record(Stage::EndToEnd, rec.exec_wall_ms + rec.queue_ms);
                        }
                    }
                    let mut g = m.lock().unwrap();
                    *at(&mut g.tier1_sim_ms, w) += rec.sim_ms;
                    g.record_batch(&rec);
                }
                Err(e) => {
                    eprintln!("[pool] w{w} batch failed: {e:#}");
                    m.lock().unwrap().errors += 1;
                }
            }
        }
        // Fold the strategy's cumulative factor-pool counters into the
        // tenant telemetry as deltas — hits, `factor_pool_miss`
        // fallbacks, and prefilled slots since the previous batch.
        if let Some(tel) = &telemetry {
            if let Some(stats) = sched.factor_pool_stats() {
                tel.factor_pool().record(
                    stats.hits.saturating_sub(last_pool.hits),
                    stats.misses.saturating_sub(last_pool.misses),
                    stats.prefilled.saturating_sub(last_pool.prefilled),
                );
                last_pool = stats;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enclave::cost::{Cat, CostModel, Ledger};
    use crate::runtime::{Device, ReferenceBackend, StageExecutor};
    use crate::strategies::Strategy;

    /// Minimal deterministic strategy double: "probability" = session id.
    struct Echo;

    impl Strategy for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn setup(&mut self) -> Result<()> {
            Ok(())
        }
        fn infer(
            &mut self,
            _ciphertext: &[u8],
            batch: usize,
            sessions: &[u64],
            ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            ledger.add_measured(Cat::DeviceCompute, 500_000);
            Ok((0..batch)
                .map(|i| sessions.get(i).copied().unwrap_or(0) as f32)
                .collect())
        }
        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    fn echo_opts(workers: usize, pipeline: bool) -> PoolOptions {
        PoolOptions {
            workers,
            max_batch: 4,
            max_delay_ms: 1.0,
            pipeline,
            ..PoolOptions::default()
        }
    }

    fn echo_pool_with(opts: PoolOptions) -> WorkerPool {
        WorkerPool::start(
            opts,
            |_w| Ok(BatchScheduler::new(Box::new(Echo), 8, vec![1, 4])),
            |_w| {
                let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
                Ok(Tier2Finisher::new(
                    Arc::new(StageExecutor::reference(rb, CostModel::default())),
                    "sim8",
                    Device::UntrustedCpu,
                ))
            },
        )
    }

    fn echo_pool(workers: usize, pipeline: bool) -> WorkerPool {
        echo_pool_with(echo_opts(workers, pipeline))
    }

    #[test]
    fn pool_serves_and_shards_by_session() {
        for pipeline in [false, true] {
            let pool = echo_pool(3, pipeline);
            let replies: Vec<_> = (0..30u64)
                .map(|s| (s, pool.submit("m", vec![0u8; 8], s).unwrap()))
                .collect();
            for (s, r) in replies {
                let resp = r.recv().expect("reply");
                assert!(resp.error.is_none(), "pipeline={pipeline}: {:?}", resp.error);
                assert_eq!(resp.probs[0], s as f32, "echoed its own session");
            }
            let m = pool.shutdown();
            assert_eq!(m.requests, 30);
            assert!(m.affinity_held(), "pipeline={pipeline}");
            // every shard saw exactly its residue class
            for (w, set) in m.sessions_per_worker.iter().enumerate() {
                assert!(set.iter().all(|s| (s % 3) as usize == w));
                assert!(!set.is_empty(), "worker {w} starved");
            }
        }
    }

    #[test]
    fn drop_and_idle_shutdown_are_clean() {
        // Drop without shutdown must close + join without hanging…
        let pool = echo_pool(2, true);
        drop(pool);
        // …and an idle pool shuts down with empty metrics.
        let pool2 = echo_pool(1, false);
        let metrics = pool2.shutdown();
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.requests, 0);
    }

    #[test]
    fn wrong_sized_ciphertext_rejected_without_hanging_peers() {
        let pool = echo_pool(1, true);
        // same shard, same batch window: one bad request + two good ones
        let bad = pool.submit("m", vec![0u8; 3], 0).unwrap();
        let good: Vec<_> = (1..=2u64)
            .map(|i| pool.submit("m", vec![0u8; 8], 3 * i).unwrap())
            .collect();
        let resp = bad.recv().expect("bad request still gets a reply");
        assert!(resp.error.is_some(), "mis-sized ciphertext must error");
        for (i, g) in good.into_iter().enumerate() {
            let resp = g.recv().expect("peer reply arrives (no hang)");
            assert!(resp.error.is_none(), "peer {i}: {:?}", resp.error);
        }
        let m = pool.shutdown();
        assert_eq!(m.errors, 1);
        assert_eq!(m.requests, 2, "only well-formed requests are served");
    }

    #[test]
    fn lane_accounting_feeds_speedup() {
        let mut m = PoolMetrics::new(2);
        m.tier1_sim_ms = vec![10.0, 12.0];
        m.tier2_sim_ms = vec![5.0, 3.0];
        m.sim_ms_total = 30.0;
        assert_eq!(m.simulated_makespan_ms(), 12.0);
        assert!((m.simulated_speedup() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn scale_up_and_down_serves_throughout() {
        let opts = PoolOptions {
            min_workers: 1,
            max_workers: 4,
            ..echo_opts(1, true)
        };
        let pool = echo_pool_with(opts);
        assert_eq!(pool.active_workers(), 1);

        let serve = |n: u64, base: u64| {
            let replies: Vec<_> = (0..n)
                .map(|s| (base + s, pool.submit("m", vec![0u8; 8], base + s).unwrap()))
                .collect();
            for (s, r) in replies {
                let resp = r.recv().expect("reply");
                assert!(resp.error.is_none(), "session {s}: {:?}", resp.error);
                assert_eq!(resp.probs[0], s as f32);
            }
        };

        serve(8, 0);
        assert_eq!(pool.scale_to(3), 3, "grow within bounds");
        serve(8, 100);
        assert_eq!(pool.scale_to(9), 4, "clamped to max_workers");
        assert_eq!(pool.scale_to(0), 1, "clamped to min_workers");
        serve(8, 200);

        let m = pool.shutdown();
        assert_eq!(m.requests, 24);
        assert_eq!(m.errors, 0);
        assert!(m.grow_events >= 2);
        assert!(m.shrink_events >= 1);
        assert_eq!(m.peak_workers, 4);
        // workers beyond the initial one actually did tier-1 work
        assert!(m.tier1_sim_ms.len() > 1, "scaled workers appear in metrics");
    }

    #[test]
    fn respawned_workers_never_reuse_a_blinding_domain() {
        // OTP safety under autoscaling: a retired slot that respawns
        // must get a *fresh* domain — its new strategy restarts its
        // epoch counter at 0, so reusing the old domain would re-emit
        // already-consumed one-time pads.
        let domains = Arc::new(Mutex::new(Vec::new()));
        let d2 = domains.clone();
        let opts = PoolOptions {
            min_workers: 1,
            max_workers: 3,
            ..echo_opts(1, true)
        };
        let pool = WorkerPool::start(
            opts,
            move |domain| {
                d2.lock().unwrap().push(domain);
                Ok(BatchScheduler::new(Box::new(Echo), 8, vec![1, 4]))
            },
            |_w| {
                let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
                Ok(Tier2Finisher::new(
                    Arc::new(StageExecutor::reference(rb, CostModel::default())),
                    "sim8",
                    Device::UntrustedCpu,
                ))
            },
        );
        pool.scale_to(3); // slots 1,2 spawn
        pool.scale_to(1); // slots 1,2 retire
        pool.scale_to(3); // slots 1,2 respawn — must not repeat domains
        drop(pool);
        let seen = domains.lock().unwrap().clone();
        assert_eq!(seen.len(), 5, "1 initial + 2 grown + 2 respawned: {seen:?}");
        let unique: std::collections::BTreeSet<usize> = seen.iter().copied().collect();
        assert_eq!(unique.len(), seen.len(), "a blinding domain was reused: {seen:?}");
    }
}
