//! Sharded multi-worker serving pool with pipelined Origami tiers.
//!
//! ```text
//!                       ┌─ worker 0: [batcher]→ tier-1 (enclave w0) ─┐
//! clients → ingress → dispatcher (session-affinity shard)           ├→ shared tier-2 queue
//!                       └─ worker N: [batcher]→ tier-1 (enclave wN) ─┘        │
//!                                            tier-2 lanes (open device) ◀────┘  (work-stealing)
//! ```
//!
//! Three properties the single-engine serving loop lacks:
//!
//! 1. **Session-affinity sharding.**  The dispatcher routes a request to
//!    worker `session % N`, so a session's tier-1 — the part that touches
//!    blinding state — always executes on the same enclave.  Each worker's
//!    pad stream lives in a disjoint keyspace (`Config::blind_domain` =
//!    worker index), so pooling never reuses a one-time pad across
//!    workers.
//! 2. **Tier pipelining.**  Inside a worker, tier-1 of batch *k+1*
//!    (enclave: decrypt, blind, unblind, non-linear) overlaps tier-2 of
//!    batch *k* (open device: the fused tail) — the overlap Origami's
//!    two-tier split creates and a serial `Strategy::infer` loop wastes.
//! 3. **Work stealing.**  Tier-2 tasks carry no enclave state, so they
//!    drain through one shared queue: any idle tier-2 lane finishes any
//!    worker's tail, absorbing load imbalance between shards.
//!
//! Outputs are bit-identical to the serial single-worker path: tier
//! splitting reorders *when* work happens, never *what* is computed.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::api::{reply_error, BatchRecord, InferRequest, InferResponse};
use super::batcher::DynamicBatcher;
use super::scheduler::{BatchScheduler, Tier2Finisher, Tier2Task};
use crate::util::stats::Summary;
use crate::util::threadpool::Channel;

/// Pool geometry and policy.
#[derive(Debug, Clone)]
pub struct PoolOptions {
    /// Worker shards (one strategy instance + enclave each).
    pub workers: usize,
    /// Dynamic batcher: max batch per shard.
    pub max_batch: usize,
    /// Dynamic batcher: max queueing delay (ms).
    pub max_delay_ms: f64,
    /// Overlap tier-1/tier-2 (double-buffered tiers + stealing lanes).
    pub pipeline: bool,
    /// Shared ingress bound (client backpressure).
    pub ingress_cap: usize,
    /// Per-worker queue bound (shard backpressure).
    pub worker_queue_cap: usize,
}

impl Default for PoolOptions {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            max_delay_ms: 2.0,
            pipeline: true,
            ingress_cap: 256,
            worker_queue_cap: 64,
        }
    }
}

/// Aggregated pool metrics, including per-lane simulated busy time.
pub struct PoolMetrics {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub latency_ms: Summary,
    pub queue_ms: Summary,
    pub exec_wall_ms: Summary,
    pub batch_size: Summary,
    /// Sum of every batch's simulated cost — what one serial worker
    /// would spend on the same traffic.
    pub sim_ms_total: f64,
    /// Simulated busy time of each worker's tier-1 (enclave) lane.
    pub tier1_sim_ms: Vec<f64>,
    /// Simulated busy time of each tier-2 (open device) lane.
    pub tier2_sim_ms: Vec<f64>,
    /// Sessions whose tier-1 ran on each worker (affinity audit: the
    /// sets must be pairwise disjoint).
    pub sessions_per_worker: Vec<BTreeSet<u64>>,
    /// Tier-2 batches finished by a lane other than the home worker's.
    pub stolen_batches: u64,
}

impl PoolMetrics {
    fn new(workers: usize) -> Self {
        Self {
            requests: 0,
            batches: 0,
            errors: 0,
            latency_ms: Summary::new(),
            queue_ms: Summary::new(),
            exec_wall_ms: Summary::new(),
            batch_size: Summary::new(),
            sim_ms_total: 0.0,
            tier1_sim_ms: vec![0.0; workers],
            tier2_sim_ms: vec![0.0; workers],
            sessions_per_worker: vec![BTreeSet::new(); workers],
            stolen_batches: 0,
        }
    }

    fn record_batch(&mut self, rec: &BatchRecord) {
        self.batches += 1;
        self.requests += rec.batch as u64;
        self.queue_ms.record(rec.queue_ms);
        self.exec_wall_ms.record(rec.exec_wall_ms);
        self.batch_size.record(rec.batch as f64);
        self.sim_ms_total += rec.sim_ms;
    }

    /// Pool makespan on the simulated timeline: each tier-1 lane is an
    /// independent enclave and each tier-2 lane an independent device
    /// stream, so the makespan is the busiest lane.
    pub fn simulated_makespan_ms(&self) -> f64 {
        self.tier1_sim_ms
            .iter()
            .chain(self.tier2_sim_ms.iter())
            .fold(0.0f64, |a, &b| a.max(b))
    }

    /// Throughput speedup of the pool over one serial worker, on the
    /// simulated-cost timeline (deterministic; independent of host core
    /// count).
    pub fn simulated_speedup(&self) -> f64 {
        let makespan = self.simulated_makespan_ms();
        if makespan <= 0.0 {
            return 1.0;
        }
        self.sim_ms_total / makespan
    }

    /// True when no session's tier-1 ran on two different workers.
    pub fn affinity_held(&self) -> bool {
        let mut seen = BTreeSet::new();
        for set in &self.sessions_per_worker {
            for s in set {
                if !seen.insert(*s) {
                    return false;
                }
            }
        }
        true
    }
}

/// The multi-worker serving pool.
pub struct WorkerPool {
    ingress: Channel<InferRequest>,
    worker_queues: Vec<Channel<InferRequest>>,
    tier2_queue: Channel<Tier2Task>,
    threads: Vec<JoinHandle<()>>,
    pub metrics: Arc<Mutex<PoolMetrics>>,
    next_id: AtomicU64,
    workers: usize,
}

impl WorkerPool {
    /// Start the pool.
    ///
    /// `sched_factory(w)` builds worker *w*'s [`BatchScheduler`] inside
    /// its tier-1 thread (strategies hold thread-local runtime handles);
    /// it must configure the strategy with `blind_domain = w` so pad
    /// streams stay disjoint — the launcher's factories do.
    /// `finisher_factory(w)` builds lane *w*'s [`Tier2Finisher`] inside
    /// its tier-2 thread (only used when `opts.pipeline`).
    pub fn start<S, F>(opts: PoolOptions, sched_factory: S, finisher_factory: F) -> Self
    where
        S: Fn(usize) -> Result<BatchScheduler> + Send + Sync + 'static,
        F: Fn(usize) -> Result<Tier2Finisher> + Send + Sync + 'static,
    {
        let workers = opts.workers.max(1);
        let ingress: Channel<InferRequest> = Channel::bounded(opts.ingress_cap.max(1));
        let worker_queues: Vec<Channel<InferRequest>> = (0..workers)
            .map(|_| Channel::bounded(opts.worker_queue_cap.max(1)))
            .collect();
        // Double-buffer depth: one in-flight tier-2 task per worker keeps
        // every enclave lane busy without unbounded feature-map buildup.
        let tier2_queue: Channel<Tier2Task> = Channel::bounded(workers.max(2));
        let metrics = Arc::new(Mutex::new(PoolMetrics::new(workers)));
        let sched_factory = Arc::new(sched_factory);
        let finisher_factory = Arc::new(finisher_factory);
        let lanes = workers * if opts.pipeline { 2 } else { 1 };
        let ready = Arc::new(Barrier::new(lanes + 1));
        let t1_active = Arc::new(AtomicUsize::new(workers));
        let mut threads = Vec::new();

        // Dispatcher: session-affinity sharding with backpressure.
        {
            let ingress = ingress.clone();
            let queues = worker_queues.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("origami-pool-dispatch".into())
                    .spawn(move || {
                        while let Some(req) = ingress.recv() {
                            let w = (req.session % queues.len() as u64) as usize;
                            if let Err(req) = queues[w].send(req) {
                                // shard queue closed mid-shutdown: fail loud
                                reply_error(&req, "worker pool is shutting down");
                            }
                        }
                        for q in &queues {
                            q.close();
                        }
                    })
                    .expect("spawn dispatcher"),
            );
        }

        // Tier-1 workers: one enclave-owning shard each.
        for w in 0..workers {
            let queue = worker_queues[w].clone();
            let t2q = tier2_queue.clone();
            let m = metrics.clone();
            let factory = sched_factory.clone();
            let r = ready.clone();
            let active = t1_active.clone();
            let opts_c = opts.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("origami-pool-w{w}-t1"))
                    .spawn(move || {
                        let batcher =
                            DynamicBatcher::new(queue, opts_c.max_batch, opts_c.max_delay_ms);
                        let mut sched = match factory(w) {
                            Ok(s) => {
                                r.wait();
                                Some(s)
                            }
                            Err(e) => {
                                eprintln!("[pool] worker {w} failed to start: {e:#}");
                                m.lock().unwrap().errors += 1;
                                r.wait();
                                None
                            }
                        };
                        while let Some(batch) = batcher.next_batch() {
                            let Some(sched) = sched.as_mut() else {
                                for req in &batch {
                                    reply_error(req, "worker failed to start");
                                }
                                continue;
                            };
                            // Admission: a mis-sized ciphertext would fail
                            // the whole concatenated batch (and the batch's
                            // reply channels would be dropped, hanging the
                            // peers' clients) — reject it alone instead.
                            // Reachable because the pool can be driven
                            // directly, without the Router's size check.
                            let (batch, rejected): (Vec<InferRequest>, Vec<InferRequest>) =
                                batch.into_iter().partition(|r| {
                                    r.ciphertext.len() == sched.sample_bytes
                                });
                            if !rejected.is_empty() {
                                let mut g = m.lock().unwrap();
                                g.errors += rejected.len() as u64;
                                drop(g);
                                for req in &rejected {
                                    reply_error(req, "ciphertext has the wrong length");
                                }
                            }
                            if batch.is_empty() {
                                continue;
                            }
                            {
                                let mut g = m.lock().unwrap();
                                for req in &batch {
                                    g.sessions_per_worker[w].insert(req.session);
                                }
                            }
                            if opts_c.pipeline {
                                match sched.execute_tier1(batch, w) {
                                    Ok(tasks) => {
                                        for task in tasks {
                                            // tier-1 failures are counted once,
                                            // by the finisher (ok=false)
                                            let mut g = m.lock().unwrap();
                                            g.tier1_sim_ms[w] +=
                                                task.ledger.grand_total_ms();
                                            drop(g);
                                            if let Err(task) = t2q.send(task) {
                                                for req in &task.requests {
                                                    reply_error(
                                                        req,
                                                        "tier-2 lanes are shut down",
                                                    );
                                                }
                                            }
                                        }
                                    }
                                    Err(e) => {
                                        // unreachable after admission; keep
                                        // the pool alive if it ever fires
                                        eprintln!("[pool] w{w} tier-1 failed: {e:#}");
                                        m.lock().unwrap().errors += 1;
                                    }
                                }
                            } else {
                                match sched.execute(batch) {
                                    Ok(rec) => {
                                        let mut g = m.lock().unwrap();
                                        g.tier1_sim_ms[w] += rec.sim_ms;
                                        g.record_batch(&rec);
                                    }
                                    Err(e) => {
                                        eprintln!("[pool] w{w} batch failed: {e:#}");
                                        m.lock().unwrap().errors += 1;
                                    }
                                }
                            }
                        }
                        // last tier-1 worker out closes the tier-2 queue
                        if active.fetch_sub(1, Ordering::SeqCst) == 1 {
                            t2q.close();
                        }
                    })
                    .expect("spawn tier-1 worker"),
            );
        }

        // Tier-2 lanes: keyless finishers draining the shared queue
        // (work stealing: any lane takes any worker's tail).
        if opts.pipeline {
            for w in 0..workers {
                let t2q = tier2_queue.clone();
                let m = metrics.clone();
                let factory = finisher_factory.clone();
                let r = ready.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("origami-pool-w{w}-t2"))
                        .spawn(move || {
                            let fin = match factory(w) {
                                Ok(f) => {
                                    r.wait();
                                    Some(f)
                                }
                                Err(e) => {
                                    eprintln!("[pool] tier-2 lane {w} failed: {e:#}");
                                    m.lock().unwrap().errors += 1;
                                    r.wait();
                                    None
                                }
                            };
                            while let Some(task) = t2q.recv() {
                                let Some(fin) = fin.as_ref() else {
                                    for req in &task.requests {
                                        reply_error(req, "tier-2 lane failed to start");
                                    }
                                    continue;
                                };
                                let home = task.home_worker;
                                let out = fin.finish(task);
                                let mut g = m.lock().unwrap();
                                g.tier2_sim_ms[w] += out.tier2_sim_ms;
                                if home != w {
                                    g.stolen_batches += 1;
                                }
                                if !out.ok {
                                    g.errors += 1;
                                }
                                g.record_batch(&out.record);
                            }
                        })
                        .expect("spawn tier-2 lane"),
                );
            }
        }

        ready.wait();
        Self {
            ingress,
            worker_queues,
            tier2_queue,
            threads,
            metrics,
            next_id: AtomicU64::new(1),
            workers,
        }
    }

    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Submit an encrypted request; returns the reply channel.
    pub fn submit(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<Channel<InferResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (req, reply) = InferRequest::new(id, model, ciphertext, session);
        self.ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("worker pool is shut down"))?;
        Ok(reply)
    }

    /// Submit and block for the response (records client latency).
    pub fn infer_blocking(
        &self,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> Result<InferResponse> {
        let reply = self.submit(model, ciphertext, session)?;
        let resp = reply
            .recv()
            .ok_or_else(|| anyhow::anyhow!("reply channel closed"))?;
        self.metrics
            .lock()
            .unwrap()
            .latency_ms
            .record(resp.latency_ms);
        Ok(resp)
    }

    /// Pending work across the pool: queued *requests* (ingress + shard
    /// queues) plus queued tier-2 *batches* (each carrying up to
    /// max-batch requests awaiting their open tail).
    pub fn queue_depth(&self) -> usize {
        self.ingress.len()
            + self.worker_queues.iter().map(|q| q.len()).sum::<usize>()
            + self.tier2_queue.len()
    }

    /// Drain and stop everything; returns the final metrics.
    pub fn shutdown(mut self) -> PoolMetrics {
        self.ingress.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let metrics = std::mem::replace(
            &mut self.metrics,
            Arc::new(Mutex::new(PoolMetrics::new(0))),
        );
        Arc::try_unwrap(metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_else(|arc| {
                let g = arc.lock().unwrap();
                PoolMetrics {
                    requests: g.requests,
                    batches: g.batches,
                    errors: g.errors,
                    latency_ms: g.latency_ms.clone(),
                    queue_ms: g.queue_ms.clone(),
                    exec_wall_ms: g.exec_wall_ms.clone(),
                    batch_size: g.batch_size.clone(),
                    sim_ms_total: g.sim_ms_total,
                    tier1_sim_ms: g.tier1_sim_ms.clone(),
                    tier2_sim_ms: g.tier2_sim_ms.clone(),
                    sessions_per_worker: g.sessions_per_worker.clone(),
                    stolen_batches: g.stolen_batches,
                }
            })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.ingress.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::enclave::cost::{Cat, CostModel, Ledger};
    use crate::runtime::{Device, ReferenceBackend, StageExecutor};
    use crate::strategies::Strategy;

    /// Minimal deterministic strategy double: "probability" = session id.
    struct Echo;

    impl Strategy for Echo {
        fn name(&self) -> String {
            "echo".into()
        }
        fn setup(&mut self) -> Result<()> {
            Ok(())
        }
        fn infer(
            &mut self,
            _ciphertext: &[u8],
            batch: usize,
            sessions: &[u64],
            ledger: &mut Ledger,
        ) -> Result<Vec<f32>> {
            ledger.add_measured(Cat::DeviceCompute, 500_000);
            Ok((0..batch)
                .map(|i| sessions.get(i).copied().unwrap_or(0) as f32)
                .collect())
        }
        fn enclave_requirement_bytes(&self) -> u64 {
            0
        }
    }

    fn echo_pool(workers: usize, pipeline: bool) -> WorkerPool {
        let opts = PoolOptions {
            workers,
            max_batch: 4,
            max_delay_ms: 1.0,
            pipeline,
            ..PoolOptions::default()
        };
        WorkerPool::start(
            opts,
            |_w| Ok(BatchScheduler::new(Box::new(Echo), 8, vec![1, 4])),
            |_w| {
                let rb = Arc::new(ReferenceBackend::vgg_lite("sim8", 1)?);
                Ok(Tier2Finisher::new(
                    Arc::new(StageExecutor::reference(rb, CostModel::default())),
                    "sim8",
                    Device::UntrustedCpu,
                ))
            },
        )
    }

    #[test]
    fn pool_serves_and_shards_by_session() {
        for pipeline in [false, true] {
            let pool = echo_pool(3, pipeline);
            let replies: Vec<_> = (0..30u64)
                .map(|s| (s, pool.submit("m", vec![0u8; 8], s).unwrap()))
                .collect();
            for (s, r) in replies {
                let resp = r.recv().expect("reply");
                assert!(resp.error.is_none(), "pipeline={pipeline}: {:?}", resp.error);
                assert_eq!(resp.probs[0], s as f32, "echoed its own session");
            }
            let m = pool.shutdown();
            assert_eq!(m.requests, 30);
            assert!(m.affinity_held(), "pipeline={pipeline}");
            // every shard saw exactly its residue class
            for (w, set) in m.sessions_per_worker.iter().enumerate() {
                assert!(set.iter().all(|s| (s % 3) as usize == w));
                assert!(!set.is_empty(), "worker {w} starved");
            }
        }
    }

    #[test]
    fn drop_and_idle_shutdown_are_clean() {
        // Drop without shutdown must close + join without hanging…
        let pool = echo_pool(2, true);
        drop(pool);
        // …and an idle pool shuts down with empty metrics.
        let pool2 = echo_pool(1, false);
        let metrics = pool2.shutdown();
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.requests, 0);
    }

    #[test]
    fn wrong_sized_ciphertext_rejected_without_hanging_peers() {
        let pool = echo_pool(1, true);
        // same shard, same batch window: one bad request + two good ones
        let bad = pool.submit("m", vec![0u8; 3], 0).unwrap();
        let good: Vec<_> = (1..=2u64)
            .map(|i| pool.submit("m", vec![0u8; 8], 3 * i).unwrap())
            .collect();
        let resp = bad.recv().expect("bad request still gets a reply");
        assert!(resp.error.is_some(), "mis-sized ciphertext must error");
        for (i, g) in good.into_iter().enumerate() {
            let resp = g.recv().expect("peer reply arrives (no hang)");
            assert!(resp.error.is_none(), "peer {i}: {:?}", resp.error);
        }
        let m = pool.shutdown();
        assert_eq!(m.errors, 1);
        assert_eq!(m.requests, 2, "only well-formed requests are served");
    }

    #[test]
    fn lane_accounting_feeds_speedup() {
        let mut m = PoolMetrics::new(2);
        m.tier1_sim_ms = vec![10.0, 12.0];
        m.tier2_sim_ms = vec![5.0, 3.0];
        m.sim_ms_total = 30.0;
        assert_eq!(m.simulated_makespan_ms(), 12.0);
        assert!((m.simulated_speedup() - 2.5).abs() < 1e-12);
    }
}
