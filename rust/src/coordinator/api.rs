//! Request/response types of the serving API.

use std::time::Instant;

use super::admission::InflightPermit;
use crate::enclave::cost::Ledger;
use crate::util::threadpool::Channel;

/// A client inference request: one encrypted image.
pub struct InferRequest {
    pub id: u64,
    /// Target model name (routing key).
    pub model: String,
    /// AES-CTR ciphertext of the f32 NHWC image (session keystream).
    pub ciphertext: Vec<u8>,
    /// Attested session id (selects keys + factor epoch).
    pub session: u64,
    /// Enqueue timestamp (queueing latency measurement).
    pub submitted_at: Instant,
    /// Where the response goes.
    pub reply: Channel<InferResponse>,
    /// In-flight admission slot the request occupies (deployment quota).
    /// Released when the request is dropped — after its reply is sent or
    /// an error path discards it — so slots can never leak.
    pub permit: Option<InflightPermit>,
}

impl InferRequest {
    pub fn new(
        id: u64,
        model: &str,
        ciphertext: Vec<u8>,
        session: u64,
    ) -> (Self, Channel<InferResponse>) {
        let reply = Channel::bounded(1);
        (
            Self {
                id,
                model: model.to_string(),
                ciphertext,
                session,
                submitted_at: Instant::now(),
                reply: reply.clone(),
                permit: None,
            },
            reply,
        )
    }
}

/// The serving response.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    /// Class probabilities, or empty on error.
    pub probs: Vec<f32>,
    /// End-to-end latency including queueing (wall, ms).
    pub latency_ms: f64,
    /// Simulated-timeline cost of the batch this request rode in (ms,
    /// amortized per request).
    pub sim_ms: f64,
    /// Batch size the request was served in.
    pub batch: usize,
    pub error: Option<String>,
}

/// Reply to a request with an error response (lets serving loops fail
/// loudly instead of dropping the reply channel and hanging the client).
pub fn reply_error(req: &InferRequest, msg: &str) {
    let _ = req.reply.send(InferResponse {
        id: req.id,
        probs: vec![],
        latency_ms: req.submitted_at.elapsed().as_secs_f64() * 1e3,
        sim_ms: 0.0,
        batch: 0,
        error: Some(msg.to_string()),
    });
}

/// Per-batch execution record the scheduler emits for metrics.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub batch: usize,
    pub queue_ms: f64,
    pub exec_wall_ms: f64,
    pub sim_ms: f64,
    pub ledger: LedgerSummary,
}

/// Compact ledger view for metrics streams.
#[derive(Debug, Clone, Default)]
pub struct LedgerSummary {
    pub measured_ms: f64,
    pub modeled_ms: f64,
    pub blind_ms: f64,
    pub device_ms: f64,
    pub paging_ms: f64,
}

impl LedgerSummary {
    /// Accumulate another summary (category-wise sum) — split executions
    /// of one logical batch report as a single record.
    pub fn merge(&mut self, other: &Self) {
        self.measured_ms += other.measured_ms;
        self.modeled_ms += other.modeled_ms;
        self.blind_ms += other.blind_ms;
        self.device_ms += other.device_ms;
        self.paging_ms += other.paging_ms;
    }

    pub fn from(l: &Ledger) -> Self {
        use crate::enclave::cost::Cat;
        Self {
            measured_ms: l.total_measured_ns() as f64 / 1e6,
            modeled_ms: l.total_modeled_ns() as f64 / 1e6,
            blind_ms: (l.total_ns(Cat::Blind) + l.total_ns(Cat::Unblind)) as f64 / 1e6,
            device_ms: l.total_ns(Cat::DeviceCompute) as f64 / 1e6,
            paging_ms: l.total_ns(Cat::Paging) as f64 / 1e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_reply_channel_wiring() {
        let (req, reply) = InferRequest::new(1, "m", vec![1, 2, 3], 7);
        req.reply
            .send(InferResponse {
                id: req.id,
                probs: vec![0.5],
                latency_ms: 1.0,
                sim_ms: 2.0,
                batch: 1,
                error: None,
            })
            .map_err(|_| ())
            .unwrap();
        let resp = reply.recv().unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.probs, vec![0.5]);
    }

    #[test]
    fn ledger_summary_extracts_categories() {
        use crate::enclave::cost::{Cat, Ledger};
        let mut l = Ledger::new();
        l.add_measured(Cat::Blind, 1_000_000);
        l.add_measured(Cat::Unblind, 500_000);
        l.add_modeled(Cat::DeviceCompute, 2_000_000);
        let s = LedgerSummary::from(&l);
        assert!((s.blind_ms - 1.5).abs() < 1e-9);
        assert!((s.device_ms - 2.0).abs() < 1e-9);
        assert!((s.measured_ms - 1.5).abs() < 1e-9);
        assert!((s.modeled_ms - 2.0).abs() < 1e-9);
    }
}
