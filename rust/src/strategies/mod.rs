//! Execution strategies — the four systems the paper evaluates, plus the
//! non-private reference.
//!
//! | Strategy           | Linear layers            | Non-linear | Tail |
//! |--------------------|--------------------------|------------|------|
//! | [`baseline`] (Baseline2) | enclave (lazy dense) | enclave    | —    |
//! | [`split`] (Split/x)      | enclave through x    | enclave    | open offload |
//! | [`slalom`] (Slalom/Privacy) | blinded offload, every layer | enclave | — |
//! | [`origami`] (Origami/p)  | blinded offload through p | enclave | open offload |
//! | [`open`] (no privacy)    | device, whole model  | device     | —    |
//!
//! All strategies implement [`Strategy`]: `setup()` (model/params/factor
//! precompute — explicitly *not* inference time, matching the paper) and
//! `infer()` (the timed request path, returning class probabilities and
//! a cost [`Ledger`]).

pub mod baseline;
pub mod ctx;
pub mod memory;
pub mod open;
pub mod origami;
pub mod slalom;
pub mod split;

use anyhow::Result;

use crate::enclave::cost::Ledger;
use crate::model::partition::PartitionPlan;
pub use ctx::StrategyCtx;

/// What tier-1 of a request produced.
///
/// Tiered strategies (Origami, Split) hand back the intermediate feature
/// map plus the open-tail stage that finishes it; the tail needs no
/// enclave keys, so *any* executor — another worker's tier-2 lane, a
/// work-stealing peer — can run it.  Non-tiered strategies return the
/// final probabilities directly.
pub enum Tier1Output {
    /// The strategy has no open tier-2; these are the class probabilities.
    Final(Vec<f32>),
    /// Tier-1 is done; run `stage` on `features` (open device) to finish.
    Handoff {
        features: Vec<f32>,
        /// Tail stage name (e.g. `tail_p06`).
        stage: String,
    },
}

/// A private-inference execution strategy.
///
/// NOT `Send`: strategies hold PJRT handles (the `xla` crate's client and
/// executables are `Rc`-backed), so each serving worker constructs its
/// own strategy inside its thread via [`ServingEngine::start`]'s factory.
///
/// [`ServingEngine::start`]: crate::coordinator::ServingEngine::start
pub trait Strategy {
    /// Human-readable name (matches the paper's figure labels).
    fn name(&self) -> String;

    /// One-time setup: enclave build, parameter residency, unblinding-
    /// factor precompute, artifact warmup. Not counted as inference time.
    fn setup(&mut self) -> Result<()>;

    /// Run one encrypted inference request of `batch` images.
    ///
    /// `ciphertext` concatenates `batch` independently encrypted samples;
    /// `sessions[i]` is the attested session of sample i (padding slots
    /// have no session entry and decode to zero samples).  Blinding-factor
    /// epochs are enclave-internal (a monotone counter), NOT client
    /// sessions — clients must not be able to pick the pad.  Returns
    /// class probabilities (batch × classes flattened).
    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>>;

    /// Enclave memory the strategy declares (Table I).
    fn enclave_requirement_bytes(&self) -> u64;

    /// Tier-1 of one request: everything that requires enclave state
    /// (session decryption, blinding, unblinding, in-enclave non-linear
    /// ops).  Tiered strategies return a [`Tier1Output::Handoff`] whose
    /// open tail can execute on a different thread/worker, which is what
    /// lets the pool overlap batch *k+1*'s tier-1 with batch *k*'s
    /// tier-2.  The default runs the whole inference (no overlap).
    fn infer_tier1(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Tier1Output> {
        Ok(Tier1Output::Final(self.infer(
            ciphertext, batch, sessions, ledger,
        )?))
    }

    /// Whether [`Strategy::infer_tier1`] can return a `Handoff` (i.e. the
    /// pipelined pool path actually overlaps something for this strategy).
    fn tiered(&self) -> bool {
        false
    }

    /// Simulate a power event + recovery; returns total recovery ms
    /// (Table II). Default: strategies without an enclave return 0.
    fn power_cycle(&mut self) -> Result<f64> {
        Ok(0.0)
    }

    /// Cumulative blinding-factor-pool counters (hits, `factor_pool_miss`
    /// fallbacks, staging state).  Default: strategies without a pool —
    /// or with `factor_pool_depth = 0` — return None, and the serving
    /// pool records no factor-pool telemetry for them.
    fn factor_pool_stats(&self) -> Option<crate::blinding::FactorPoolStats> {
        None
    }

    /// Cumulative feature-map arena counters (takes/hits/fresh).  The
    /// fig20 arena leg asserts `fresh` stays flat in steady state — zero
    /// activation allocations once the size classes are warm.  Default:
    /// strategies that do not thread an arena return None.
    fn arena_stats(&self) -> Option<crate::util::arena::ArenaStats> {
        None
    }
}

/// Instantiate a strategy by config name.  [`partition_plan_for`] below
/// is the same dispatch table mapped onto [`PartitionPlan`]s — the two
/// matches live side by side so a new strategy cannot be added to one
/// without the other.
pub fn build(ctx: StrategyCtx, strategy: &str, partition: usize) -> Result<Box<dyn Strategy>> {
    let s = strategy.to_ascii_lowercase();
    if let Some(x) = s.strip_prefix("split/") {
        return Ok(Box::new(split::Split::new(ctx, x.parse()?)));
    }
    if let Some(p) = s.strip_prefix("origami/") {
        return Ok(Box::new(origami::Origami::new(ctx, p.parse()?)));
    }
    Ok(match s.as_str() {
        "baseline2" | "baseline" => Box::new(baseline::Baseline2::new(ctx)),
        "slalom" => Box::new(slalom::Slalom::new(ctx)),
        "origami" => Box::new(origami::Origami::new(ctx, partition)),
        "open" | "none" => Box::new(open::OpenInference::new(ctx)),
        other => anyhow::bail!(
            "unknown strategy `{other}` (baseline2|split/N|slalom|origami[/N]|open)"
        ),
    })
}

/// The partition plan a strategy name describes — what the memory
/// analytics ([`memory::enclave_requirement`]) and the EPC ledger's
/// per-worker footprint estimate evaluate.  `open` runs no enclave →
/// `None`.  Mirrors [`build`]'s dispatch exactly (kept adjacent so the
/// tables cannot drift; pinned by a test).
pub fn partition_plan_for(
    model: &crate::model::Model,
    strategy: &str,
    partition: usize,
) -> Result<Option<PartitionPlan>> {
    let s = strategy.to_ascii_lowercase();
    if let Some(x) = s.strip_prefix("split/") {
        return Ok(Some(PartitionPlan::split(model, x.parse()?)));
    }
    if let Some(p) = s.strip_prefix("origami/") {
        return Ok(Some(PartitionPlan::origami(model, p.parse()?)));
    }
    Ok(match s.as_str() {
        "baseline2" | "baseline" => Some(PartitionPlan::baseline(model)),
        "slalom" => Some(PartitionPlan::slalom(model)),
        "origami" => Some(PartitionPlan::origami(model, partition)),
        "open" | "none" => None,
        other => anyhow::bail!(
            "unknown strategy `{other}` (baseline2|split/N|slalom|origami[/N]|open)"
        ),
    })
}
