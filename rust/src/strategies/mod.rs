//! Execution strategies — the four systems the paper evaluates, plus the
//! non-private reference.
//!
//! | Strategy           | Linear layers            | Non-linear | Tail |
//! |--------------------|--------------------------|------------|------|
//! | [`baseline`] (Baseline2) | enclave (lazy dense) | enclave    | —    |
//! | [`split`] (Split/x)      | enclave through x    | enclave    | open offload |
//! | [`slalom`] (Slalom/Privacy) | blinded offload, every layer | enclave | — |
//! | [`origami`] (Origami/p)  | blinded offload through p | enclave | open offload |
//! | [`open`] (no privacy)    | device, whole model  | device     | —    |
//!
//! All strategies implement [`Strategy`]: `setup()` (model/params/factor
//! precompute — explicitly *not* inference time, matching the paper) and
//! `infer()` (the timed request path, returning class probabilities and
//! a cost [`Ledger`]).

pub mod baseline;
pub mod ctx;
pub mod memory;
pub mod open;
pub mod origami;
pub mod slalom;
pub mod split;

use anyhow::Result;

use crate::enclave::cost::Ledger;
pub use ctx::StrategyCtx;

/// A private-inference execution strategy.
///
/// NOT `Send`: strategies hold PJRT handles (the `xla` crate's client and
/// executables are `Rc`-backed), so each serving worker constructs its
/// own strategy inside its thread via [`ServingEngine::start`]'s factory.
///
/// [`ServingEngine::start`]: crate::coordinator::ServingEngine::start
pub trait Strategy {
    /// Human-readable name (matches the paper's figure labels).
    fn name(&self) -> String;

    /// One-time setup: enclave build, parameter residency, unblinding-
    /// factor precompute, artifact warmup. Not counted as inference time.
    fn setup(&mut self) -> Result<()>;

    /// Run one encrypted inference request of `batch` images.
    ///
    /// `ciphertext` concatenates `batch` independently encrypted samples;
    /// `sessions[i]` is the attested session of sample i (padding slots
    /// may be absent and decrypt under session 0).  Blinding-factor
    /// epochs are enclave-internal (a monotone counter), NOT client
    /// sessions — clients must not be able to pick the pad.  Returns
    /// class probabilities (batch × classes flattened).
    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>>;

    /// Enclave memory the strategy declares (Table I).
    fn enclave_requirement_bytes(&self) -> u64;

    /// Simulate a power event + recovery; returns total recovery ms
    /// (Table II). Default: strategies without an enclave return 0.
    fn power_cycle(&mut self) -> Result<f64> {
        Ok(0.0)
    }
}

/// Instantiate a strategy by config name.
pub fn build(ctx: StrategyCtx, strategy: &str, partition: usize) -> Result<Box<dyn Strategy>> {
    let s = strategy.to_ascii_lowercase();
    if let Some(x) = s.strip_prefix("split/") {
        return Ok(Box::new(split::Split::new(ctx, x.parse()?)));
    }
    if let Some(p) = s.strip_prefix("origami/") {
        return Ok(Box::new(origami::Origami::new(ctx, p.parse()?)));
    }
    Ok(match s.as_str() {
        "baseline2" | "baseline" => Box::new(baseline::Baseline2::new(ctx)),
        "slalom" => Box::new(slalom::Slalom::new(ctx)),
        "origami" => Box::new(origami::Origami::new(ctx, partition)),
        "open" | "none" => Box::new(open::OpenInference::new(ctx)),
        other => anyhow::bail!(
            "unknown strategy `{other}` (baseline2|split/N|slalom|origami[/N]|open)"
        ),
    })
}
