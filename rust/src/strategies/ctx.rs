//! Shared strategy context: executor + model + enclave + blinding state,
//! and the layer-walk helpers every strategy composes.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::blinding::pool::{FactorPool, FactorPoolStats, PrefillShape};
use crate::blinding::{self, FactorStream, UnblindStore};
use crate::config::Config;
use crate::enclave::cost::{Cat, CostModel, Ledger};
use crate::enclave::epc::AllocId;
use crate::enclave::Enclave;
use crate::model::{LayerKind, Model};
use crate::runtime::{Device, StageExecutor};
use crate::util::arena::{ArenaStats, TensorArena};
use crate::util::stats::Timer;

/// Everything a strategy needs to run one model privately.
pub struct StrategyCtx {
    pub executor: Arc<StageExecutor>,
    pub model: Arc<Model>,
    pub device: Device,
    pub config: Config,
    /// The simulated enclave (None for the open strategy).
    pub enclave: Option<Enclave>,
    pub factors: Option<FactorStream>,
    /// Shared with the factor-pool prefill workers once the pool starts;
    /// setup-time writes go through `Arc::get_mut` (sole owner until then).
    pub unblind: Option<Arc<UnblindStore>>,
    /// Blinding-factor precompute service (None = inline generation).
    pub factor_pool: Option<FactorPool>,
    /// Param-blob residency handles (EPC accounting), by layer index.
    pub(crate) resident_params: Vec<(usize, AllocId)>,
    /// Size-classed activation-buffer pool: blinded pads, unblinded
    /// outputs and pooled feature maps are recycled through it so the
    /// steady-state walk allocates nothing (fig20 arena leg).
    pub(crate) arena: TensorArena,
    /// Enclave-internal blinding-epoch counter (one per inference).
    epoch_ctr: u64,
}

impl StrategyCtx {
    /// Assemble a context from config (enclave geometry decided by the
    /// strategy via `with_enclave`).
    pub fn new(executor: Arc<StageExecutor>, model: Arc<Model>, config: Config) -> Result<Self> {
        let device = Device::parse(&config.device)?;
        Ok(Self {
            executor,
            model,
            device,
            config,
            enclave: None,
            factors: None,
            unblind: None,
            factor_pool: None,
            resident_params: Vec::new(),
            arena: TensorArena::new(),
            epoch_ctr: 0,
        })
    }

    /// Build the enclave with `declared_bytes` and wire the blinding
    /// subsystems off its key material.
    ///
    /// The blinding stream is derived under the config's `blind_domain`,
    /// which the worker pool sets to the worker index: every worker keeps
    /// the shared deployment master (so any worker can decrypt any
    /// session's ciphertext) but draws its one-time pads from a disjoint
    /// keyspace — two workers can never emit the same pad for the same
    /// (layer, epoch), which would void the OTP across the pool.
    pub fn with_enclave(&mut self, declared_bytes: u64) -> Result<()> {
        let seed = self.config.seed.to_le_bytes();
        let mut enclave = Enclave::create(
            declared_bytes,
            self.config.usable_epc_bytes(),
            &seed,
            self.executor.cost.clone(),
        );
        enclave.set_oblivious(self.config.oblivious);
        let key = enclave.derive_key(&format!(
            "blinding-stream-{}",
            self.config.blind_domain
        ))?;
        let measurement = crate::crypto::sha256(&[&seed[..], self.model.name.as_bytes()].concat());
        self.factors = Some(FactorStream::new(key));
        self.unblind = Some(Arc::new(UnblindStore::new(
            &seed,
            measurement,
            self.config.pool_epochs,
            self.config.allow_factor_reuse,
        )));
        self.enclave = Some(enclave);
        Ok(())
    }

    pub fn enclave_mut(&mut self) -> Result<&mut Enclave> {
        self.enclave
            .as_mut()
            .ok_or_else(|| anyhow!("strategy has no enclave"))
    }

    /// Stage-name helpers (naming convention of python/compile/model.py).
    pub fn lin_open(idx: usize) -> String {
        format!("layer{idx:02}_lin_open")
    }

    pub fn lin_blind(idx: usize) -> String {
        format!("layer{idx:02}_lin_blind")
    }

    pub fn tail(p: usize) -> String {
        format!("tail_p{p:02}")
    }

    /// Declare layer parameters enclave-resident: allocates + writes a
    /// blob of the layer's `params_bytes` through the EPC (residency and
    /// paging accounting; values live in the AOT artifacts).
    pub fn load_params_resident(&mut self, idx: usize, ledger: &mut Ledger) -> Result<()> {
        let bytes = self.model.layer(idx)?.params_bytes as usize;
        if bytes == 0 {
            return Ok(());
        }
        let enclave = self.enclave_mut()?;
        let id = enclave.alloc_bytes(bytes, ledger)?;
        enclave.write_bytes(id, &vec![0u8; bytes], ledger)?;
        self.resident_params.push((idx, id));
        Ok(())
    }

    /// Lazy-load params for one inference step and free them after
    /// (Baseline2's ≥8 MB dense policy). Returns measured load ns.
    pub fn with_lazy_params<R>(
        &mut self,
        idx: usize,
        ledger: &mut Ledger,
        f: impl FnOnce(&mut Self, &mut Ledger) -> Result<R>,
    ) -> Result<R> {
        let bytes = self.model.layer(idx)?.params_bytes as usize;
        let enclave = self.enclave_mut()?;
        let t = Timer::start();
        let id = enclave.alloc_bytes(bytes.max(1), ledger)?;
        enclave.write_bytes(id, &vec![0u8; bytes.max(1)], ledger)?;
        ledger.add_measured(Cat::DataMove, t.elapsed().as_nanos() as u64 / 2);
        let out = f(self, ledger);
        self.enclave_mut()?.free_bytes(id)?;
        out
    }

    // ----------------------------------------------------------------------
    // Layer walks
    // ----------------------------------------------------------------------

    /// Execute layers [from..=to] entirely inside the enclave: linear
    /// parts as TrustedCpu artifacts, non-linear natively, with the
    /// feature map resident in the EPC between layers.
    pub fn enclave_walk(
        &mut self,
        from: usize,
        to: usize,
        mut x: Vec<f32>,
        batch: usize,
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        let model = self.model.clone();
        for idx in from..=to {
            let layer = model.layer(idx)?.clone();
            match layer.kind {
                LayerKind::Conv | LayerKind::Dense => {
                    let lazy = layer.params_bytes >= self.config.lazy_dense_bytes
                        && layer.kind == LayerKind::Dense;
                    // compute reads its weights through the EPC: fault
                    // evicted param pages back in (real decryption)
                    if let Some(&(_, id)) = self
                        .resident_params
                        .iter()
                        .find(|(i, _)| *i == idx)
                    {
                        let bytes = layer.params_bytes as usize;
                        self.enclave_mut()?.touch_bytes(id, bytes, ledger)?;
                    }
                    let stage = Self::lin_open(idx);
                    let run = |ctx: &mut Self, ledger: &mut Ledger| {
                        let out = ctx.executor.run(
                            &model.name,
                            &stage,
                            batch,
                            &[&x],
                            Device::TrustedCpu,
                            ledger,
                        )?;
                        Ok(out.data)
                    };
                    let mut y = if lazy {
                        self.with_lazy_params(idx, ledger, run)?
                    } else {
                        run(self, ledger)?
                    };
                    if layer.has_relu {
                        self.enclave_mut()?.relu(&mut y, ledger);
                    }
                    x = y;
                    // feature map stays enclave-resident between layers
                    self.touch_feature(idx, &x, ledger)?;
                }
                LayerKind::Pool => {
                    let (h, w, c) = spatial(&layer.in_shape)?;
                    x = self
                        .enclave_mut()?
                        .maxpool2x2(&x, batch, h, w, c, ledger);
                }
                LayerKind::Flatten => { /* layout no-op */ }
                LayerKind::Softmax => {
                    let classes = *layer.out_shape.last().unwrap_or(&1);
                    self.enclave_mut()?.softmax(&mut x, classes, ledger);
                }
            }
        }
        Ok(x)
    }

    /// Execute layers [from..=to] Slalom-style: each linear layer's input
    /// is quantize+blinded in the enclave, offloaded to the untrusted
    /// device in the mod-2^24 domain, unblinded with the precomputed
    /// factors, bias-added; non-linear ops run natively in the enclave.
    pub fn blinded_walk(
        &mut self,
        from: usize,
        to: usize,
        mut x: Vec<f32>,
        batch: usize,
        epoch: u64,
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        let model = self.model.clone();
        let device = self.device;
        for idx in from..=to {
            let layer = model.layer(idx)?.clone();
            match layer.kind {
                LayerKind::Conv | LayerKind::Dense => {
                    let n = batch * layer.in_elems();
                    let n_out = batch * layer.out_elems();
                    let epoch = self
                        .unblind
                        .as_ref()
                        .ok_or_else(|| anyhow!("no unblind store"))?
                        .resolve_epoch(epoch)?;
                    // 1. blind inside the enclave.  A warm factor pool
                    //    hands us both the pad and the already-unsealed
                    //    unblinding factors; a cold pool falls back to
                    //    inline generation — bit-identically, since the
                    //    stream is deterministic per (layer, epoch) —
                    //    and counts a `factor_pool_miss`.
                    let staged = self
                        .factor_pool
                        .as_ref()
                        .and_then(|p| p.take(idx, epoch, n, n_out));
                    let (r, staged_ru) = match staged {
                        Some(entry) => (entry.r, Some(entry.ru)),
                        None => (
                            self.factors
                                .as_ref()
                                .ok_or_else(|| anyhow!("no factor stream"))?
                                .factors(idx, epoch, n),
                            None,
                        ),
                    };
                    let mut blinded = self.arena.take(n);
                    blinding::quantize_blind(&x, &r, &mut blinded, ledger);
                    // 2. offload the linear op (OCALL out, OCALL back)
                    self.enclave_mut()?.round_trip(ledger);
                    let out = self.executor.run(
                        &model.name,
                        &Self::lin_blind(idx),
                        batch,
                        &[&blinded],
                        device,
                        ledger,
                    )?;
                    self.arena.give(blinded);
                    // 3. this layer's unblinding factors: staged by the
                    //    prefill service, or fetched + unsealed inline
                    //    (sealed, outside the EPC) — then decode
                    let t = Timer::start();
                    let ru = match staged_ru {
                        Some(ru) if ru.len() == out.data.len() => ru,
                        _ => self
                            .unblind
                            .as_ref()
                            .unwrap()
                            .fetch(idx, epoch, out.data.len())?,
                    };
                    ledger.add_measured(Cat::DataMove, t.elapsed().as_nanos() as u64);
                    let mut y = self.arena.take(out.data.len());
                    blinding::unblind_dequantize(&out.data, &ru, &mut y, ledger);
                    self.arena.give(out.data);
                    // 4. bias + ReLU in the enclave
                    self.enclave_mut()?.bias_add(&mut y, &layer.bias, ledger);
                    if layer.has_relu {
                        self.enclave_mut()?.relu(&mut y, ledger);
                    }
                    Self::check_decodable(idx, &y)?;
                    // recycle the spent input; the output becomes next x
                    self.arena.give(std::mem::replace(&mut x, y));
                }
                LayerKind::Pool => {
                    let (h, w, c) = spatial(&layer.in_shape)?;
                    let pooled = self
                        .enclave_mut()?
                        .maxpool2x2(&x, batch, h, w, c, ledger);
                    self.arena.give(std::mem::replace(&mut x, pooled));
                }
                LayerKind::Flatten => {}
                LayerKind::Softmax => {
                    let classes = *layer.out_shape.last().unwrap_or(&1);
                    self.enclave_mut()?.softmax(&mut x, classes, ledger);
                }
            }
        }
        Ok(x)
    }

    /// Offload layers [p+1..] as one open tail artifact on the device.
    pub fn tail_offload(
        &mut self,
        p: usize,
        feat: &[f32],
        batch: usize,
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        if let Some(enclave) = self.enclave.as_mut() {
            enclave.round_trip(ledger);
        }
        let out = self.executor.run(
            &self.model.name,
            &Self::tail(p),
            batch,
            &[feat],
            self.device,
            ledger,
        )?;
        Ok(out.data)
    }

    /// Precompute + seal the unblinding factors for the given layers and
    /// epochs: R = lin_blind(r) run on the device (setup phase).
    pub fn precompute_unblind_factors(
        &mut self,
        layers: &[usize],
        epochs: u64,
        batch: usize,
    ) -> Result<()> {
        let model = self.model.clone();
        let mut scratch = Ledger::new(); // setup cost, not inference
        for &idx in layers {
            let layer = model.layer(idx)?;
            let n = batch * layer.in_elems();
            for epoch in 0..epochs {
                let r_f32 = self
                    .factors
                    .as_ref()
                    .ok_or_else(|| anyhow!("no factor stream"))?
                    .factors_f32(idx, epoch, n);
                let out = self.executor.run(
                    &model.name,
                    &Self::lin_blind(idx),
                    batch,
                    &[&r_f32],
                    self.device,
                    &mut scratch,
                )?;
                let store = self
                    .unblind
                    .as_mut()
                    .ok_or_else(|| anyhow!("no unblind store"))?;
                Arc::get_mut(store)
                    .ok_or_else(|| {
                        anyhow!(
                            "unblind store is shared — precompute factors \
                             before starting the factor pool"
                        )
                    })?
                    .put(idx, epoch, &out.data)?;
            }
        }
        Ok(())
    }

    /// Start the blinding-factor precompute service for the given linear
    /// layers: `config.factor_prefill_workers` background threads stage
    /// `config.factor_pool_depth` epochs of (pad, unsealed-R) pairs per
    /// layer at batch 1 (batched shapes join the staging set on first
    /// use).  No-op when the configured depth is 0 (inline blinding).
    pub fn start_factor_pool(&mut self, layers: &[usize]) -> Result<()> {
        let depth = self.config.factor_pool_depth;
        if depth == 0 {
            return Ok(());
        }
        let stream = self
            .factors
            .as_ref()
            .ok_or_else(|| anyhow!("no factor stream"))?
            .clone();
        let store = self
            .unblind
            .as_ref()
            .ok_or_else(|| anyhow!("no unblind store"))?
            .clone();
        let mut shapes = Vec::with_capacity(layers.len());
        for &idx in layers {
            let layer = self.model.layer(idx)?;
            shapes.push(PrefillShape {
                layer: idx,
                n_in: layer.in_elems(),
                n_out: layer.out_elems(),
            });
        }
        let pool = FactorPool::start(
            stream,
            store,
            shapes,
            depth,
            self.config.factor_prefill_workers,
        );
        // deterministic warm start: stage the seeded shapes before the
        // first request regardless of worker count
        pool.prefill_now();
        self.factor_pool = Some(pool);
        Ok(())
    }

    /// Cumulative factor-pool counters (None when no pool runs).
    pub fn factor_pool_stats(&self) -> Option<FactorPoolStats> {
        self.factor_pool.as_ref().map(|p| p.stats())
    }

    /// Cumulative feature-map arena counters.
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    /// Decodability gate: a layer output outside the centered mod-2^24
    /// decode window would dequantize to garbage, silently — so it is a
    /// hard checked error in release builds too (was debug_assert-only).
    pub fn check_decodable(idx: usize, y: &[f32]) -> Result<()> {
        match y.iter().find(|v| !(v.abs() < blinding::quant::DECODE_RANGE)) {
            None => Ok(()),
            Some(v) => Err(anyhow!(
                "decodability range violated at layer {idx}: |{v}| >= {} \
                 (quantized output left the mod-2^24 decode window)",
                blinding::quant::DECODE_RANGE
            )),
        }
    }

    /// Decrypt a client request batch inside the enclave (per-sample
    /// session keystreams — see [`Enclave::decrypt_batch`]).
    pub fn decrypt_request(
        &mut self,
        sessions: &[u64],
        batch: usize,
        ciphertext: &[u8],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        self.enclave_mut()?.transition(ledger); // ECALL in
        self.enclave_mut()?.decrypt_batch(sessions, batch, ciphertext, ledger)
    }

    /// Next enclave-internal blinding epoch (monotone per inference).
    pub fn next_epoch(&mut self) -> u64 {
        let e = self.epoch_ctr;
        self.epoch_ctr += 1;
        e
    }

    /// Keep the working feature map resident in the EPC (write-through;
    /// drives Baseline2's data-movement share, Fig 11).
    fn touch_feature(&mut self, idx: usize, x: &[f32], ledger: &mut Ledger) -> Result<()> {
        let name = format!("feat-{idx}");
        let enclave = self.enclave_mut()?;
        enclave.put_tensor(&name, x, ledger)?;
        enclave.drop_tensor(&name)?;
        Ok(())
    }

    /// Cost model passthrough.
    pub fn cost(&self) -> &CostModel {
        &self.executor.cost
    }
}

/// (H, W, C) of an NHWC per-sample shape.
pub fn spatial(shape: &[usize]) -> Result<(usize, usize, usize)> {
    match shape {
        [h, w, c] => Ok((*h, *w, *c)),
        other => Err(anyhow!("expected HWC shape, got {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decodability_gate_accepts_in_range_outputs() {
        let limit = blinding::quant::DECODE_RANGE;
        assert!(StrategyCtx::check_decodable(3, &[0.0, limit - 1.0, 1.0 - limit]).is_ok());
        assert!(StrategyCtx::check_decodable(0, &[]).is_ok());
    }

    #[test]
    fn decodability_gate_rejects_out_of_range_outputs() {
        let limit = blinding::quant::DECODE_RANGE;
        let err = StrategyCtx::check_decodable(3, &[0.0, limit]).unwrap_err();
        assert!(err.to_string().contains("layer 3"), "{err}");
        assert!(StrategyCtx::check_decodable(1, &[-limit - 1.0]).is_err());
        // NaN is not decodable either — must error, not pass silently
        assert!(StrategyCtx::check_decodable(2, &[f32::NAN]).is_err());
    }
}
