//! Enclave memory requirement policy (Table I).
//!
//! SGX requires the enclave size to be declared statically; the paper's
//! Table I reports what each strategy must declare for VGG-16:
//! Baseline2 86 MB, Split/6–10 29–35 MB, Slalom/Origami 39 MB.
//!
//! The requirement decomposes mechanically:
//!   base runtime (SGXDNN code + heap)                     — all
//! + resident parameters (plan-dependent)                  — B2 / Split
//! + lazy-load chunk (largest on-demand dense slice)       — Baseline2
//! + feature working set (largest in+out maps of the
//!   enclave-resident tier)                                — all
//! + blinding-factor buffer (largest blinded map, r + R)   — Slalom/Origami
//!
//! The same policy evaluated on the 224-scale metadata reproduces the
//! paper's numbers to within a few MB (see table1 bench).

use crate::model::partition::{PartitionPlan, Placement};
use crate::model::Model;

/// Fixed base: enclave code, heap, TCS stacks (SGXDNN-era footprint).
pub const BASE_RUNTIME_BYTES_224: u64 = 15 * 1024 * 1024;

/// Decomposed enclave memory requirement.
#[derive(Debug, Clone)]
pub struct MemoryRequirement {
    pub base: u64,
    pub resident_params: u64,
    pub lazy_chunk: u64,
    pub feature_buffers: u64,
    pub blind_buffers: u64,
}

impl MemoryRequirement {
    pub fn total(&self) -> u64 {
        self.base + self.resident_params + self.lazy_chunk + self.feature_buffers
            + self.blind_buffers
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }
}

/// Scale-appropriate base runtime: full size at 224, proportional below.
pub fn base_runtime_bytes(model: &Model) -> u64 {
    if model.image >= 224 {
        BASE_RUNTIME_BYTES_224
    } else {
        // scale by feature-map area ratio (32² vs 224²)
        let ratio = (model.image * model.image) as f64 / (224.0 * 224.0);
        ((BASE_RUNTIME_BYTES_224 as f64) * ratio).max(16.0 * 1024.0) as u64
    }
}

/// Compute the requirement for a (model, plan, lazy bound) triple.
pub fn enclave_requirement(
    model: &Model,
    plan: &PartitionPlan,
    lazy_dense_bytes: u64,
    batch: usize,
) -> MemoryRequirement {
    let base = base_runtime_bytes(model);

    // Parameters resident in the enclave under this plan, except dense
    // layers past the lazy bound (loaded on demand in chunks).
    let mut resident_params = 0u64;
    let mut lazy_chunk = 0u64;
    for l in &model.layers {
        match plan.placement(l.index) {
            Placement::Enclave => {
                if l.kind == crate::model::LayerKind::Dense
                    && l.params_bytes >= lazy_dense_bytes
                {
                    lazy_chunk = lazy_chunk.max(lazy_dense_bytes);
                } else {
                    resident_params += l.params_bytes;
                }
            }
            Placement::BlindedOffload => {
                // bias only
                resident_params += l.out_shape.last().map(|&c| 4 * c as u64).unwrap_or(0);
            }
            Placement::OpenOffload => {}
        }
    }

    // Feature working set: one working buffer sized to the largest
    // feature map among layers that touch the enclave (SGXDNN computes
    // layer-in-place with a single ping buffer).
    let feature_buffers = model
        .layers
        .iter()
        .filter(|l| plan.placement(l.index) != Placement::OpenOffload)
        .map(|l| l.out_bytes(batch).max(l.in_bytes(batch)))
        .max()
        .unwrap_or(0);

    // Blinding-factor buffer: r for the largest blinded input (the
    // paper's "12MB of which are used to temporarily store
    // blinding/unblinding factors"; R streams in per layer from the
    // sealed store and reuses the working buffer).
    let blind_buffers = model
        .layers
        .iter()
        .filter(|l| plan.placement(l.index) == Placement::BlindedOffload)
        .map(|l| l.in_bytes(batch))
        .max()
        .unwrap_or(0);

    MemoryRequirement {
        base,
        resident_params,
        lazy_chunk,
        feature_buffers,
        blind_buffers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Layer, LayerKind};

    fn model_224ish() -> Model {
        // Miniature stand-in with paper-like proportions.
        let conv = |i: usize, pb: u64, elems: usize| Layer {
            index: i,
            kind: LayerKind::Conv,
            name: format!("conv{i}"),
            in_shape: vec![elems, 1, 1],
            out_shape: vec![elems, 1, 1],
            has_relu: true,
            flops: 0,
            params_bytes: pb,
            bias: vec![],
        };
        let dense = |i: usize, pb: u64| Layer {
            index: i,
            kind: LayerKind::Dense,
            name: format!("dense{i}"),
            in_shape: vec![1000],
            out_shape: vec![1000],
            has_relu: false,
            flops: 0,
            params_bytes: pb,
            bias: vec![],
        };
        Model {
            name: "t".into(),
            image: 224,
            in_channels: 3,
            layers: vec![
                conv(1, 10 << 20, 3_000_000),
                conv(2, 40 << 20, 1_500_000),
                dense(3, 400 << 20),
            ],
            partitions: vec![1],
            stages: vec![],
        }
    }

    #[test]
    fn baseline_includes_conv_params_and_lazy_chunk() {
        let m = model_224ish();
        let plan = PartitionPlan::baseline(&m);
        let r = enclave_requirement(&m, &plan, 8 << 20, 1);
        assert_eq!(r.resident_params, 50 << 20);
        assert_eq!(r.lazy_chunk, 8 << 20);
        assert_eq!(r.blind_buffers, 0);
        assert!(r.total() > 70 << 20);
    }

    #[test]
    fn slalom_has_blind_buffers_but_bias_only_params() {
        let m = model_224ish();
        let plan = PartitionPlan::slalom(&m);
        let r = enclave_requirement(&m, &plan, 8 << 20, 1);
        assert!(r.resident_params < 1 << 20);
        assert_eq!(r.lazy_chunk, 0);
        assert!(r.blind_buffers > 0);
    }

    #[test]
    fn split_sheds_offloaded_tier() {
        let m = model_224ish();
        let full = enclave_requirement(&m, &PartitionPlan::baseline(&m), 8 << 20, 1);
        let split = enclave_requirement(&m, &PartitionPlan::split(&m, 1), 8 << 20, 1);
        assert!(split.total() < full.total());
        assert_eq!(split.resident_params, 10 << 20);
    }

    #[test]
    fn small_scale_base_is_proportional() {
        let mut m = model_224ish();
        m.image = 32;
        assert!(base_runtime_bytes(&m) < BASE_RUNTIME_BYTES_224 / 10);
    }
}
