//! Slalom/Privacy: every linear layer offloaded under cryptographic
//! blinding; every non-linear op inside the enclave (Tramèr & Boneh,
//! reproduced as the paper's strongest prior-work baseline).
//!
//! The cost structure the paper dissects (§VI-C.2): per linear layer, a
//! blind pass + an unblind pass over the full feature map — ~4 ms per
//! 6 MB on their Xeon — which is what Origami later eliminates for the
//! deep tier.

use anyhow::Result;

use super::ctx::StrategyCtx;
use super::memory::enclave_requirement;
use super::Strategy;
use crate::enclave::cost::Ledger;
use crate::enclave::power::power_cycle;
use crate::model::partition::PartitionPlan;

/// Blinded offload for the whole network.
pub struct Slalom {
    ctx: StrategyCtx,
    requirement: u64,
    skipped_batches: Vec<usize>,
}

impl Slalom {
    pub fn new(ctx: StrategyCtx) -> Self {
        Self {
            ctx,
            requirement: 0,
            skipped_batches: Vec::new(),
        }
    }

    /// Serving batch sizes skipped at setup because the batched
    /// `lin_blind` stage is not exported (see `Origami::skipped_batches`).
    pub fn skipped_batches(&self) -> &[usize] {
        &self.skipped_batches
    }
}

impl Strategy for Slalom {
    fn name(&self) -> String {
        "slalom".into()
    }

    fn setup(&mut self) -> Result<()> {
        let model = self.ctx.model.clone();
        let plan = PartitionPlan::slalom(&model);
        let req = enclave_requirement(&model, &plan, self.ctx.config.lazy_dense_bytes, 1);
        self.requirement = req.total();
        self.ctx.with_enclave(self.requirement)?;
        // Precompute + seal unblinding factors for every linear layer
        // (paper: "Unblinding factors are pre-computed and are not part
        // of the inference time").
        let layers = model.linear_indices();
        let epochs = self.ctx.config.pool_epochs;
        self.ctx.precompute_unblind_factors(&layers, epochs, 1)?;
        // batched artifacts share the per-sample factors? No — each
        // batch size has its own artifact; precompute every size the
        // scheduler can pick.  A size whose stage is not exported is
        // recorded and skipped; real precompute failures propagate
        // rather than degrading into serve-time fetch misses.
        self.skipped_batches.clear();
        for b in model.serving_batches() {
            if b <= 1 {
                continue;
            }
            let exported = layers
                .iter()
                .all(|&i| model.stage(&StrategyCtx::lin_blind(i), b).is_ok());
            if exported {
                self.ctx.precompute_unblind_factors(&layers, epochs, b)?;
            } else {
                self.skipped_batches.push(b);
            }
        }
        self.ctx.start_factor_pool(&layers)?;
        Ok(())
    }

    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        let x = self.ctx.decrypt_request(sessions, batch, ciphertext, ledger)?;
        let epoch = self.ctx.next_epoch();
        let n = self.ctx.model.num_layers();
        self.ctx.blinded_walk(1, n, x, batch, epoch, ledger)
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        self.requirement
    }

    fn factor_pool_stats(&self) -> Option<crate::blinding::FactorPoolStats> {
        self.ctx.factor_pool_stats()
    }

    fn arena_stats(&self) -> Option<crate::util::arena::ArenaStats> {
        Some(self.ctx.arena_stats())
    }

    fn power_cycle(&mut self) -> Result<f64> {
        // Slalom keeps only biases + factor buffers in the enclave; the
        // sealed unblinding factors survive outside and only the enclave
        // itself must be rebuilt.
        let mut ledger = Ledger::new();
        let enclave = self.ctx.enclave_mut()?;
        enclave.power_event();
        Ok(power_cycle(enclave, &[], &mut ledger).rebuild_ms)
    }
}

// NOTE on batched factors: factors are generated per (layer, epoch) for
// `batch * in_elems` elements, so a batch-8 request simply consumes an
// 8x longer stream — `precompute_unblind_factors(layers, epochs, 8)`
// stores the matching R under the same (layer, epoch) namespacing as the
// batch-1 pool because the artifact output length disambiguates them.
// The integration tests cover both paths.
