//! Split/x: layers 1..=x inside the enclave, the rest offloaded to the
//! untrusted device in the open (paper §III-B, "Key Idea 1").
//!
//! Privacy rests on the partition point alone — the offloaded tail sees
//! the layer-x feature map in plaintext, so x must be at or past the
//! layer where the c-GAN adversary fails (x ≥ 6 for VGG-16, Fig 8).

use anyhow::Result;

use super::ctx::StrategyCtx;
use super::memory::enclave_requirement;
use super::{Strategy, Tier1Output};
use crate::enclave::cost::Ledger;
use crate::enclave::power::power_cycle;
use crate::model::partition::PartitionPlan;

/// Enclave head + open offloaded tail.
pub struct Split {
    ctx: StrategyCtx,
    x: usize,
    requirement: u64,
}

impl Split {
    pub fn new(ctx: StrategyCtx, x: usize) -> Self {
        Self {
            ctx,
            x,
            requirement: 0,
        }
    }
}

impl Strategy for Split {
    fn name(&self) -> String {
        format!("split/{}", self.x)
    }

    fn setup(&mut self) -> Result<()> {
        let model = self.ctx.model.clone();
        anyhow::ensure!(
            self.x < model.num_layers(),
            "split point {} out of range",
            self.x
        );
        // the tail artifact must exist for this partition
        let _ = model.stage(&StrategyCtx::tail(self.x), self.ctx.config.max_batch.max(1))
            .or_else(|_| model.stage(&StrategyCtx::tail(self.x), 1))?;
        let plan = PartitionPlan::split(&model, self.x);
        let req = enclave_requirement(&model, &plan, self.ctx.config.lazy_dense_bytes, 1);
        self.requirement = req.total();
        self.ctx.with_enclave(self.requirement)?;
        let mut setup_ledger = Ledger::new();
        for idx in model.linear_indices().into_iter().filter(|&i| i <= self.x) {
            self.ctx.load_params_resident(idx, &mut setup_ledger)?;
        }
        Ok(())
    }

    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        match self.infer_tier1(ciphertext, batch, sessions, ledger)? {
            Tier1Output::Final(probs) => Ok(probs),
            Tier1Output::Handoff { features, stage } => {
                let out = self.ctx.executor.run(
                    &self.ctx.model.name,
                    &stage,
                    batch,
                    &[&features],
                    self.ctx.device,
                    ledger,
                )?;
                Ok(out.data)
            }
        }
    }

    fn infer_tier1(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Tier1Output> {
        let x0 = self.ctx.decrypt_request(sessions, batch, ciphertext, ledger)?;
        let features = self.ctx.enclave_walk(1, self.x, x0, batch, ledger)?;
        self.ctx.enclave_mut()?.round_trip(ledger);
        Ok(Tier1Output::Handoff {
            features,
            stage: StrategyCtx::tail(self.x),
        })
    }

    fn tiered(&self) -> bool {
        true
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        self.requirement
    }

    fn power_cycle(&mut self) -> Result<f64> {
        let model = self.ctx.model.clone();
        let x = self.x;
        let mut ledger = Ledger::new();
        self.ctx.resident_params.clear();
        let enclave = self.ctx.enclave_mut()?;
        enclave.power_event();
        let rebuild_ms = power_cycle(enclave, &[], &mut ledger).rebuild_ms;
        let t = crate::util::stats::Timer::start();
        for idx in model.linear_indices().into_iter().filter(|&i| i <= x) {
            self.ctx.load_params_resident(idx, &mut ledger)?;
        }
        Ok(rebuild_ms + t.elapsed_ms())
    }
}
