//! Origami(p): the paper's contribution.  Tier 1 (layers 1..=p) runs
//! Slalom-style — linear parts blinded-offloaded, non-linear in the
//! enclave; tier 2 (layers p+1..) runs *entirely in the open* on the
//! untrusted device as one fused artifact, because past layer p the
//! c-GAN adversary can no longer reconstruct the input (Fig 8).
//!
//! This eliminates Slalom's per-layer blind/unblind for the deep tier —
//! the ~47-51 MB of intermediate encoding traffic that caps Slalom at
//! 10-11x — and lifts the speedup to 12.7x/15.1x (Fig 9).

use anyhow::Result;

use super::ctx::StrategyCtx;
use super::memory::enclave_requirement;
use super::{Strategy, Tier1Output};
use crate::enclave::cost::Ledger;
use crate::enclave::power::power_cycle;
use crate::model::partition::PartitionPlan;

/// Blinded tier-1 + open tier-2.
pub struct Origami {
    ctx: StrategyCtx,
    p: usize,
    requirement: u64,
    skipped_batches: Vec<usize>,
}

impl Origami {
    pub fn new(ctx: StrategyCtx, p: usize) -> Self {
        Self {
            ctx,
            p,
            requirement: 0,
            skipped_batches: Vec::new(),
        }
    }

    /// The partition point in use.
    pub fn partition(&self) -> usize {
        self.p
    }

    /// Serving batch sizes whose unblinding factors were *not*
    /// precomputed at setup because the model does not export the
    /// batched `lin_blind` stage (requests at these sizes fetch-miss
    /// and run inline).  Genuine precompute failures propagate from
    /// `setup` instead of landing here.
    pub fn skipped_batches(&self) -> &[usize] {
        &self.skipped_batches
    }
}

impl Strategy for Origami {
    fn name(&self) -> String {
        format!("origami/{}", self.p)
    }

    fn setup(&mut self) -> Result<()> {
        let model = self.ctx.model.clone();
        anyhow::ensure!(
            self.p < model.num_layers(),
            "partition {} out of range",
            self.p
        );
        let _ = model
            .stage(&StrategyCtx::tail(self.p), 1)
            .map_err(|e| anyhow::anyhow!("origami needs tail_p{:02} artifact: {e}", self.p))?;
        let plan = PartitionPlan::origami(&model, self.p);
        let req = enclave_requirement(&model, &plan, self.ctx.config.lazy_dense_bytes, 1);
        self.requirement = req.total();
        self.ctx.with_enclave(self.requirement)?;
        // unblinding factors only for tier-1 linear layers
        let layers: Vec<usize> = model
            .linear_indices()
            .into_iter()
            .filter(|&i| i <= self.p)
            .collect();
        let epochs = self.ctx.config.pool_epochs;
        // Precompute for every batch size the scheduler can pick (the
        // exported serving set), batch 1 mandatory.  A batched stage the
        // model does not export is a *skip* (recorded below); anything
        // else — seal failures, artifact shape mismatches — is a genuine
        // error and propagates instead of resurfacing at serve time as a
        // hot-path fetch miss.
        self.ctx.precompute_unblind_factors(&layers, epochs, 1)?;
        self.skipped_batches.clear();
        for b in model.serving_batches() {
            if b <= 1 {
                continue;
            }
            let exported = layers
                .iter()
                .all(|&i| model.stage(&StrategyCtx::lin_blind(i), b).is_ok());
            if exported {
                self.ctx.precompute_unblind_factors(&layers, epochs, b)?;
            } else {
                self.skipped_batches.push(b);
            }
        }
        // With all R sealed, start the blinding-factor prefill service
        // (no-op at factor_pool_depth = 0).
        self.ctx.start_factor_pool(&layers)?;
        Ok(())
    }

    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        // The serial path is exactly tier-1 followed by the open tail on
        // this worker's own executor, so the pipelined pool path (tier-2
        // finished by a peer lane) is bit-identical by construction.
        match self.infer_tier1(ciphertext, batch, sessions, ledger)? {
            Tier1Output::Final(probs) => Ok(probs),
            Tier1Output::Handoff { features, stage } => {
                let out = self.ctx.executor.run(
                    &self.ctx.model.name,
                    &stage,
                    batch,
                    &[&features],
                    self.ctx.device,
                    ledger,
                )?;
                // The tail consumed the feature map; recycle it so the
                // steady-state serve loop allocates nothing per request.
                self.ctx.arena.give(features);
                Ok(out.data)
            }
        }
    }

    fn infer_tier1(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Tier1Output> {
        let x = self.ctx.decrypt_request(sessions, batch, ciphertext, ledger)?;
        let epoch = self.ctx.next_epoch();
        // Tier 1: Slalom-style blinded execution through layer p.
        let features = self
            .ctx
            .blinded_walk(1, self.p, x, batch, epoch, ledger)?;
        // The OCALL pair that ships the feature map out belongs to tier-1
        // (it is the enclave's last act for this request).
        self.ctx.enclave_mut()?.round_trip(ledger);
        Ok(Tier1Output::Handoff {
            features,
            stage: StrategyCtx::tail(self.p),
        })
    }

    fn tiered(&self) -> bool {
        true
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        self.requirement
    }

    fn factor_pool_stats(&self) -> Option<crate::blinding::FactorPoolStats> {
        self.ctx.factor_pool_stats()
    }

    fn arena_stats(&self) -> Option<crate::util::arena::ArenaStats> {
        Some(self.ctx.arena_stats())
    }

    fn power_cycle(&mut self) -> Result<f64> {
        // Same profile as Slalom: nothing heavy to reload (factors are
        // sealed outside; weights live in the artifacts).
        let mut ledger = Ledger::new();
        let enclave = self.ctx.enclave_mut()?;
        enclave.power_event();
        Ok(power_cycle(enclave, &[], &mut ledger).rebuild_ms)
    }
}
