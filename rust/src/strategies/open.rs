//! Non-private reference: the whole model on the untrusted device, no
//! enclave, no blinding — the "fast hardware without any privacy
//! guarantees" baseline of Figs 12/13.

use anyhow::Result;

use super::ctx::StrategyCtx;
use super::Strategy;
use crate::enclave::cost::Ledger;

/// Plain full-model inference on the configured device.
pub struct OpenInference {
    ctx: StrategyCtx,
}

impl OpenInference {
    pub fn new(ctx: StrategyCtx) -> Self {
        Self { ctx }
    }
}

impl Strategy for OpenInference {
    fn name(&self) -> String {
        format!("open/{}", self.ctx.device.name())
    }

    fn setup(&mut self) -> Result<()> {
        // warm the full-model artifact (no-op on the reference backend)
        self.ctx
            .executor
            .warm(&self.ctx.model.name, &[("full_open", 1)])?;
        Ok(())
    }

    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        // No enclave: the "ciphertext" is decoded outside any trust
        // boundary (the client's data is exposed — that is the point of
        // this baseline). Same per-sample session keystreams as the
        // enclave path so callers can reuse one encryption helper.
        anyhow::ensure!(batch > 0 && ciphertext.len() % batch == 0, "bad batch");
        let sample_bytes = ciphertext.len() / batch;
        let mut x = Vec::with_capacity(ciphertext.len() / 4);
        for (i, chunk) in ciphertext.chunks_exact(sample_bytes).enumerate() {
            let session = sessions.get(i).copied().unwrap_or(0);
            let key = crate::crypto::derive_aes_key(
                &self.ctx.config.seed.to_le_bytes(),
                &format!("session-{session}"),
            );
            let mut plain = chunk.to_vec();
            crate::crypto::AesCtr::new(&key, session).apply(0, &mut plain);
            x.extend(
                plain
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap())),
            );
        }
        let out = self.ctx.executor.run(
            &self.ctx.model.name,
            "full_open",
            batch,
            &[&x],
            self.ctx.device,
            ledger,
        )?;
        Ok(out.data)
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        0
    }
}
