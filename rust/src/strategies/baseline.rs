//! Baseline2: the whole model inside the enclave, with lazy on-demand
//! loading of large dense layers (the paper's primary baseline; §VI-C:
//! "performs lazy loading of model parameters into SGX when loading
//! fully connected layers that require more than 8MB memory").

use anyhow::Result;

use super::ctx::StrategyCtx;
use super::memory::enclave_requirement;
use super::Strategy;
use crate::enclave::cost::Ledger;
use crate::enclave::power::power_cycle;
use crate::model::partition::PartitionPlan;
use crate::model::LayerKind;

/// Full-enclave execution with lazy dense loading.
pub struct Baseline2 {
    ctx: StrategyCtx,
    requirement: u64,
}

impl Baseline2 {
    pub fn new(ctx: StrategyCtx) -> Self {
        Self {
            ctx,
            requirement: 0,
        }
    }
}

impl Strategy for Baseline2 {
    fn name(&self) -> String {
        "baseline2".into()
    }

    fn setup(&mut self) -> Result<()> {
        let model = self.ctx.model.clone();
        let plan = PartitionPlan::baseline(&model);
        let req = enclave_requirement(&model, &plan, self.ctx.config.lazy_dense_bytes, 1);
        self.requirement = req.total();
        self.ctx.with_enclave(self.requirement)?;
        // Pre-load everything except lazy dense layers.
        let mut setup_ledger = Ledger::new();
        for idx in model.linear_indices() {
            let layer = model.layer(idx)?;
            let lazy = layer.kind == LayerKind::Dense
                && layer.params_bytes >= self.ctx.config.lazy_dense_bytes;
            if !lazy {
                self.ctx.load_params_resident(idx, &mut setup_ledger)?;
            }
        }
        Ok(())
    }

    fn infer(
        &mut self,
        ciphertext: &[u8],
        batch: usize,
        sessions: &[u64],
        ledger: &mut Ledger,
    ) -> Result<Vec<f32>> {
        let x = self.ctx.decrypt_request(sessions, batch, ciphertext, ledger)?;
        let n = self.ctx.model.num_layers();
        self.ctx.enclave_walk(1, n, x, batch, ledger)
    }

    fn enclave_requirement_bytes(&self) -> u64 {
        self.requirement
    }

    fn power_cycle(&mut self) -> Result<f64> {
        let model = self.ctx.model.clone();
        let lazy_bound = self.ctx.config.lazy_dense_bytes;
        // Rebuild the enclave, then re-establish parameter residency: the
        // reload is proportional to the preloaded (non-lazy) params —
        // exactly why Baseline2 recovers slowest (Table II).
        let mut ledger = Ledger::new();
        self.ctx.resident_params.clear();
        let enclave = self.ctx.enclave_mut()?;
        enclave.power_event();
        let rebuild_ms = {
            let report = power_cycle(enclave, &[], &mut ledger);
            report.rebuild_ms
        };
        let t = crate::util::stats::Timer::start();
        for idx in model.linear_indices() {
            let layer = model.layer(idx)?;
            let lazy =
                layer.kind == LayerKind::Dense && layer.params_bytes >= lazy_bound;
            if !lazy {
                self.ctx.load_params_resident(idx, &mut ledger)?;
            }
        }
        Ok(rebuild_ms + t.elapsed_ms())
    }
}
