//! Enclave cryptography: AES-128-CTR page/stream cipher, HMAC-SHA256
//! MACs, and HKDF-style key derivation — built on the RustCrypto block
//! primitives (`aes`, `sha2`, `hmac`).
//!
//! The enclave simulator uses these for *real work*, not costume: EPC
//! pages evicted past the protected-memory limit are genuinely encrypted
//! and MACed (that cost is what drives the paper's Fig 2/11 slowdowns),
//! sealed state is genuinely wrapped, and attestation reports genuinely
//! MACed. Confidentiality against a real adversary is NOT claimed — a
//! simulator shares its address space — but the arithmetic and byte
//! traffic match the mechanism being modeled.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type HmacSha256 = Hmac<Sha256>;

/// 128-bit AES-CTR stream cipher (the EPC page cipher).
///
/// CTR mode: keystream block i = AES_k(nonce || counter+i); XOR in place.
/// Encryption and decryption are the same operation.
pub struct AesCtr {
    cipher: Aes128,
    nonce: u64,
}

impl AesCtr {
    pub fn new(key: &[u8; 16], nonce: u64) -> Self {
        Self {
            cipher: Aes128::new(key.into()),
            nonce,
        }
    }

    /// XOR `data` with the keystream starting at block `start_block`.
    pub fn apply(&self, start_block: u64, data: &mut [u8]) {
        let mut block_idx = start_block;
        for chunk in data.chunks_mut(16) {
            let mut block = [0u8; 16];
            block[..8].copy_from_slice(&self.nonce.to_le_bytes());
            block[8..].copy_from_slice(&block_idx.to_le_bytes());
            let mut b = block.into();
            self.cipher.encrypt_block(&mut b);
            for (d, k) in chunk.iter_mut().zip(b.iter()) {
                *d ^= k;
            }
            block_idx = block_idx.wrapping_add(1);
        }
    }
}

/// Low 48 bits of a session word: the session id proper.  The high 16
/// bits carry the keystream epoch (see [`session_word`]).
pub const SESSION_ID_MASK: u64 = 0x0000_FFFF_FFFF_FFFF;

/// Fold a keystream epoch into a session id.
///
/// The per-session AES-CTR nonce (and the per-session key derivation
/// purpose string) are built from this word, NOT the bare id: a bare-id
/// nonce replays the identical keystream whenever an id is reused after
/// expiry or kept across a refresh — XORing two ciphertexts under the
/// same keystream leaks their plaintext difference.  Mixing the epoch
/// into the high 16 bits gives every (session, epoch) pair a distinct
/// nonce while keeping epoch 0 bit-identical to the legacy bare id for
/// every id below 2^48 (which is why the session table only issues ids
/// inside [`SESSION_ID_MASK`]).  The epoch wraps at 2^16 refreshes; the
/// session TTL retires ids long before that.
pub fn session_word(session: u64, epoch: u32) -> u64 {
    ((epoch as u64 & 0xFFFF) << 48) | (session & SESSION_ID_MASK)
}

/// HMAC-SHA256 tag.
pub fn hmac_sha256(key: &[u8], data: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(key).expect("hmac key");
    mac.update(data);
    mac.finalize().into_bytes().into()
}

/// Constant-time tag comparison.
pub fn verify_hmac(key: &[u8], data: &[u8], tag: &[u8; 32]) -> bool {
    use subtle::ConstantTimeEq;
    hmac_sha256(key, data).ct_eq(tag).into()
}

/// SHA-256 digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize().into()
}

/// Simple HKDF-like derivation: key material for a named purpose.
/// (HKDF-Extract+Expand with a fixed salt; one output block is enough for
/// our 16/32-byte keys.)
pub fn derive_key(master: &[u8], purpose: &str) -> [u8; 32] {
    let prk = hmac_sha256(b"origami-hkdf-salt-v1", master);
    let mut info = purpose.as_bytes().to_vec();
    info.push(0x01);
    hmac_sha256(&prk, &info)
}

/// Derive a 16-byte AES key for a purpose.
pub fn derive_aes_key(master: &[u8], purpose: &str) -> [u8; 16] {
    derive_key(master, purpose)[..16].try_into().unwrap()
}

/// Authenticated encryption of a buffer: CTR encrypt + HMAC over
/// nonce||ciphertext (encrypt-then-MAC). Returns ciphertext||tag.
pub fn seal(key_enc: &[u8; 16], key_mac: &[u8; 32], nonce: u64, plain: &[u8]) -> Vec<u8> {
    let mut out = plain.to_vec();
    AesCtr::new(key_enc, nonce).apply(0, &mut out);
    let mut mac_input = nonce.to_le_bytes().to_vec();
    mac_input.extend_from_slice(&out);
    let tag = hmac_sha256(key_mac, &mac_input);
    out.extend_from_slice(&tag);
    out
}

/// Open a sealed buffer; None on MAC failure.
pub fn open(key_enc: &[u8; 16], key_mac: &[u8; 32], nonce: u64, sealed: &[u8]) -> Option<Vec<u8>> {
    if sealed.len() < 32 {
        return None;
    }
    let (ct, tag_bytes) = sealed.split_at(sealed.len() - 32);
    let tag: [u8; 32] = tag_bytes.try_into().ok()?;
    let mut mac_input = nonce.to_le_bytes().to_vec();
    mac_input.extend_from_slice(ct);
    if !verify_hmac(key_mac, &mac_input, &tag) {
        return None;
    }
    let mut plain = ct.to_vec();
    AesCtr::new(key_enc, nonce).apply(0, &mut plain);
    Some(plain)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctr_roundtrip_and_randomization() {
        let key = [7u8; 16];
        let ctr = AesCtr::new(&key, 99);
        let plain = b"the quick brown fox jumps over the lazy dog".to_vec();
        let mut data = plain.clone();
        ctr.apply(0, &mut data);
        assert_ne!(data, plain);
        ctr.apply(0, &mut data);
        assert_eq!(data, plain);
    }

    #[test]
    fn ctr_is_random_access() {
        let key = [1u8; 16];
        let ctr = AesCtr::new(&key, 5);
        let mut all = vec![0u8; 64];
        ctr.apply(0, &mut all);
        // blocks 2..4 encrypted standalone match the same byte range
        let mut tail = vec![0u8; 32];
        ctr.apply(2, &mut tail);
        assert_eq!(&tail, &all[32..64]);
    }

    #[test]
    fn different_nonces_differ() {
        let key = [2u8; 16];
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        AesCtr::new(&key, 1).apply(0, &mut a);
        AesCtr::new(&key, 2).apply(0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn session_word_epoch_zero_is_the_bare_id() {
        assert_eq!(session_word(12345, 0), 12345);
        assert_eq!(session_word(SESSION_ID_MASK, 0), SESSION_ID_MASK);
    }

    #[test]
    fn session_word_epochs_yield_distinct_keystreams() {
        let key = [9u8; 16];
        let mut e0 = vec![0u8; 32];
        let mut e1 = vec![0u8; 32];
        AesCtr::new(&key, session_word(77, 0)).apply(0, &mut e0);
        AesCtr::new(&key, session_word(77, 1)).apply(0, &mut e1);
        assert_ne!(e0, e1, "epoch bump must retire the old keystream");
        // distinct sessions stay distinct within an epoch too
        let mut other = vec![0u8; 32];
        AesCtr::new(&key, session_word(78, 1)).apply(0, &mut other);
        assert_ne!(e1, other);
    }

    #[test]
    fn session_word_is_injective_over_masked_ids() {
        assert_ne!(session_word(1, 0), session_word(1, 1));
        assert_eq!(session_word(1, 0x1_0000), session_word(1, 0), "epoch wraps at 2^16");
        // id bits above the mask are dropped — the table never issues them
        assert_eq!(session_word(1 | (1 << 48), 0), session_word(1, 0));
    }

    #[test]
    fn hmac_verifies_and_rejects() {
        let tag = hmac_sha256(b"key", b"hello");
        assert!(verify_hmac(b"key", b"hello", &tag));
        assert!(!verify_hmac(b"key", b"hellp", &tag));
        assert!(!verify_hmac(b"kez", b"hello", &tag));
    }

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        let d = sha256(b"abc");
        assert_eq!(
            &d[..4],
            &[0xba, 0x78, 0x16, 0xbf],
        );
    }

    #[test]
    fn derive_key_separates_purposes() {
        let a = derive_key(b"master", "epc");
        let b = derive_key(b"master", "seal");
        assert_ne!(a, b);
        assert_eq!(a, derive_key(b"master", "epc"));
    }

    #[test]
    fn seal_open_roundtrip_and_tamper() {
        let ke = derive_aes_key(b"m", "enc");
        let km = derive_key(b"m", "mac");
        let sealed = seal(&ke, &km, 3, b"secret weights");
        assert_eq!(
            open(&ke, &km, 3, &sealed).unwrap(),
            b"secret weights".to_vec()
        );
        let mut bad = sealed.clone();
        bad[0] ^= 1;
        assert!(open(&ke, &km, 3, &bad).is_none());
        // wrong nonce fails the MAC
        assert!(open(&ke, &km, 4, &sealed).is_none());
    }
}
