//! Configuration system: JSON config file + CLI overrides.
//!
//! Everything the launcher needs to assemble a serving stack: model,
//! strategy, offload device, enclave geometry, blinding pool, batching
//! policy.  `Config::default()` is the 32-scale CI profile; the paper-
//! scale geometry (128 MB EPC etc.) is `Config::paper_scale()`.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::cli::Args;
use crate::util::json::{self, Value};

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifacts directory (manifest + HLO files).
    pub artifacts: PathBuf,
    /// Model name in the manifest.
    pub model: String,
    /// Strategy: baseline2 | split/N | slalom | origami[/N] | open.
    pub strategy: String,
    /// Offload device: cpu | gpu.
    pub device: String,
    /// Enclave protected-memory capacity (bytes).
    pub epc_bytes: u64,
    /// Enclave master seed (determinism).
    pub seed: u64,
    /// Origami partition point (layer index, paper numbering).
    pub partition: usize,
    /// Precomputed unblinding-factor epochs.
    pub pool_epochs: u64,
    /// Allow factor-pool cycling (bench mode only).
    pub allow_factor_reuse: bool,
    /// Dynamic batcher: max batch size (must be an exported batch).
    pub max_batch: usize,
    /// Dynamic batcher: max queueing delay in ms.
    pub max_delay_ms: f64,
    /// Server worker threads.
    pub workers: usize,
    /// Lazy-load dense layers above this many bytes (Baseline2 policy;
    /// the paper uses 8 MB).
    pub lazy_dense_bytes: u64,
    /// Worker pool: overlap tier-1 (enclave) of batch k+1 with tier-2
    /// (open device) of batch k inside every worker.
    pub pipeline: bool,
    /// Blinding-keyspace domain for this strategy instance.  The worker
    /// pool assigns each worker its index so pad streams are disjoint
    /// across workers; single-instance deployments leave it at 0.
    pub blind_domain: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            artifacts: crate::model::Manifest::default_root(),
            model: "vgg16-32".into(),
            strategy: "origami".into(),
            device: "cpu".into(),
            // 32-scale default: EPC scaled so model-vs-EPC pressure is
            // paper-like (see DESIGN.md §2). vgg16-32 params ≈ 0.13 MB.
            epc_bytes: 256 * 1024,
            seed: 2019,
            partition: 6,
            pool_epochs: 64,
            allow_factor_reuse: true,
            max_batch: 8,
            max_delay_ms: 2.0,
            workers: 2,
            lazy_dense_bytes: 16 * 1024,
            pipeline: true,
            blind_domain: 0,
        }
    }
}

impl Config {
    /// Paper-scale geometry (224 models, 128 MB EPC, 8 MB lazy bound).
    pub fn paper_scale() -> Self {
        Self {
            model: "vgg16".into(),
            epc_bytes: 128 * 1024 * 1024,
            lazy_dense_bytes: 8 * 1024 * 1024,
            ..Self::default()
        }
    }

    /// Usable EPC after SGX metadata overhead (~93 of 128 MB; same ratio
    /// applied at every scale).
    pub fn usable_epc_bytes(&self) -> u64 {
        (self.epc_bytes as f64 * 0.727) as u64
    }

    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let v = json::from_file(path)?;
        let mut c = Self::default();
        c.apply_json(&v);
        Ok(c)
    }

    fn apply_json(&mut self, v: &Value) {
        if let Some(s) = v.get("artifacts").and_then(|x| x.as_str()) {
            self.artifacts = PathBuf::from(s);
        }
        for (field, slot) in [
            ("model", &mut self.model),
            ("strategy", &mut self.strategy),
            ("device", &mut self.device),
        ] {
            if let Some(s) = v.get(field).and_then(|x| x.as_str()) {
                *slot = s.to_string();
            }
        }
        for (field, slot) in [
            ("epc_bytes", &mut self.epc_bytes),
            ("seed", &mut self.seed),
            ("pool_epochs", &mut self.pool_epochs),
            ("lazy_dense_bytes", &mut self.lazy_dense_bytes),
        ] {
            if let Some(n) = v.get(field).and_then(|x| x.as_i64()) {
                *slot = n as u64;
            }
        }
        for (field, slot) in [
            ("partition", &mut self.partition),
            ("max_batch", &mut self.max_batch),
            ("workers", &mut self.workers),
        ] {
            if let Some(n) = v.get(field).and_then(|x| x.as_usize()) {
                *slot = n;
            }
        }
        if let Some(n) = v.get("max_delay_ms").and_then(|x| x.as_f64()) {
            self.max_delay_ms = n;
        }
        if let Some(b) = v.get("allow_factor_reuse").and_then(|x| x.as_bool()) {
            self.allow_factor_reuse = b;
        }
        if let Some(b) = v.get("pipeline").and_then(|x| x.as_bool()) {
            self.pipeline = b;
        }
        if let Some(n) = v.get("blind_domain").and_then(|x| x.as_i64()) {
            self.blind_domain = n as u64;
        }
    }

    /// Apply CLI overrides (`--model`, `--device`, …; `--config` first).
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut c = match args.get("config") {
            Some(path) => Self::from_file(Path::new(path))?,
            None => Self::default(),
        };
        if args.has("paper-scale") {
            c = Self {
                artifacts: c.artifacts.clone(),
                ..Self::paper_scale()
            };
        }
        if let Some(v) = args.get("artifacts") {
            c.artifacts = PathBuf::from(v);
        }
        if let Some(v) = args.get("model") {
            c.model = v.into();
        }
        if let Some(v) = args.get("strategy") {
            c.strategy = v.into();
        }
        if let Some(v) = args.get("device") {
            c.device = v.into();
        }
        c.epc_bytes = args.u64_or("epc-bytes", c.epc_bytes)?;
        c.seed = args.u64_or("seed", c.seed)?;
        c.partition = args.usize_or("partition", c.partition)?;
        c.pool_epochs = args.u64_or("pool-epochs", c.pool_epochs)?;
        c.max_batch = args.usize_or("max-batch", c.max_batch)?;
        c.max_delay_ms = args.f64_or("max-delay-ms", c.max_delay_ms)?;
        c.workers = args.usize_or("workers", c.workers)?;
        c.lazy_dense_bytes = args.u64_or("lazy-dense-bytes", c.lazy_dense_bytes)?;
        if args.has("strict-otp") {
            c.allow_factor_reuse = false;
        }
        if args.has("no-pipeline") {
            c.pipeline = false;
        }
        Ok(c)
    }

    /// Serialize (for `origami inspect` and run records).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("artifacts", json::s(&self.artifacts.display().to_string())),
            ("model", json::s(&self.model)),
            ("strategy", json::s(&self.strategy)),
            ("device", json::s(&self.device)),
            ("epc_bytes", json::num(self.epc_bytes as f64)),
            ("seed", json::num(self.seed as f64)),
            ("partition", json::num(self.partition as f64)),
            ("pool_epochs", json::num(self.pool_epochs as f64)),
            (
                "allow_factor_reuse",
                Value::Bool(self.allow_factor_reuse),
            ),
            ("max_batch", json::num(self.max_batch as f64)),
            ("max_delay_ms", json::num(self.max_delay_ms)),
            ("workers", json::num(self.workers as f64)),
            ("lazy_dense_bytes", json::num(self.lazy_dense_bytes as f64)),
            ("pipeline", Value::Bool(self.pipeline)),
            ("blind_domain", json::num(self.blind_domain as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_then_json_roundtrip() {
        let c = Config::default();
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.epc_bytes, c.epc_bytes);
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            "serve --model vgg19-32 --device gpu --max-batch 4 --strict-otp"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.model, "vgg19-32");
        assert_eq!(c.device, "gpu");
        assert_eq!(c.max_batch, 4);
        assert!(!c.allow_factor_reuse);
    }

    #[test]
    fn paper_scale_geometry() {
        let c = Config::paper_scale();
        assert_eq!(c.epc_bytes, 128 * 1024 * 1024);
        assert!(c.usable_epc_bytes() > 90 * 1024 * 1024);
        assert!(c.usable_epc_bytes() < 94 * 1024 * 1024);
    }

    #[test]
    fn config_file_loads() {
        let dir = std::env::temp_dir().join("origami-test-config");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        std::fs::write(&path, r#"{"model": "vgg19-32", "max_delay_ms": 7.5}"#).unwrap();
        let c = Config::from_file(&path).unwrap();
        assert_eq!(c.model, "vgg19-32");
        assert_eq!(c.max_delay_ms, 7.5);
    }
}
