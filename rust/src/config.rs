//! Configuration system: JSON config file + CLI overrides.
//!
//! Everything the launcher needs to assemble a serving stack: model,
//! strategy, offload device, enclave geometry, blinding pool, batching
//! policy.  `Config::default()` is the 32-scale CI profile; the paper-
//! scale geometry (128 MB EPC etc.) is `Config::paper_scale()`.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::cli::Args;
use crate::util::json::{self, Value};

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Artifacts directory (manifest + HLO files).
    pub artifacts: PathBuf,
    /// Model name in the manifest.
    pub model: String,
    /// Strategy: baseline2 | split/N | slalom | origami[/N] | open.
    pub strategy: String,
    /// Offload device: cpu | gpu.
    pub device: String,
    /// Enclave protected-memory capacity (bytes).
    pub epc_bytes: u64,
    /// Enclave master seed (determinism).
    pub seed: u64,
    /// Origami partition point (layer index, paper numbering).
    pub partition: usize,
    /// Precomputed unblinding-factor epochs.
    pub pool_epochs: u64,
    /// Allow factor-pool cycling (bench mode only).
    pub allow_factor_reuse: bool,
    /// Blinding-factor precompute service: epochs of (pad, unsealed-R)
    /// pairs staged ahead of demand per tier-1 linear layer (clamped to
    /// `pool_epochs`).  0 disables the pool — blinding runs inline.
    pub factor_pool_depth: u64,
    /// Background prefill worker threads per strategy instance (0 =
    /// stage only at setup; consumed slots then refill inline as misses).
    pub factor_prefill_workers: usize,
    /// Dynamic batcher: max batch size (must be an exported batch).
    pub max_batch: usize,
    /// Dynamic batcher: max queueing delay in ms.
    pub max_delay_ms: f64,
    /// Server worker threads.
    pub workers: usize,
    /// Lazy-load dense layers above this many bytes (Baseline2 policy;
    /// the paper uses 8 MB).
    pub lazy_dense_bytes: u64,
    /// Worker pool: overlap tier-1 (enclave) of batch k+1 with tier-2
    /// (open device) of batch k inside every worker.
    pub pipeline: bool,
    /// Blinding-keyspace domain for this strategy instance.  The worker
    /// pool assigns each worker its index so pad streams are disjoint
    /// across workers; single-instance deployments leave it at 0.
    pub blind_domain: u64,
    /// Multi-model deployment spec, comma-separated
    /// (`model[=strategy[@device][*weight]]`, e.g.
    /// `sim8=origami/6@cpu*2,sim16=slalom`).  Empty = single-model.
    pub models: String,
    /// Shared tier-2 lane fabric: initial lane count (0 → `workers`).
    pub lanes: usize,
    /// Lane autoscale floor (0 → `lanes`).
    pub min_lanes: usize,
    /// Lane autoscale ceiling (0 → `lanes`).
    pub max_lanes: usize,
    /// Per-lane device cycle, comma-separated (`cpu,gpu`); lane *i* is
    /// pinned to entry `i % len`.  Empty → every lane uses `device`.
    pub lane_devices: String,
    /// Tier-1 worker autoscale floor (0 → `workers`).
    pub min_workers: usize,
    /// Tier-1 worker autoscale ceiling (0 → `workers`).
    pub max_workers: usize,
    /// Run the deployment's queue-depth autoscaler thread.
    pub autoscale: bool,
    /// Autoscaler cadence (ms).
    pub autoscale_tick_ms: u64,
    /// Grow a pool/fabric when queue depth exceeds `high × active`.
    pub autoscale_high_depth: usize,
    /// Shrink when depth falls to `low × (active − 1)`.
    pub autoscale_low_depth: usize,
    /// Occupancy-aware batching: flush partial batches early while the
    /// tier-2 side is starved.
    pub occupancy_flush: bool,
    /// End-to-end latency objective (ms) for the model(s); 0 = none.
    /// Per-model overrides come from the deployment spec
    /// (`model=strategy:slo=20ms`).
    pub slo_ms: f64,
    /// Autoscaler signal: `depth` (queue depth, the PR-2 rule) or `p95`
    /// (windowed p95-vs-SLO error with depth fallback).
    pub autoscale_policy: String,
    /// Ticks a scaling target holds after any scale event (hysteresis).
    pub autoscale_cooldown: usize,
    /// Tail-batch splitting: per-task simulated-cost ceiling (ms);
    /// 0 disables cost-based chunk sizing.
    pub split_tail_ms: f64,
    /// Tail-batch splitting: hard per-task request ceiling; 0 disables.
    pub split_tail_chunk: usize,
    /// Admission: sustained per-tenant request rate (requests/s);
    /// 0 = unlimited.  Per-model overrides via `:rps=` in the spec.
    pub rps: f64,
    /// Admission: token-bucket burst capacity (requests); 0 derives
    /// `max(1, rps / 10)`.
    pub admission_burst: f64,
    /// Admission: per-tenant in-flight request quota; 0 = unlimited.
    /// Per-model overrides via `:inflight=` in the spec.
    pub inflight: usize,
    /// Admission: shed requests once the tenant's tier-1 backlog reaches
    /// this depth; 0 = off.  Per-model overrides via `:shed=`.
    pub shed_depth: usize,
    /// What happens to shed requests: `reject` (typed error with a
    /// retry-after hint) or `degrade` (serve from a cheaper strategy
    /// tier; see `degrade_strategy`).
    pub shed_policy: String,
    /// Strategy tier shed requests degrade to under `--shed-policy
    /// degrade`.  `baseline2` keeps the whole network in the enclave, so
    /// degraded traffic stays off the shared tier-2 lanes entirely.
    pub degrade_strategy: String,
    /// EPC-aware co-scheduling of tier-1 pools: 0 = off (the default);
    /// > 0 packs every pool's per-worker enclave footprint (Table-I
    /// memory analytics) into `usable_epc_bytes() × epc_overcommit` —
    /// 1.0 packs exactly, above 1.0 tolerates that much overcommit.
    /// Grows beyond the budget reclaim idle workers from
    /// over-provisioned tenants or are denied (typed, in telemetry).
    pub epc_overcommit: f64,
    /// Process-wide cap on kernel worker threads: all blocked/simd
    /// reference kernels draw from one shared governor sized by this,
    /// so N tier-1 workers × M kernel threads can never oversubscribe
    /// the host.  0 = `available_parallelism`.
    pub kernel_threads: usize,
    /// Default tier-2 tail numeric precision: `f32` or `int8`
    /// (symmetric i8 weights/activations, i32 accumulation).  Per-model
    /// overrides via `:tail=` in the deployment spec.
    pub tail_precision: String,
    /// Data-oblivious tier-1 execution: the non-linear kernels (ReLU,
    /// 2x2 maxpool, padding) run branchless fixed-iteration variants
    /// whose memory-touch sequence depends only on tensor shapes —
    /// Privado's access-pattern leak closed, at bit-identical outputs.
    /// The planners scale the tenant's queue pressure by
    /// [`OBLIVIOUS_COST_MULTIPLIER`] so autoscaling stays honest under
    /// the slower kernels.  Per-model overrides via `:oblivious=` in
    /// the deployment spec.
    ///
    /// [`OBLIVIOUS_COST_MULTIPLIER`]: crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER
    pub oblivious: bool,
    /// Network front door bind address (`host:port`; port 0 picks an
    /// ephemeral port).  Empty = no listener: the deployment serves
    /// in-process submissions only.
    pub listen: String,
    /// Session TTL for the deployment's session table (ms): sessions
    /// expire this long after they are established (or last refreshed);
    /// 0 expires immediately (useful in tests).
    pub session_ttl_ms: u64,
    /// Session-table shard count (striped locks; rounded up to a power
    /// of two).  Size for the live-session population — the default
    /// comfortably absorbs millions of entries.
    pub session_shards: usize,
    /// Live-session ceiling: past it the table evicts its
    /// least-recently-used entries, so session state stays bounded even
    /// under a HELLO flood arriving faster than the TTL retires it.
    /// 0 = unbounded (trusted in-process deployments only).
    pub session_cap: usize,
    /// Session-sweep cadence (ms) of the deployment's background
    /// sweeper thread: expired sessions are reaped on this cadence even
    /// with autoscaling off.  0 disables the sweeper (trusted
    /// deployments that drive [`autoscale_tick`] themselves).
    ///
    /// [`autoscale_tick`]: crate::coordinator::Deployment::autoscale_tick
    pub session_sweep_ms: u64,
    /// Enclave track this node serves in (empty = single-node, no track
    /// membership).  All members of a track share one blinding-domain
    /// seed and session-key root, handed off over the attested join
    /// channel, so any member can pick up any of the track's sessions.
    pub track: String,
    /// Comma-separated `host:port` list of existing track members to
    /// join through (empty = this node is the track's genesis member
    /// and mints the track keys itself).
    pub track_peers: String,
    /// Grace period (ms) a draining node's sessions get before the
    /// cluster router force-migrates them onto same-track siblings.
    pub drain_grace_ms: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            artifacts: crate::model::Manifest::default_root(),
            model: "vgg16-32".into(),
            strategy: "origami".into(),
            device: "cpu".into(),
            // 32-scale default: EPC scaled so model-vs-EPC pressure is
            // paper-like (see DESIGN.md §2). vgg16-32 params ≈ 0.13 MB.
            epc_bytes: 256 * 1024,
            seed: 2019,
            partition: 6,
            pool_epochs: 64,
            allow_factor_reuse: true,
            factor_pool_depth: 0,
            factor_prefill_workers: 2,
            max_batch: 8,
            max_delay_ms: 2.0,
            workers: 2,
            lazy_dense_bytes: 16 * 1024,
            pipeline: true,
            blind_domain: 0,
            models: String::new(),
            lanes: 0,
            min_lanes: 0,
            max_lanes: 0,
            lane_devices: String::new(),
            min_workers: 0,
            max_workers: 0,
            autoscale: false,
            autoscale_tick_ms: 20,
            autoscale_high_depth: 4,
            autoscale_low_depth: 1,
            occupancy_flush: false,
            slo_ms: 0.0,
            autoscale_policy: "depth".into(),
            autoscale_cooldown: 2,
            split_tail_ms: 0.0,
            split_tail_chunk: 0,
            rps: 0.0,
            admission_burst: 0.0,
            inflight: 0,
            shed_depth: 0,
            shed_policy: "reject".into(),
            degrade_strategy: "baseline2".into(),
            epc_overcommit: 0.0,
            kernel_threads: 0,
            tail_precision: "f32".into(),
            oblivious: false,
            listen: String::new(),
            session_ttl_ms: crate::coordinator::router::DEFAULT_SESSION_TTL_MS,
            session_shards: crate::coordinator::router::DEFAULT_SESSION_SHARDS,
            session_cap: crate::coordinator::router::DEFAULT_SESSION_CAP,
            session_sweep_ms: crate::coordinator::router::DEFAULT_SESSION_SWEEP_MS,
            track: String::new(),
            track_peers: String::new(),
            drain_grace_ms: crate::coordinator::cluster::DEFAULT_DRAIN_GRACE_MS,
        }
    }
}

impl Config {
    /// Paper-scale geometry (224 models, 128 MB EPC, 8 MB lazy bound).
    pub fn paper_scale() -> Self {
        Self {
            model: "vgg16".into(),
            epc_bytes: 128 * 1024 * 1024,
            lazy_dense_bytes: 8 * 1024 * 1024,
            ..Self::default()
        }
    }

    /// Usable EPC after SGX metadata overhead (~93 of 128 MB; same ratio
    /// applied at every scale).
    pub fn usable_epc_bytes(&self) -> u64 {
        (self.epc_bytes as f64 * 0.727) as u64
    }

    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let v = json::from_file(path)?;
        let mut c = Self::default();
        c.apply_json(&v);
        anyhow::ensure!(
            c.autoscale_policy == "depth" || c.autoscale_policy == "p95",
            "config {}: autoscale_policy must be `depth` or `p95`, got `{}`",
            path.display(),
            c.autoscale_policy
        );
        anyhow::ensure!(
            c.shed_policy == "reject" || c.shed_policy == "degrade",
            "config {}: shed_policy must be `reject` or `degrade`, got `{}`",
            path.display(),
            c.shed_policy
        );
        anyhow::ensure!(
            c.tail_precision == "f32" || c.tail_precision == "int8",
            "config {}: tail_precision must be `f32` or `int8`, got `{}`",
            path.display(),
            c.tail_precision
        );
        Ok(c)
    }

    fn apply_json(&mut self, v: &Value) {
        if let Some(s) = v.get("artifacts").and_then(|x| x.as_str()) {
            self.artifacts = PathBuf::from(s);
        }
        for (field, slot) in [
            ("model", &mut self.model),
            ("strategy", &mut self.strategy),
            ("device", &mut self.device),
            ("models", &mut self.models),
            ("lane_devices", &mut self.lane_devices),
            ("autoscale_policy", &mut self.autoscale_policy),
            ("shed_policy", &mut self.shed_policy),
            ("degrade_strategy", &mut self.degrade_strategy),
            ("tail_precision", &mut self.tail_precision),
            ("listen", &mut self.listen),
            ("track", &mut self.track),
            ("track_peers", &mut self.track_peers),
        ] {
            if let Some(s) = v.get(field).and_then(|x| x.as_str()) {
                *slot = s.to_string();
            }
        }
        for (field, slot) in [
            ("epc_bytes", &mut self.epc_bytes),
            ("seed", &mut self.seed),
            ("pool_epochs", &mut self.pool_epochs),
            ("factor_pool_depth", &mut self.factor_pool_depth),
            ("lazy_dense_bytes", &mut self.lazy_dense_bytes),
            ("autoscale_tick_ms", &mut self.autoscale_tick_ms),
            ("session_ttl_ms", &mut self.session_ttl_ms),
            ("session_sweep_ms", &mut self.session_sweep_ms),
            ("drain_grace_ms", &mut self.drain_grace_ms),
        ] {
            if let Some(n) = v.get(field).and_then(|x| x.as_i64()) {
                *slot = n as u64;
            }
        }
        for (field, slot) in [
            ("partition", &mut self.partition),
            ("factor_prefill_workers", &mut self.factor_prefill_workers),
            ("max_batch", &mut self.max_batch),
            ("workers", &mut self.workers),
            ("lanes", &mut self.lanes),
            ("min_lanes", &mut self.min_lanes),
            ("max_lanes", &mut self.max_lanes),
            ("min_workers", &mut self.min_workers),
            ("max_workers", &mut self.max_workers),
            ("autoscale_high_depth", &mut self.autoscale_high_depth),
            ("autoscale_low_depth", &mut self.autoscale_low_depth),
            ("autoscale_cooldown", &mut self.autoscale_cooldown),
            ("split_tail_chunk", &mut self.split_tail_chunk),
            ("inflight", &mut self.inflight),
            ("shed_depth", &mut self.shed_depth),
            ("kernel_threads", &mut self.kernel_threads),
            ("session_shards", &mut self.session_shards),
            ("session_cap", &mut self.session_cap),
        ] {
            if let Some(n) = v.get(field).and_then(|x| x.as_usize()) {
                *slot = n;
            }
        }
        if let Some(n) = v.get("max_delay_ms").and_then(|x| x.as_f64()) {
            self.max_delay_ms = n;
        }
        if let Some(n) = v.get("slo_ms").and_then(|x| x.as_f64()) {
            self.slo_ms = n;
        }
        if let Some(n) = v.get("split_tail_ms").and_then(|x| x.as_f64()) {
            self.split_tail_ms = n;
        }
        if let Some(n) = v.get("rps").and_then(|x| x.as_f64()) {
            self.rps = n;
        }
        if let Some(n) = v.get("admission_burst").and_then(|x| x.as_f64()) {
            self.admission_burst = n;
        }
        if let Some(n) = v.get("epc_overcommit").and_then(|x| x.as_f64()) {
            self.epc_overcommit = n;
        }
        if let Some(b) = v.get("allow_factor_reuse").and_then(|x| x.as_bool()) {
            self.allow_factor_reuse = b;
        }
        if let Some(b) = v.get("pipeline").and_then(|x| x.as_bool()) {
            self.pipeline = b;
        }
        if let Some(b) = v.get("autoscale").and_then(|x| x.as_bool()) {
            self.autoscale = b;
        }
        if let Some(b) = v.get("occupancy_flush").and_then(|x| x.as_bool()) {
            self.occupancy_flush = b;
        }
        if let Some(b) = v.get("oblivious").and_then(|x| x.as_bool()) {
            self.oblivious = b;
        }
        if let Some(n) = v.get("blind_domain").and_then(|x| x.as_i64()) {
            self.blind_domain = n as u64;
        }
    }

    /// Apply CLI overrides (`--model`, `--device`, …; `--config` first).
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut c = match args.get("config") {
            Some(path) => Self::from_file(Path::new(path))?,
            None => Self::default(),
        };
        if args.has("paper-scale") {
            c = Self {
                artifacts: c.artifacts.clone(),
                ..Self::paper_scale()
            };
        }
        if let Some(v) = args.get("artifacts") {
            c.artifacts = PathBuf::from(v);
        }
        if let Some(v) = args.get("model") {
            c.model = v.into();
        }
        if let Some(v) = args.get("strategy") {
            c.strategy = v.into();
        }
        if let Some(v) = args.get("device") {
            c.device = v.into();
        }
        if let Some(v) = args.get("models") {
            c.models = v.into();
        }
        if let Some(v) = args.get("lane-devices") {
            c.lane_devices = v.into();
        }
        c.epc_bytes = args.u64_or("epc-bytes", c.epc_bytes)?;
        c.seed = args.u64_or("seed", c.seed)?;
        c.partition = args.usize_or("partition", c.partition)?;
        c.pool_epochs = args.u64_or("pool-epochs", c.pool_epochs)?;
        c.factor_pool_depth = args.u64_or("factor-pool-depth", c.factor_pool_depth)?;
        c.factor_prefill_workers =
            args.usize_or("factor-prefill-workers", c.factor_prefill_workers)?;
        c.max_batch = args.usize_or("max-batch", c.max_batch)?;
        c.max_delay_ms = args.f64_or("max-delay-ms", c.max_delay_ms)?;
        c.workers = args.usize_or("workers", c.workers)?;
        c.lanes = args.usize_or("lanes", c.lanes)?;
        c.min_lanes = args.usize_or("min-lanes", c.min_lanes)?;
        c.max_lanes = args.usize_or("max-lanes", c.max_lanes)?;
        c.min_workers = args.usize_or("min-workers", c.min_workers)?;
        c.max_workers = args.usize_or("max-workers", c.max_workers)?;
        c.autoscale_tick_ms = args.u64_or("autoscale-tick-ms", c.autoscale_tick_ms)?;
        c.autoscale_high_depth = args.usize_or("autoscale-high-depth", c.autoscale_high_depth)?;
        c.autoscale_low_depth = args.usize_or("autoscale-low-depth", c.autoscale_low_depth)?;
        c.autoscale_cooldown = args.usize_or("autoscale-cooldown", c.autoscale_cooldown)?;
        if let Some(v) = args.get("autoscale-policy") {
            anyhow::ensure!(
                v == "depth" || v == "p95",
                "--autoscale-policy must be `depth` or `p95`, got `{v}`"
            );
            c.autoscale_policy = v.into();
        }
        c.slo_ms = args.f64_or("slo-ms", c.slo_ms)?;
        c.split_tail_ms = args.f64_or("split-tail-ms", c.split_tail_ms)?;
        c.split_tail_chunk = args.usize_or("split-tail-chunk", c.split_tail_chunk)?;
        c.rps = args.f64_or("rps", c.rps)?;
        c.admission_burst = args.f64_or("admission-burst", c.admission_burst)?;
        c.inflight = args.usize_or("inflight", c.inflight)?;
        c.shed_depth = args.usize_or("shed-depth", c.shed_depth)?;
        if let Some(v) = args.get("shed-policy") {
            anyhow::ensure!(
                v == "reject" || v == "degrade",
                "--shed-policy must be `reject` or `degrade`, got `{v}`"
            );
            c.shed_policy = v.into();
        }
        if let Some(v) = args.get("degrade-strategy") {
            c.degrade_strategy = v.into();
        }
        c.epc_overcommit = args.f64_or("epc-overcommit", c.epc_overcommit)?;
        anyhow::ensure!(
            c.epc_overcommit >= 0.0,
            "--epc-overcommit must be ≥ 0 (0 disables EPC-aware scheduling), got {}",
            c.epc_overcommit
        );
        c.lazy_dense_bytes = args.u64_or("lazy-dense-bytes", c.lazy_dense_bytes)?;
        c.kernel_threads = args.usize_or("kernel-threads", c.kernel_threads)?;
        if let Some(v) = args.get("tail-precision") {
            anyhow::ensure!(
                v == "f32" || v == "int8",
                "--tail-precision must be `f32` or `int8`, got `{v}`"
            );
            c.tail_precision = v.into();
        }
        if let Some(v) = args.get("listen") {
            c.listen = v.into();
        }
        c.session_ttl_ms = args.u64_or("session-ttl", c.session_ttl_ms)?;
        c.session_shards = args.usize_or("session-shards", c.session_shards)?;
        c.session_cap = args.usize_or("session-cap", c.session_cap)?;
        c.session_sweep_ms = args.u64_or("session-sweep-ms", c.session_sweep_ms)?;
        anyhow::ensure!(
            c.session_shards > 0,
            "--session-shards must be ≥ 1, got {}",
            c.session_shards
        );
        if let Some(v) = args.get("track") {
            c.track = v.into();
        }
        if let Some(v) = args.get("track-peers") {
            c.track_peers = v.into();
        }
        c.drain_grace_ms = args.u64_or("drain-grace-ms", c.drain_grace_ms)?;
        if args.has("strict-otp") {
            c.allow_factor_reuse = false;
        }
        if args.has("no-pipeline") {
            c.pipeline = false;
        }
        if args.has("autoscale") {
            c.autoscale = true;
        }
        if args.has("occupancy-flush") {
            c.occupancy_flush = true;
        }
        if args.has("oblivious") {
            c.oblivious = true;
        }
        Ok(c)
    }

    /// Serialize (for `origami inspect` and run records).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("artifacts", json::s(&self.artifacts.display().to_string())),
            ("model", json::s(&self.model)),
            ("strategy", json::s(&self.strategy)),
            ("device", json::s(&self.device)),
            ("epc_bytes", json::num(self.epc_bytes as f64)),
            ("seed", json::num(self.seed as f64)),
            ("partition", json::num(self.partition as f64)),
            ("pool_epochs", json::num(self.pool_epochs as f64)),
            (
                "allow_factor_reuse",
                Value::Bool(self.allow_factor_reuse),
            ),
            (
                "factor_pool_depth",
                json::num(self.factor_pool_depth as f64),
            ),
            (
                "factor_prefill_workers",
                json::num(self.factor_prefill_workers as f64),
            ),
            ("max_batch", json::num(self.max_batch as f64)),
            ("max_delay_ms", json::num(self.max_delay_ms)),
            ("workers", json::num(self.workers as f64)),
            ("lazy_dense_bytes", json::num(self.lazy_dense_bytes as f64)),
            ("pipeline", Value::Bool(self.pipeline)),
            ("blind_domain", json::num(self.blind_domain as f64)),
            ("models", json::s(&self.models)),
            ("lanes", json::num(self.lanes as f64)),
            ("min_lanes", json::num(self.min_lanes as f64)),
            ("max_lanes", json::num(self.max_lanes as f64)),
            ("lane_devices", json::s(&self.lane_devices)),
            ("min_workers", json::num(self.min_workers as f64)),
            ("max_workers", json::num(self.max_workers as f64)),
            ("autoscale", Value::Bool(self.autoscale)),
            ("autoscale_tick_ms", json::num(self.autoscale_tick_ms as f64)),
            (
                "autoscale_high_depth",
                json::num(self.autoscale_high_depth as f64),
            ),
            (
                "autoscale_low_depth",
                json::num(self.autoscale_low_depth as f64),
            ),
            ("occupancy_flush", Value::Bool(self.occupancy_flush)),
            ("slo_ms", json::num(self.slo_ms)),
            ("autoscale_policy", json::s(&self.autoscale_policy)),
            (
                "autoscale_cooldown",
                json::num(self.autoscale_cooldown as f64),
            ),
            ("split_tail_ms", json::num(self.split_tail_ms)),
            (
                "split_tail_chunk",
                json::num(self.split_tail_chunk as f64),
            ),
            ("rps", json::num(self.rps)),
            ("admission_burst", json::num(self.admission_burst)),
            ("inflight", json::num(self.inflight as f64)),
            ("shed_depth", json::num(self.shed_depth as f64)),
            ("shed_policy", json::s(&self.shed_policy)),
            ("degrade_strategy", json::s(&self.degrade_strategy)),
            ("epc_overcommit", json::num(self.epc_overcommit)),
            ("kernel_threads", json::num(self.kernel_threads as f64)),
            ("tail_precision", json::s(&self.tail_precision)),
            ("oblivious", Value::Bool(self.oblivious)),
            ("listen", json::s(&self.listen)),
            ("session_ttl_ms", json::num(self.session_ttl_ms as f64)),
            ("session_shards", json::num(self.session_shards as f64)),
            ("session_cap", json::num(self.session_cap as f64)),
            ("session_sweep_ms", json::num(self.session_sweep_ms as f64)),
            ("track", json::s(&self.track)),
            ("track_peers", json::s(&self.track_peers)),
            ("drain_grace_ms", json::num(self.drain_grace_ms as f64)),
        ])
    }

    /// The config-file keys and values where `self` differs from the
    /// defaults — what the `serve` startup banner prints, so the banner
    /// reflects *every* knob (autoscale, admission, EPC, …) and can
    /// never drift from the config surface: both sides come from
    /// [`Config::to_json`].
    pub fn non_default_settings(&self) -> Vec<(String, String)> {
        let mine = self.to_json();
        let base = Config::default().to_json();
        let mut out = Vec::new();
        if let (Value::Obj(fields), Value::Obj(_)) = (&mine, &base) {
            for (key, value) in fields {
                if base.get(key) != Some(value) {
                    out.push((key.clone(), render_value(value)));
                }
            }
        }
        out
    }
}

fn render_value(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        other => other.to_json(),
    }
}

/// One CLI flag's documentation row — the single source the `--help`
/// text, the `serve` startup banner and the `docs/CONFIG.md` drift
/// tests render from, so none of them can omit a knob the parser
/// accepts (the PR-3/4 help text drifted exactly that way).
#[derive(Debug, Clone)]
pub struct FlagDoc {
    /// Section in the help output (`common`, `serve`, `fabric`,
    /// `autoscale`, `admission`, `epc`).
    pub group: &'static str,
    /// The CLI flag (empty for config-file-only fields like
    /// `blind_domain`, which serving infrastructure sets internally).
    pub flag: &'static str,
    /// Value placeholder in the help text (empty for boolean switches).
    pub value: &'static str,
    /// Config-file JSON key (empty for CLI-only flags like `--config`).
    pub json_key: &'static str,
    /// One-line description.
    pub help: &'static str,
}

/// The suffix keys [`ModelSpec::parse`] accepts after a model spec
/// (`model:key=value`).  Kept as data so the CONFIG.md drift test can
/// assert each is documented.
pub const SPEC_SUFFIX_KEYS: [&str; 6] = ["slo", "rps", "inflight", "shed", "tail", "oblivious"];

impl Config {
    /// Every CLI flag and config-file field, grouped for help output.
    /// A unit test pins this table against [`Config::to_json`]'s keys,
    /// so adding a config field without documenting it fails CI.
    pub fn flag_docs() -> Vec<FlagDoc> {
        let d = |group, flag, value, json_key, help| FlagDoc {
            group,
            flag,
            value,
            json_key,
            help,
        };
        vec![
            // common
            d("common", "--config", "<file>", "", "JSON config file (CLI overrides after)"),
            d("common", "--paper-scale", "", "", "paper geometry preset (224, 128 MB EPC)"),
            d("common", "--artifacts", "<dir>", "artifacts", "artifacts root (manifest + HLO)"),
            d("common", "--model", "<name>", "model", "vgg16-32 | vgg19-32 | sim8/sim224"),
            d("common", "--strategy", "<s>", "strategy", "baseline2|split/N|slalom|origami|open"),
            d("common", "--device", "<d>", "device", "offload device: cpu | gpu"),
            d("common", "--partition", "<p>", "partition", "Origami partition layer"),
            d("common", "--seed", "<n>", "seed", "deployment master seed (determinism)"),
            d("common", "--epc-bytes", "<n>", "epc_bytes", "enclave protected memory (bytes)"),
            d("common", "--pool-epochs", "<n>", "pool_epochs", "precomputed unblind-factor epochs"),
            d("common", "--strict-otp", "", "allow_factor_reuse", "forbid factor-pool cycling"),
            d("common", "--factor-pool-depth", "<n>", "factor_pool_depth", "staged epochs/layer (0 = inline)"),
            d("common", "--factor-prefill-workers", "<n>", "factor_prefill_workers", "prefill threads"),
            d("common", "--lazy-dense-bytes", "<n>", "lazy_dense_bytes", "lazy-load dense bound"),
            d("common", "--kernel-threads", "<n>", "kernel_threads", "kernel thread cap (0 = cores)"),
            d("common", "--tail-precision", "<p>", "tail_precision", "tier-2 tails: f32 | int8"),
            d("common", "--oblivious", "", "oblivious", "data-oblivious tier-1 kernels (fixed access trace)"),
            // serve
            d("serve", "--requests", "<n>", "", "total synthetic workload requests [64]"),
            d("serve", "--rate", "<rps>", "", "Poisson open-loop arrival rate [50]"),
            d("serve", "--workers", "<n>", "workers", "tier-1 strategy workers per pool"),
            d("serve", "--max-batch", "<n>", "max_batch", "dynamic batcher: max batch size"),
            d("serve", "--max-delay-ms", "<f>", "max_delay_ms", "batcher max queueing delay (ms)"),
            d("serve", "--pool", "", "", "sharded worker pool, not the shared-batcher engine"),
            d("serve", "--no-pipeline", "", "pipeline", "pool only: serialize tier-1/tier-2"),
            d("serve", "--occupancy-flush", "", "occupancy_flush", "flush while tier-2 starves"),
            d("serve", "", "", "blind_domain", "pad keyspace (set per worker by the pool)"),
            // fabric (multi-model)
            d("fabric", "--models", "<spec>", "models", "model[=strat[@dev][*w]][:key=val…],…"),
            d("fabric", "--lanes", "<n>", "lanes", "shared tier-2 lane count (0 = workers)"),
            d("fabric", "--min-lanes", "<n>", "min_lanes", "lane autoscale floor (0 = pinned)"),
            d("fabric", "--max-lanes", "<n>", "max_lanes", "lane autoscale ceiling (0 = pinned)"),
            d("fabric", "--lane-devices", "<l>", "lane_devices", "device cycle, e.g. cpu,gpu"),
            d("fabric", "--min-workers", "<n>", "min_workers", "worker floor (0 = pinned)"),
            d("fabric", "--max-workers", "<n>", "max_workers", "worker ceiling (0 = pinned)"),
            d("fabric", "--split-tail-ms", "<f>", "split_tail_ms", "split tails over this cost"),
            d("fabric", "--split-tail-chunk", "<n>", "split_tail_chunk", "per-tail req ceiling"),
            // autoscale
            d("autoscale", "--autoscale", "", "autoscale", "run the background autoscaler"),
            d("autoscale", "--autoscale-policy", "<p>", "autoscale_policy", "depth | p95"),
            d("autoscale", "--autoscale-tick-ms", "<t>", "autoscale_tick_ms", "cadence (ms)"),
            d("autoscale", "--autoscale-high-depth", "<n>", "autoscale_high_depth", "grow bar"),
            d("autoscale", "--autoscale-low-depth", "<n>", "autoscale_low_depth", "shrink bar"),
            d("autoscale", "--autoscale-cooldown", "<t>", "autoscale_cooldown", "hold ticks"),
            d("autoscale", "--slo-ms", "<f>", "slo_ms", "default latency objective (0 = none)"),
            // admission
            d("admission", "--rps", "<f>", "rps", "token-bucket rate limit (req/s; 0 = off)"),
            d("admission", "--admission-burst", "<f>", "admission_burst", "bucket burst cap"),
            d("admission", "--inflight", "<n>", "inflight", "in-flight quota (0 = off)"),
            d("admission", "--shed-depth", "<n>", "shed_depth", "shed backlog bar (0 = off)"),
            d("admission", "--shed-policy", "<p>", "shed_policy", "reject | degrade"),
            d("admission", "--degrade-strategy", "<s>", "degrade_strategy", "the cheaper tier"),
            // epc
            d("epc", "--epc-overcommit", "<f>", "epc_overcommit", "usable EPC × this (0 = off)"),
            // net (attested front door)
            d("net", "--listen", "<addr>", "listen", "TCP front door bind addr (empty = off)"),
            d("net", "--session-ttl", "<ms>", "session_ttl_ms", "session table TTL (ms)"),
            d("net", "--session-shards", "<n>", "session_shards", "session table lock stripes"),
            d("net", "--session-cap", "<n>", "session_cap", "live-session LRU ceiling (0 = off)"),
            d("net", "--session-sweep-ms", "<ms>", "session_sweep_ms", "expiry sweep cadence (0 = off)"),
            // track (enclave tracks + cluster routing)
            d("track", "--track", "<name>", "track", "enclave track to serve in (empty = solo)"),
            d("track", "--track-peers", "<l>", "track_peers", "host:port,… members to join through"),
            d("track", "--drain-grace-ms", "<ms>", "drain_grace_ms", "drain grace before force-migrate"),
        ]
    }
}

/// One model's slot in a multi-model deployment spec.
///
/// Text form: `model[=strategy[@device][*weight]][:key=value…]` — e.g.
/// `sim8`, `sim8=origami/6`, `sim8=origami/6@gpu*2:slo=20ms`,
/// `sim16=slalom@cpu`, `sim16:slo=20ms:rps=500:inflight=64:shed=128`.
/// Omitted parts inherit the base config.  Suffix keys:
///
/// - `slo` — end-to-end latency objective the p95 autoscaler (and the
///   fabric's deadline-aware popping) holds the model to (ms; the `ms`
///   suffix is optional).
/// - `rps` — admission token-bucket rate limit (requests/s).
/// - `inflight` — admission in-flight concurrency quota.
/// - `shed` — admission queue-depth shed threshold.
/// - `tail` — tier-2 tail precision: `f32` or `int8`.
/// - `oblivious` — data-oblivious tier-1 kernels: `on` or `off`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub model: String,
    pub strategy: Option<String>,
    pub device: Option<String>,
    /// Weighted-fair share of the shared tier-2 lane fabric.
    pub weight: f64,
    /// Per-model latency objective (ms).
    pub slo_ms: Option<f64>,
    /// Admission: sustained request rate (requests/s).
    pub rps: Option<f64>,
    /// Admission: in-flight request quota.
    pub inflight: Option<usize>,
    /// Admission: tier-1 queue depth at which requests are shed.
    pub shed_depth: Option<usize>,
    /// Tier-2 tail precision override (`f32` | `int8`).
    pub tail: Option<String>,
    /// Data-oblivious tier-1 kernel override (`on` | `off`).
    pub oblivious: Option<bool>,
}

impl ModelSpec {
    /// Parse one spec.
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        anyhow::ensure!(!spec.is_empty(), "empty model spec");
        let mut suffixes = spec.split(':');
        let head = suffixes.next().unwrap_or_default().trim();
        anyhow::ensure!(!head.is_empty(), "model spec `{spec}`: empty model name");
        let mut slo_ms = None;
        let mut rps = None;
        let mut inflight = None;
        let mut shed_depth = None;
        let mut tail = None;
        let mut oblivious = None;
        for part in suffixes {
            let (key, value) = part
                .trim()
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("model spec `{spec}`: bad option `{part}`"))?;
            let value = value.trim();
            match key.trim() {
                "slo" => {
                    let raw = value.trim_end_matches("ms").trim();
                    let slo = raw.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("model spec `{spec}`: bad SLO `{value}`")
                    })?;
                    anyhow::ensure!(
                        slo > 0.0,
                        "model spec `{spec}`: SLO must be positive"
                    );
                    slo_ms = Some(slo);
                }
                "rps" => {
                    let r = value.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("model spec `{spec}`: bad rps `{value}`")
                    })?;
                    anyhow::ensure!(
                        r > 0.0,
                        "model spec `{spec}`: rps must be positive"
                    );
                    rps = Some(r);
                }
                "inflight" => {
                    let n = value.parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("model spec `{spec}`: bad inflight `{value}`")
                    })?;
                    anyhow::ensure!(
                        n > 0,
                        "model spec `{spec}`: inflight must be positive"
                    );
                    inflight = Some(n);
                }
                "shed" => {
                    let n = value.parse::<usize>().map_err(|_| {
                        anyhow::anyhow!("model spec `{spec}`: bad shed depth `{value}`")
                    })?;
                    anyhow::ensure!(
                        n > 0,
                        "model spec `{spec}`: shed depth must be positive"
                    );
                    shed_depth = Some(n);
                }
                "tail" => {
                    anyhow::ensure!(
                        value == "f32" || value == "int8",
                        "model spec `{spec}`: tail must be `f32` or `int8`, got `{value}`"
                    );
                    tail = Some(value.to_string());
                }
                "oblivious" => {
                    oblivious = Some(match value {
                        "on" => true,
                        "off" => false,
                        _ => anyhow::bail!(
                            "model spec `{spec}`: oblivious must be `on` or `off`, got `{value}`"
                        ),
                    });
                }
                other => anyhow::bail!("model spec `{spec}`: unknown option `{other}`"),
            }
        }
        let (model, rest) = match head.split_once('=') {
            Some((m, r)) => (m.trim(), Some(r.trim())),
            None => (head, None),
        };
        anyhow::ensure!(!model.is_empty(), "model spec `{spec}`: empty model name");
        let mut strategy = None;
        let mut device = None;
        let mut weight = 1.0f64;
        if let Some(rest) = rest {
            let (rest, w) = match rest.split_once('*') {
                Some((r, w)) => (r.trim(), Some(w.trim())),
                None => (rest, None),
            };
            if let Some(w) = w {
                weight = w
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("model spec `{spec}`: bad weight `{w}`"))?;
                anyhow::ensure!(
                    weight > 0.0,
                    "model spec `{spec}`: weight must be positive"
                );
            }
            let (strat, dev) = match rest.split_once('@') {
                Some((s, d)) => (s.trim(), Some(d.trim())),
                None => (rest, None),
            };
            if !strat.is_empty() {
                strategy = Some(strat.to_string());
            }
            if let Some(d) = dev {
                anyhow::ensure!(!d.is_empty(), "model spec `{spec}`: empty device");
                device = Some(d.to_string());
            }
        }
        Ok(Self {
            model: model.to_string(),
            strategy,
            device,
            weight,
            slo_ms,
            rps,
            inflight,
            shed_depth,
            tail,
            oblivious,
        })
    }

    /// Parse a comma-separated spec list (`--models`).
    pub fn parse_list(s: &str) -> Result<Vec<Self>> {
        let mut out = Vec::new();
        for part in s.split(',') {
            if part.trim().is_empty() {
                continue;
            }
            out.push(Self::parse(part)?);
        }
        anyhow::ensure!(!out.is_empty(), "no model specs in `{s}`");
        Ok(out)
    }

    /// The per-model config: the base with this spec's overrides applied.
    pub fn apply(&self, base: &Config) -> Config {
        let mut c = base.clone();
        c.model = self.model.clone();
        if let Some(s) = &self.strategy {
            c.strategy = s.clone();
        }
        if let Some(d) = &self.device {
            c.device = d.clone();
        }
        if let Some(slo) = self.slo_ms {
            c.slo_ms = slo;
        }
        if let Some(rps) = self.rps {
            c.rps = rps;
        }
        if let Some(inflight) = self.inflight {
            c.inflight = inflight;
        }
        if let Some(shed) = self.shed_depth {
            c.shed_depth = shed;
        }
        if let Some(tail) = &self.tail {
            c.tail_precision = tail.clone();
        }
        if let Some(oblivious) = self.oblivious {
            c.oblivious = oblivious;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_then_json_roundtrip() {
        let c = Config::default();
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.model, c.model);
        assert_eq!(c2.epc_bytes, c.epc_bytes);
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            "serve --model vgg19-32 --device gpu --max-batch 4 --strict-otp"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.model, "vgg19-32");
        assert_eq!(c.device, "gpu");
        assert_eq!(c.max_batch, 4);
        assert!(!c.allow_factor_reuse);
    }

    #[test]
    fn paper_scale_geometry() {
        let c = Config::paper_scale();
        assert_eq!(c.epc_bytes, 128 * 1024 * 1024);
        assert!(c.usable_epc_bytes() > 90 * 1024 * 1024);
        assert!(c.usable_epc_bytes() < 94 * 1024 * 1024);
    }

    #[test]
    fn model_spec_parses_all_shapes() {
        let s = ModelSpec::parse("sim8").unwrap();
        assert_eq!(s.model, "sim8");
        assert_eq!(s.strategy, None);
        assert_eq!(s.device, None);
        assert_eq!(s.weight, 1.0);
        assert_eq!(s.slo_ms, None);

        let s = ModelSpec::parse("sim8=origami/6@gpu*2").unwrap();
        assert_eq!(s.model, "sim8");
        assert_eq!(s.strategy.as_deref(), Some("origami/6"));
        assert_eq!(s.device.as_deref(), Some("gpu"));
        assert_eq!(s.weight, 2.0);

        let s = ModelSpec::parse(" sim16 = slalom ").unwrap();
        assert_eq!(s.model, "sim16");
        assert_eq!(s.strategy.as_deref(), Some("slalom"));

        let list = ModelSpec::parse_list("sim8=origami/6*2, sim16=slalom@cpu").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[1].model, "sim16");
        assert_eq!(list[1].device.as_deref(), Some("cpu"));

        assert!(ModelSpec::parse("").is_err());
        assert!(ModelSpec::parse("=origami").is_err());
        assert!(ModelSpec::parse("sim8=origami*zero").is_err());
        assert!(ModelSpec::parse("sim8=origami*-1").is_err());
        assert!(ModelSpec::parse_list(" , ").is_err());
    }

    #[test]
    fn model_spec_parses_slo_suffix() {
        let s = ModelSpec::parse("sim8=origami/6@gpu*2:slo=20ms").unwrap();
        assert_eq!(s.model, "sim8");
        assert_eq!(s.strategy.as_deref(), Some("origami/6"));
        assert_eq!(s.device.as_deref(), Some("gpu"));
        assert_eq!(s.weight, 2.0);
        assert_eq!(s.slo_ms, Some(20.0));

        // ms suffix optional; works without strategy too
        let s = ModelSpec::parse("sim16:slo=7.5").unwrap();
        assert_eq!(s.model, "sim16");
        assert_eq!(s.strategy, None);
        assert_eq!(s.slo_ms, Some(7.5));

        assert!(ModelSpec::parse("sim8:slo=").is_err());
        assert!(ModelSpec::parse("sim8:slo=-3").is_err());
        assert!(ModelSpec::parse("sim8:slo=fast").is_err());
        assert!(ModelSpec::parse(":slo=5").is_err(), "SLO without a model");

        // the SLO flows into the per-model config
        let base = Config::default();
        let cfg = ModelSpec::parse("sim8:slo=12ms").unwrap().apply(&base);
        assert_eq!(cfg.slo_ms, 12.0);
        let cfg = ModelSpec::parse("sim8").unwrap().apply(&base);
        assert_eq!(cfg.slo_ms, base.slo_ms, "no SLO in the spec inherits");

        let list = ModelSpec::parse_list("sim8:slo=5ms,sim16=slalom:slo=50ms").unwrap();
        assert_eq!(list[0].slo_ms, Some(5.0));
        assert_eq!(list[1].slo_ms, Some(50.0));
        assert_eq!(list[1].strategy.as_deref(), Some("slalom"));
    }

    #[test]
    fn model_spec_parses_admission_suffixes() {
        let s = ModelSpec::parse("sim8=origami/6@gpu*2:slo=20ms:rps=500:inflight=64:shed=128")
            .unwrap();
        assert_eq!(s.model, "sim8");
        assert_eq!(s.strategy.as_deref(), Some("origami/6"));
        assert_eq!(s.device.as_deref(), Some("gpu"));
        assert_eq!(s.weight, 2.0);
        assert_eq!(s.slo_ms, Some(20.0));
        assert_eq!(s.rps, Some(500.0));
        assert_eq!(s.inflight, Some(64));
        assert_eq!(s.shed_depth, Some(128));

        // suffix order is free; unspecified limits stay None
        let s = ModelSpec::parse("sim16:rps=10.5").unwrap();
        assert_eq!(s.rps, Some(10.5));
        assert_eq!(s.inflight, None);
        assert_eq!(s.shed_depth, None);
        assert_eq!(s.slo_ms, None);

        assert!(ModelSpec::parse("sim8:rps=0").is_err());
        assert!(ModelSpec::parse("sim8:rps=fast").is_err());
        assert!(ModelSpec::parse("sim8:inflight=0").is_err());
        assert!(ModelSpec::parse("sim8:inflight=-2").is_err());
        assert!(ModelSpec::parse("sim8:shed=0").is_err());
        assert!(ModelSpec::parse("sim8:quota=3").is_err(), "unknown key");
        assert!(ModelSpec::parse("sim8:rps").is_err(), "missing value");

        // the limits flow into the per-model config
        let base = Config::default();
        let cfg = ModelSpec::parse("sim8:rps=100:inflight=8:shed=32")
            .unwrap()
            .apply(&base);
        assert_eq!(cfg.rps, 100.0);
        assert_eq!(cfg.inflight, 8);
        assert_eq!(cfg.shed_depth, 32);
        let cfg = ModelSpec::parse("sim8").unwrap().apply(&base);
        assert_eq!(cfg.rps, base.rps, "no limits in the spec inherits");
    }

    #[test]
    fn admission_args_parse_and_roundtrip() {
        let args = Args::parse(
            "serve --models sim8 --rps 250 --admission-burst 16 --inflight 32 \
             --shed-depth 64 --shed-policy degrade --degrade-strategy slalom"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.rps, 250.0);
        assert_eq!(c.admission_burst, 16.0);
        assert_eq!(c.inflight, 32);
        assert_eq!(c.shed_depth, 64);
        assert_eq!(c.shed_policy, "degrade");
        assert_eq!(c.degrade_strategy, "slalom");
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.rps, 250.0);
        assert_eq!(c2.admission_burst, 16.0);
        assert_eq!(c2.inflight, 32);
        assert_eq!(c2.shed_depth, 64);
        assert_eq!(c2.shed_policy, "degrade");
        assert_eq!(c2.degrade_strategy, "slalom");

        // a bad shed policy is rejected on both config paths
        let bad = Args::parse(
            "serve --shed-policy drop"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        let dir = std::env::temp_dir().join("origami-test-admission-config");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, r#"{"shed_policy": "DROP"}"#).unwrap();
        assert!(Config::from_file(&path).is_err());
    }

    #[test]
    fn model_spec_apply_overrides_base() {
        let base = Config::default();
        let cfg = ModelSpec::parse("sim8=origami/4@gpu").unwrap().apply(&base);
        assert_eq!(cfg.model, "sim8");
        assert_eq!(cfg.strategy, "origami/4");
        assert_eq!(cfg.device, "gpu");
        let cfg = ModelSpec::parse("sim16").unwrap().apply(&base);
        assert_eq!(cfg.model, "sim16");
        assert_eq!(cfg.strategy, base.strategy, "unspecified parts inherit");
    }

    #[test]
    fn fabric_and_autoscale_args_parse() {
        let args = Args::parse(
            "serve --models sim8=origami/6:slo=20ms,sim16=slalom --lanes 4 --min-lanes 2 \
             --max-lanes 8 --lane-devices cpu,gpu --min-workers 1 --max-workers 6 \
             --autoscale --occupancy-flush --autoscale-high-depth 3 \
             --autoscale-policy p95 --autoscale-cooldown 4 --slo-ms 25 \
             --split-tail-ms 6.5 --split-tail-chunk 2"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.models, "sim8=origami/6:slo=20ms,sim16=slalom");
        assert_eq!(c.lanes, 4);
        assert_eq!(c.min_lanes, 2);
        assert_eq!(c.max_lanes, 8);
        assert_eq!(c.lane_devices, "cpu,gpu");
        assert_eq!(c.min_workers, 1);
        assert_eq!(c.max_workers, 6);
        assert!(c.autoscale);
        assert!(c.occupancy_flush);
        assert_eq!(c.autoscale_high_depth, 3);
        assert_eq!(c.autoscale_policy, "p95");
        assert_eq!(c.autoscale_cooldown, 4);
        assert_eq!(c.slo_ms, 25.0);
        assert_eq!(c.split_tail_ms, 6.5);
        assert_eq!(c.split_tail_chunk, 2);
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.models, c.models);
        assert_eq!(c2.lane_devices, c.lane_devices);
        assert_eq!(c2.max_lanes, c.max_lanes);
        assert!(c2.autoscale);
        assert!(c2.occupancy_flush);
        assert_eq!(c2.autoscale_policy, "p95");
        assert_eq!(c2.autoscale_cooldown, 4);
        assert_eq!(c2.slo_ms, 25.0);
        assert_eq!(c2.split_tail_ms, 6.5);
        assert_eq!(c2.split_tail_chunk, 2);
    }

    #[test]
    fn bad_autoscale_policy_rejected() {
        let args = Args::parse(
            "serve --autoscale-policy depth95"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(Config::from_args(&args).is_err());
    }

    #[test]
    fn epc_overcommit_parses_and_validates() {
        let args = Args::parse(
            "serve --models sim8 --epc-overcommit 1.25"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.epc_overcommit, 1.25);
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.epc_overcommit, 1.25);
        // defaults off
        assert_eq!(Config::default().epc_overcommit, 0.0);
        // negative is rejected
        let bad = Args::parse(
            "serve --epc-overcommit -1"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
    }

    #[test]
    fn factor_pool_args_parse_and_roundtrip() {
        // off by default: blinding runs inline unless opted in
        assert_eq!(Config::default().factor_pool_depth, 0);
        assert_eq!(Config::default().factor_prefill_workers, 2);
        let args = Args::parse(
            "serve --factor-pool-depth 16 --factor-prefill-workers 3"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.factor_pool_depth, 16);
        assert_eq!(c.factor_prefill_workers, 3);
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.factor_pool_depth, 16);
        assert_eq!(c2.factor_prefill_workers, 3);
    }

    #[test]
    fn flag_docs_cover_every_config_field() {
        // The drift gate behind the regenerated `--help`: every key the
        // config serializes must be documented in the flag table, every
        // documented json key must exist, and flags must be unique.
        let docs = Config::flag_docs();
        let Value::Obj(fields) = Config::default().to_json() else {
            panic!("config serializes to an object");
        };
        for (key, _) in &fields {
            assert!(
                docs.iter().any(|d| d.json_key == *key),
                "config field `{key}` missing from Config::flag_docs()"
            );
        }
        let mut seen = std::collections::BTreeSet::new();
        for doc in &docs {
            if !doc.json_key.is_empty() {
                assert!(
                    fields.iter().any(|(k, _)| k == doc.json_key),
                    "flag doc references unknown config field `{}`",
                    doc.json_key
                );
            }
            if !doc.flag.is_empty() {
                assert!(seen.insert(doc.flag), "duplicate flag `{}`", doc.flag);
                assert!(doc.flag.starts_with("--"));
            }
            assert!(!doc.help.is_empty(), "`{}` has no help text", doc.flag);
        }
    }

    #[test]
    fn spec_suffix_keys_match_the_parser() {
        // each declared key parses with a key-appropriate sample value…
        for key in SPEC_SUFFIX_KEYS {
            let value = if key == "tail" { "int8" } else { "5" };
            let spec = format!("sim8:{key}={value}");
            assert!(
                ModelSpec::parse(&spec).is_ok(),
                "declared suffix `{key}` must parse"
            );
        }
        // …and undeclared keys are rejected, so the const stays honest
        assert!(ModelSpec::parse("sim8:nope=5").is_err());
    }

    #[test]
    fn model_spec_parses_tail_suffix() {
        let s = ModelSpec::parse("sim8=origami/6:tail=int8").unwrap();
        assert_eq!(s.tail.as_deref(), Some("int8"));
        let s = ModelSpec::parse("sim8:tail=f32").unwrap();
        assert_eq!(s.tail.as_deref(), Some("f32"));
        assert!(ModelSpec::parse("sim8:tail=fp16").is_err());
        assert!(ModelSpec::parse("sim8:tail=").is_err());

        // flows into the per-model config; absent inherits the base
        let base = Config::default();
        let cfg = ModelSpec::parse("sim8:tail=int8").unwrap().apply(&base);
        assert_eq!(cfg.tail_precision, "int8");
        let cfg = ModelSpec::parse("sim8").unwrap().apply(&base);
        assert_eq!(cfg.tail_precision, base.tail_precision);
    }

    #[test]
    fn model_spec_parses_oblivious_suffix() {
        let s = ModelSpec::parse("sim8=origami/6:oblivious=on").unwrap();
        assert_eq!(s.oblivious, Some(true));
        let s = ModelSpec::parse("sim8:oblivious=off").unwrap();
        assert_eq!(s.oblivious, Some(false));
        assert!(ModelSpec::parse("sim8:oblivious=maybe").is_err());
        assert!(ModelSpec::parse("sim8:oblivious=").is_err());

        // flows into the per-model config; absent inherits the base
        let base = Config::default();
        let cfg = ModelSpec::parse("sim8:oblivious=on").unwrap().apply(&base);
        assert!(cfg.oblivious);
        let cfg = ModelSpec::parse("sim8:tail=int8:oblivious=on")
            .unwrap()
            .apply(&base);
        assert!(cfg.oblivious, "composes with other suffixes");
        assert_eq!(cfg.tail_precision, "int8");
        let cfg = ModelSpec::parse("sim8").unwrap().apply(&base);
        assert_eq!(cfg.oblivious, base.oblivious);
    }

    #[test]
    fn kernel_and_tail_args_parse_and_roundtrip() {
        assert_eq!(Config::default().kernel_threads, 0, "0 = auto");
        assert_eq!(Config::default().tail_precision, "f32");
        let args = Args::parse(
            "serve --kernel-threads 6 --tail-precision int8"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.kernel_threads, 6);
        assert_eq!(c.tail_precision, "int8");
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.kernel_threads, 6);
        assert_eq!(c2.tail_precision, "int8");
        // bad precision rejected on both config paths
        let bad = Args::parse(
            "serve --tail-precision fp16"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        let dir = std::env::temp_dir().join("origami-test-tail-config");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, r#"{"tail_precision": "FP16"}"#).unwrap();
        assert!(Config::from_file(&path).is_err());
    }

    #[test]
    fn net_args_parse_and_roundtrip() {
        let d = Config::default();
        assert!(d.listen.is_empty(), "no listener by default");
        assert_eq!(d.session_ttl_ms, 600_000);
        assert_eq!(d.session_shards, 64);
        let args = Args::parse(
            "serve --listen 127.0.0.1:7070 --session-ttl 30000 --session-shards 128"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = Config::from_args(&args).unwrap();
        assert_eq!(c.listen, "127.0.0.1:7070");
        assert_eq!(c.session_ttl_ms, 30_000);
        assert_eq!(c.session_shards, 128);
        // round-trips through JSON
        let v = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&v);
        assert_eq!(c2.listen, "127.0.0.1:7070");
        assert_eq!(c2.session_ttl_ms, 30_000);
        assert_eq!(c2.session_shards, 128);
        // zero shards is rejected — the table needs at least one stripe
        let bad = Args::parse(
            "serve --session-shards 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
    }

    #[test]
    fn non_default_settings_reflect_overrides_only() {
        let base = Config::default();
        assert!(base.non_default_settings().is_empty());
        let c = Config {
            rps: 250.0,
            autoscale: true,
            epc_overcommit: 1.0,
            ..Config::default()
        };
        let diffs = c.non_default_settings();
        let keys: Vec<&str> = diffs.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["autoscale", "rps", "epc_overcommit"]);
        assert!(diffs.iter().any(|(k, v)| k == "rps" && v == "250"));
    }

    #[test]
    fn config_file_loads() {
        let dir = std::env::temp_dir().join("origami-test-config");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        std::fs::write(&path, r#"{"model": "vgg19-32", "max_delay_ms": 7.5}"#).unwrap();
        let c = Config::from_file(&path).unwrap();
        assert_eq!(c.model, "vgg19-32");
        assert_eq!(c.max_delay_ms, 7.5);
        // a bad autoscale_policy is rejected at load time on the file
        // path too — a typo must not silently fall back to depth scaling
        let bad = dir.join("bad.json");
        std::fs::write(&bad, r#"{"autoscale_policy": "P95"}"#).unwrap();
        assert!(Config::from_file(&bad).is_err());
    }
}
