//! Launcher: assemble the full stack (backend → executor → strategy →
//! serving engine / worker pool) from a [`Config`].  Shared by the CLI,
//! the examples and the benches.
//!
//! Two backends, picked by model name:
//! - `sim*` models (e.g. `sim8`) run on the hermetic pure-Rust
//!   [`ReferenceBackend`] — no artifacts, no PJRT, fully deterministic.
//! - everything else loads compiled HLO artifacts through the PJRT
//!   client ([`Stack::load`]).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{Config, ModelSpec};
use crate::coordinator::scheduler::{BatchScheduler, Tier2Finisher};
use crate::coordinator::{
    AdmissionLimits, AutoscalePolicy, DeploySpec, Deployment, EpcOptions, FabricOptions,
    NetOptions, NetServer, PoolOptions, ScaleMode, ServingEngine, SessionTable, ShedPolicy,
    SplitPolicy, TrackMembership, TrackOptions, TrackRegistry, WorkerPool,
};
use crate::enclave::cost::CostModel;
use crate::model::{Manifest, Model};
use crate::runtime::reference::is_sim_model;
use crate::runtime::{
    ArtifactRegistry, Device, PjrtClient, ReferenceBackend, StageExecutor, TailPrecision,
};
use crate::strategies::{self, Strategy, StrategyCtx};

/// The assembled, strategy-agnostic lower stack.
pub struct Stack {
    pub client: Arc<PjrtClient>,
    pub manifest: Arc<Manifest>,
    pub registry: Arc<ArtifactRegistry>,
    pub executor: Arc<StageExecutor>,
}

impl Stack {
    /// Build the PJRT client + artifact registry once per process.
    pub fn load(config: &Config) -> Result<Self> {
        let client = Arc::new(PjrtClient::cpu().context("creating PJRT CPU client")?);
        let manifest = Arc::new(
            Manifest::load(&config.artifacts).context("loading artifacts manifest")?,
        );
        let registry = Arc::new(ArtifactRegistry::new(client.clone(), manifest.clone()));
        let executor = Arc::new(StageExecutor::new(registry.clone(), CostModel::default()));
        Ok(Self {
            client,
            manifest,
            registry,
            executor,
        })
    }

    pub fn model(&self, name: &str) -> Result<Arc<Model>> {
        Ok(Arc::new(self.manifest.model(name)?.clone()))
    }

    /// Build + set up one strategy instance per the config.
    pub fn build_strategy(&self, config: &Config) -> Result<Box<dyn Strategy>> {
        let model = self.model(&config.model)?;
        build_strategy_with(self.executor.clone(), model, config)
    }

    /// Plaintext image bytes per sample for a model.
    pub fn sample_bytes(&self, model: &str) -> Result<usize> {
        let m = self.manifest.model(model)?;
        Ok(4 * m.image * m.image * m.in_channels)
    }

    /// Batch sizes exported for the full/tail stages of a model.
    pub fn artifact_batches(&self, model: &str) -> Result<Vec<usize>> {
        Ok(self.manifest.model(model)?.serving_batches())
    }

    /// Spin up a serving engine with `config.workers` independent
    /// strategy instances.  Each worker thread builds its *own* Stack
    /// (PJRT client + compiled artifacts + enclave + factor pools): the
    /// `xla` crate's handles are thread-local by construction.
    pub fn start_engine(&self, config: &Config) -> Result<ServingEngine> {
        let sample_bytes = self.sample_bytes(&config.model)?;
        let batches = self.artifact_batches(&config.model)?;
        start_engine_from_config(config.clone(), sample_bytes, batches)
    }

    /// Spin up a sharded worker pool per the config (see
    /// [`start_pool_from_config`]).
    pub fn start_pool(&self, config: &Config) -> Result<WorkerPool> {
        start_pool_from_config(config.clone())
    }
}

/// Build the executor + model for a config, on whichever backend the
/// model name selects (`sim*` → reference interpreter, else artifacts).
/// Also publishes the config's `--kernel-threads` cap to the shared
/// kernel-thread governor, so every kernel the executor runs draws from
/// the same process-wide budget.
pub fn executor_for(config: &Config) -> Result<(Arc<StageExecutor>, Arc<Model>)> {
    crate::util::threadpool::set_kernel_thread_cap(config.kernel_threads);
    if is_sim_model(&config.model) {
        let rb = Arc::new(ReferenceBackend::vgg_lite(&config.model, config.seed)?);
        let model = Arc::new(rb.model().clone());
        let mut executor = StageExecutor::reference(rb, CostModel::default());
        if config.tail_precision == "int8" {
            executor = executor.with_tail_precision(TailPrecision::Int8);
        }
        if config.oblivious {
            executor = executor.with_oblivious(true);
        }
        Ok((Arc::new(executor), model))
    } else {
        anyhow::ensure!(
            config.tail_precision != "int8",
            "model {}: `--tail-precision int8` needs a sim* model \
             (no int8 HLO artifacts are exported)",
            config.model
        );
        anyhow::ensure!(
            !config.oblivious,
            "model {}: `--oblivious` needs a sim* model (the compiled HLO \
             artifacts keep their branchy kernels)",
            config.model
        );
        let stack = Stack::load(config)?;
        let model = stack.model(&config.model)?;
        Ok((stack.executor, model))
    }
}

/// Build + set up a strategy on an already-constructed executor.
pub fn build_strategy_with(
    executor: Arc<StageExecutor>,
    model: Arc<Model>,
    config: &Config,
) -> Result<Box<dyn Strategy>> {
    let ctx = StrategyCtx::new(executor, model, config.clone())?;
    let mut s = strategies::build(ctx, &config.strategy, config.partition)?;
    s.setup()
        .with_context(|| format!("setting up strategy {}", s.name()))?;
    Ok(s)
}

/// Build a complete [`BatchScheduler`] (backend + strategy + batch
/// policy) for a config — one call per worker thread.
pub fn scheduler_for(config: &Config) -> Result<BatchScheduler> {
    let (executor, model) = executor_for(config)?;
    let sample_bytes = 4 * model.image * model.image * model.in_channels;
    let batches = model.serving_batches();
    let strategy = build_strategy_with(executor, model, config)?;
    Ok(BatchScheduler::new(strategy, sample_bytes, batches))
}

/// Build a keyless tier-2 finisher for a config — one call per tier-2
/// lane thread.
pub fn finisher_for(config: &Config) -> Result<Tier2Finisher> {
    let (executor, model) = executor_for(config)?;
    Ok(Tier2Finisher::new(
        executor,
        &model.name,
        Device::parse(&config.device)?,
    ))
}

/// Start a serving engine without a pre-built Stack; every worker builds
/// its own backend inside its thread.
pub fn start_engine_from_config(
    config: Config,
    sample_bytes: usize,
    artifact_batches: Vec<usize>,
) -> Result<ServingEngine> {
    let workers = config.workers.max(1);
    let max_batch = config.max_batch;
    let max_delay = config.max_delay_ms;
    Ok(ServingEngine::start(
        workers,
        max_batch,
        max_delay,
        move |_worker| {
            let (executor, model) = executor_for(&config)?;
            let strategy = build_strategy_with(executor, model, &config)?;
            Ok(BatchScheduler::new(
                strategy,
                sample_bytes,
                artifact_batches.clone(),
            ))
        },
    ))
}

/// Pool geometry/policy from a config (min/max worker bounds feed the
/// deployment autoscaler; 0 means "pin at `workers`").
pub fn pool_options_from_config(config: &Config) -> PoolOptions {
    PoolOptions {
        workers: config.workers.max(1),
        min_workers: config.min_workers,
        max_workers: config.max_workers,
        max_batch: config.max_batch,
        max_delay_ms: config.max_delay_ms,
        pipeline: config.pipeline,
        occupancy_flush: config.occupancy_flush,
        slo_ms: config.slo_ms,
        ..PoolOptions::default()
    }
}

/// Lane-fabric geometry from a config: the lane device cycle comes from
/// `lane_devices` (falling back to the config device), so tier-2 lanes
/// carry explicit per-lane cost profiles instead of inheriting whatever
/// the model was configured with.
pub fn fabric_options_from_config(config: &Config) -> Result<FabricOptions> {
    let devices = if config.lane_devices.trim().is_empty() {
        vec![Device::parse(&config.device)?]
    } else {
        config
            .lane_devices
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| Device::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?
    };
    Ok(FabricOptions {
        lanes: if config.lanes == 0 {
            config.workers.max(1)
        } else {
            config.lanes
        },
        min_lanes: config.min_lanes,
        max_lanes: config.max_lanes,
        lane_devices: devices,
        split: SplitPolicy {
            max_task_ms: config.split_tail_ms,
            max_chunk: config.split_tail_chunk,
        },
        ..FabricOptions::default()
    })
}

/// Autoscaler thresholds from a config (`autoscale_policy` selects the
/// depth rule or the p95-vs-SLO rule).
pub fn autoscale_policy_from_config(config: &Config) -> AutoscalePolicy {
    AutoscalePolicy {
        high_depth_per_worker: config.autoscale_high_depth.max(1),
        low_depth_per_worker: config.autoscale_low_depth,
        tick_ms: config.autoscale_tick_ms.max(1),
        mode: if config.autoscale_policy == "p95" {
            ScaleMode::SloP95
        } else {
            ScaleMode::Depth
        },
        cooldown_ticks: config.autoscale_cooldown as u64,
        ..AutoscalePolicy::default()
    }
}

/// Per-tenant admission limits from a config (`:rps=`/`:inflight=`/
/// `:shed=` spec suffixes land in the per-model config first).
pub fn admission_limits_from_config(config: &Config) -> AdmissionLimits {
    AdmissionLimits {
        rps: config.rps.max(0.0),
        burst: config.admission_burst.max(0.0),
        inflight: config.inflight,
        shed_depth: config.shed_depth,
    }
}

/// Shed policy from a config (`--shed-policy reject|degrade`).
pub fn shed_policy_from_config(config: &Config) -> ShedPolicy {
    if config.shed_policy == "degrade" {
        ShedPolicy::Degrade
    } else {
        ShedPolicy::Reject
    }
}

/// EPC co-scheduling geometry from a config: `--epc-overcommit 0`
/// disables the ledger entirely; anything above packs tier-1 pools into
/// `usable_epc_bytes() × overcommit`.
pub fn epc_options_from_config(config: &Config) -> Option<EpcOptions> {
    (config.epc_overcommit > 0.0).then(|| EpcOptions {
        usable_bytes: config.usable_epc_bytes(),
        overcommit: config.epc_overcommit,
    })
}

/// Estimate one tier-1 worker's resident enclave footprint — what the
/// EPC ledger charges per worker.  This is the Table-I decomposition
/// ([`crate::strategies::memory::enclave_requirement`]) over the plan
/// the strategy name describes
/// ([`strategies::partition_plan_for`](crate::strategies::partition_plan_for)):
/// base runtime + plan-resident parameters + lazy-dense chunk + peak
/// feature-map working set + blinding buffers, evaluated at the
/// batcher's `max_batch` (the worst residency a worker can reach).
/// Strategies without an enclave (`open`) cost 0.
///
/// When the blinding-factor precompute pipeline is on
/// (`--factor-pool-depth > 0`), each blinded layer additionally stages
/// `depth` epochs of pads + unblinding factors in enclave memory
/// ([`crate::blinding::pool::shape_bytes`]), so pool depth trades
/// transparently against how many tier-1 workers the EPC ledger admits.
pub fn worker_epc_bytes_for(model: &Model, config: &Config) -> Result<u64> {
    let Some(plan) =
        strategies::partition_plan_for(model, &config.strategy, config.partition)?
    else {
        return Ok(0);
    };
    let req = crate::strategies::memory::enclave_requirement(
        model,
        &plan,
        config.lazy_dense_bytes,
        config.max_batch.max(1),
    );
    let mut total = req.total();
    if config.factor_pool_depth > 0 {
        let depth = config.factor_pool_depth.min(config.pool_epochs).max(1);
        for idx in plan.blinded_layers() {
            let layer = model.layer(idx)?;
            total += depth
                * crate::blinding::pool::shape_bytes(layer.in_elems(), layer.out_elems());
        }
    }
    Ok(total)
}

/// [`worker_epc_bytes_for`] for callers without a loaded model (tests,
/// benches): resolves the model geometry from the config first.
pub fn worker_epc_bytes_from_config(config: &Config) -> Result<u64> {
    let (_, model) = executor_for(config)?;
    worker_epc_bytes_for(&model, config)
}

/// Device-side resident footprint of a model's tier-2 tail weights —
/// the parameters of every `OpenOffload` layer in the strategy's
/// partition plan (every layer when the strategy runs fully open).
/// These live *outside* the enclave, so they never enter the EPC
/// ledger, but they are exactly the bytes the `:tail=int8` opt-in
/// shrinks: int8 weights are a quarter of the f32 footprint (biases
/// stay f32 and are counted at full width).
pub fn tail_resident_bytes_for(model: &Model, config: &Config) -> Result<u64> {
    use crate::model::partition::Placement;
    let plan = strategies::partition_plan_for(model, &config.strategy, config.partition)?;
    let mut weights = 0u64;
    let mut biases = 0u64;
    for l in &model.layers {
        let open = match &plan {
            Some(p) => p.placement(l.index) == Placement::OpenOffload,
            None => true,
        };
        if open {
            let bias_bytes = 4 * l.bias.len() as u64;
            weights += l.params_bytes.saturating_sub(bias_bytes);
            biases += bias_bytes;
        }
    }
    Ok(if config.tail_precision == "int8" {
        weights / 4 + biases
    } else {
        weights + biases
    })
}

/// Keyspace stride between tenants' blinding domains: tenant *t*'s pool
/// draws its workers' domains from `t·STRIDE + incarnation`, where the
/// incarnation index is the pool's monotone spawn counter (never reused,
/// even when an autoscaled shard is retired and respawned).  No two
/// enclaves in a deployment — same model or not, same slot or not — can
/// ever derive the same pad stream, as long as a pool never performs
/// 2^32 spawns (an autoscaler flapping once per millisecond would need
/// ~50 days; the counter is checked nowhere near that in practice).
pub const BLIND_DOMAIN_STRIDE: u64 = 1 << 32;

/// Start the sharded worker pool: `config.workers` enclave shards with
/// session-affinity routing, disjoint per-worker blinding domains, and
/// (when `config.pipeline`) double-buffered tier-1/tier-2 execution with
/// work-stealing tier-2 lanes.
pub fn start_pool_from_config(config: Config) -> Result<WorkerPool> {
    let opts = pool_options_from_config(&config);
    let sched_cfg = config.clone();
    let fin_cfg = config;
    Ok(WorkerPool::start(
        opts,
        move |domain| {
            // Pool-unique domain index = blinding domain: pads never
            // repeat across shards (or shard incarnations) even though
            // all shards share the deployment master.
            let mut c = sched_cfg.clone();
            c.blind_domain = domain as u64;
            scheduler_for(&c)
        },
        move |_lane| finisher_for(&fin_cfg),
    ))
}

/// Name suffix of a model's degraded-tier tenant (internal routing key;
/// clients keep submitting under the primary model name).
pub const DEGRADE_TENANT_SUFFIX: &str = "~degraded";

/// Weighted-fair share of the shared lanes a model's degraded tier gets,
/// as a fraction of the primary's weight.  Spillover is best-effort: it
/// must not let an overloaded model double its cross-tenant share by
/// fielding two tenants (the default `baseline2` tier adds no tier-2
/// compute, but any other `--degrade-strategy` would).
pub const DEGRADE_WEIGHT_FRACTION: f64 = 0.25;

/// Register `config.model` in a deployment: probes the model geometry,
/// attaches the model to the shared lane fabric with `weight`, and
/// starts its tier-1 pool with the config's admission limits.  The
/// deployment assigns the tenant's keyspace band under its registry
/// lock; each worker incarnation then blinds under
/// `band · BLIND_DOMAIN_STRIDE + domain` — disjoint across models,
/// workers, and respawns.
///
/// Under `--shed-policy degrade` (with a shed threshold configured), a
/// second tenant named `{model}~degraded` is deployed running
/// `config.degrade_strategy` over the same model geometry, and shed
/// requests reroute to it instead of being rejected.  The default
/// degrade tier, `baseline2`, keeps the whole network inside the
/// enclave: its tails are pass-through `Final` tasks that add no tier-2
/// compute, so an overloaded tenant's spillover cannot crowd the shared
/// lanes either.
pub fn deploy_from_config(dep: &Deployment, config: &Config, weight: f64) -> Result<()> {
    let (_, model) = executor_for(config)?;
    let sample_bytes = 4 * model.image * model.image * model.in_channels;
    let sched_cfg = config.clone();
    let fin_cfg = config.clone();
    let slo_ms = (config.slo_ms > 0.0).then_some(config.slo_ms);
    let limits = admission_limits_from_config(config);
    let shed_policy = shed_policy_from_config(config);
    let mut pool_opts = pool_options_from_config(config);
    if dep.epc_ledger().is_some() {
        pool_opts.worker_epc_bytes = worker_epc_bytes_for(&model, config)?;
    }
    let cost_multiplier = if config.oblivious {
        crate::runtime::reference::OBLIVIOUS_COST_MULTIPLIER
    } else {
        1.0
    };
    dep.deploy_model(
        DeploySpec::new(&config.model, sample_bytes)
            .weight(weight)
            .slo_ms(slo_ms)
            .admission(limits)
            .shed_policy(shed_policy)
            .cost_multiplier(cost_multiplier)
            .pool(pool_opts),
        move |band, domain| {
            let mut c = sched_cfg.clone();
            c.blind_domain = band * BLIND_DOMAIN_STRIDE + domain as u64;
            scheduler_for(&c)
        },
        move |_lane| finisher_for(&fin_cfg),
    )?;
    if shed_policy == ShedPolicy::Degrade && limits.shed_depth > 0 {
        let degraded = format!("{}{}", config.model, DEGRADE_TENANT_SUFFIX);
        let mut dcfg = config.clone();
        dcfg.strategy = config.degrade_strategy.clone();
        // the degraded tier is best-effort spillover: no SLO, no limits
        dcfg.slo_ms = 0.0;
        let dsched_cfg = dcfg.clone();
        let dfin_cfg = dcfg.clone();
        let mut dpool_opts = pool_options_from_config(&dcfg);
        if dep.epc_ledger().is_some() {
            // the degraded tier's enclaves live in the same EPC (same
            // model geometry, different strategy → different plan)
            dpool_opts.worker_epc_bytes = worker_epc_bytes_for(&model, &dcfg)?;
        }
        dep.deploy_model(
            DeploySpec::new(&degraded, sample_bytes)
                .weight(weight * DEGRADE_WEIGHT_FRACTION)
                // explicit: spillover must stay unthrottled even if the
                // deployment carries a default admission policy
                .admission(AdmissionLimits::default())
                .cost_multiplier(cost_multiplier)
                .pool(dpool_opts),
            move |band, domain| {
                let mut c = dsched_cfg.clone();
                c.blind_domain = band * BLIND_DOMAIN_STRIDE + domain as u64;
                // tier-1 still tags tasks by the request's model string,
                // which is the degraded tenant name on this path
                scheduler_for(&c)
            },
            move |_lane| finisher_for(&dfin_cfg),
        )?;
        dep.set_degrade(&config.model, &degraded)?;
    }
    Ok(())
}

/// Assemble a full multi-model deployment: one shared lane fabric, one
/// attached tier-1 pool per spec, and (when `base.autoscale`) the
/// background queue-depth autoscaler.
pub fn start_deployment_from_config(base: &Config, specs: &[ModelSpec]) -> Result<Deployment> {
    let mut dep = Deployment::builder(fabric_options_from_config(base)?)
        .policy(autoscale_policy_from_config(base))
        .epc(epc_options_from_config(base))
        .sessions(SessionTable::with_capacity(
            base.session_shards,
            base.session_ttl_ms,
            base.session_cap,
        ))
        .sweep_every_ms(base.session_sweep_ms)
        .build();
    for spec in specs {
        let cfg = spec.apply(base);
        deploy_from_config(&dep, &cfg, spec.weight)?;
    }
    if base.autoscale {
        dep.enable_autoscaler();
    }
    Ok(dep)
}

/// Network front-door options from a config.  The measurement and
/// platform key are the simulator's well-known constants
/// ([`NetOptions::default`]) — in a real SGX deployment these would
/// come from the quoting enclave; here both ends of the loopback agree
/// on them so the handshake exercises the full verify path.
pub fn net_options_from_config(config: &Config) -> NetOptions {
    NetOptions {
        listen: config.listen.clone(),
        ..NetOptions::default()
    }
}

/// Start the attested TCP front door over a deployment, when the config
/// asks for one (`--listen`).  Returns `None` when `listen` is empty.
/// With a track registry the front door also answers track-join frames
/// (the transport `--track-peers` joins through).
pub fn start_net_server(
    dep: &Arc<Deployment>,
    config: &Config,
    tracks: Option<Arc<TrackRegistry>>,
) -> Result<Option<NetServer>> {
    if config.listen.trim().is_empty() {
        return Ok(None);
    }
    let server =
        NetServer::start_with_tracks(dep.clone(), net_options_from_config(config), tracks)?;
    Ok(Some(server))
}

/// Track attestation parameters from a config — the same well-known
/// constants the front door uses ([`TrackOptions::default`]): joins and
/// client HELLOs verify against one measurement.
pub fn track_options_from_config(_config: &Config) -> TrackOptions {
    TrackOptions::default()
}

/// What `--track` wires up on a serving node.
pub struct TrackRuntime {
    /// The node's local registry — the front door answers join frames
    /// from it, so later nodes can join through this one.
    pub registry: Arc<TrackRegistry>,
    /// This node's membership (keys + monotone incarnation).
    pub membership: TrackMembership,
}

/// Establish this node's track membership per the config: `--track`
/// with no peers claims the track fresh (genesis — mints the key
/// material); `--track-peers` joins over the wire through an existing
/// member's front door, trying each peer in order.  Empty `--track` is
/// single-node serving: returns `None`.
///
/// Peers listed but all unreachable is an **error**, not a genesis
/// fallback — silently minting fresh keys would fork the track into two
/// key domains that cannot serve each other's sessions.
pub fn start_track_from_config(config: &Config) -> Result<Option<TrackRuntime>> {
    let track = config.track.trim();
    if track.is_empty() {
        return Ok(None);
    }
    let opts = track_options_from_config(config);
    let node = if config.listen.trim().is_empty() {
        "local".to_string()
    } else {
        config.listen.clone()
    };
    let registry = Arc::new(TrackRegistry::new(config.seed, opts.clone()));
    let peers: Vec<&str> = config
        .track_peers
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if peers.is_empty() {
        let membership = registry.claim(track, &node);
        return Ok(Some(TrackRuntime {
            registry,
            membership,
        }));
    }
    let mut last_err = None;
    for peer in peers {
        match join_track_via(peer, &opts, track, &node) {
            Ok(membership) => {
                return Ok(Some(TrackRuntime {
                    registry,
                    membership,
                }))
            }
            Err(e) => last_err = Some(e.context(format!("joining via {peer}"))),
        }
    }
    Err(last_err.unwrap().context(format!(
        "no --track-peers member of track `{track}` was reachable"
    )))
}

/// One wire join attempt against a member's front door: send the framed
/// join request, verify the grant, open the sealed track keys.  Both
/// ends judge report freshness on wall-clock UNIX time
/// ([`wall_now_ms`](crate::coordinator::track::wall_now_ms)), so cross-
/// host skew up to the attestation TTL is tolerated.
fn join_track_via(
    peer: &str,
    opts: &TrackOptions,
    track: &str,
    node: &str,
) -> Result<TrackMembership> {
    use crate::coordinator::track;
    use std::hash::{BuildHasher, Hasher};
    use std::io::Write;
    let mut stream = std::net::TcpStream::connect(peer)
        .with_context(|| format!("connecting to track peer {peer}"))?;
    stream.set_nodelay(true).ok();
    let now_ms = track::wall_now_ms();
    // fresh challenge per attempt (hashmap RandomState = per-process
    // random seed; folding the clock decorrelates retries)
    let mut h = std::collections::hash_map::RandomState::new().build_hasher();
    h.write_u64(now_ms);
    let challenge = h.finish();
    let frame = track::join_request(opts, track, node, challenge, now_ms);
    stream.write_all(&frame)?;
    let (ty, payload) = crate::coordinator::net::read_frame(&mut stream)?;
    let mut reply = Vec::with_capacity(payload.len() + 5);
    crate::coordinator::net::write_frame(&mut reply, ty, &payload)?;
    // same now_ms as the request: the grant's wrap key derives from the
    // joiner's quote, which is deterministic in (challenge, timestamp)
    track::accept_grant(opts, track, node, challenge, &reply, now_ms)
        .map_err(anyhow::Error::from)
}

/// Encrypt a plaintext image for `session` under the deployment seed —
/// the client side of the attested channel.
pub fn encrypt_request(config: &Config, session: u64, image: &[f32]) -> Vec<u8> {
    crate::enclave::Enclave::encrypt_for_session(
        &config.seed.to_le_bytes(),
        session,
        image,
    )
}

/// Deterministic synthetic image batch (structured, not white noise —
/// gradients + blocks, mirroring python/compile/data.py's spirit).
pub fn synth_images(n: usize, image: usize, channels: usize, seed: u64) -> Vec<Vec<f32>> {
    use crate::util::rng::Rng;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = Rng::with_stream(seed, i as u64);
        let mut img = vec![0f32; image * image * channels];
        // gradient background
        let horizontal = rng.below(2) == 0;
        let c0: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let c1: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
        for y in 0..image {
            for x in 0..image {
                let t = if horizontal {
                    x as f32 / image as f32
                } else {
                    y as f32 / image as f32
                };
                for c in 0..channels {
                    img[(y * image + x) * channels + c] = c0[c] * (1.0 - t) + c1[c] * t;
                }
            }
        }
        // a few random rectangles
        for _ in 0..(2 + rng.below(3)) {
            let x0 = rng.below(image as u32 - 2) as usize;
            let y0 = rng.below(image as u32 - 2) as usize;
            let w = 2 + rng.below((image / 2) as u32) as usize;
            let h = 2 + rng.below((image / 2) as u32) as usize;
            let col: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
            for y in y0..(y0 + h).min(image) {
                for x in x0..(x0 + w).min(image) {
                    for c in 0..channels {
                        img[(y * image + x) * channels + c] = col[c];
                    }
                }
            }
        }
        out.push(img);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_scheduler_builds_and_serves_one_request() {
        let cfg = Config {
            model: "sim8".into(),
            strategy: "origami/6".into(),
            pool_epochs: 4,
            ..Config::default()
        };
        let mut sched = scheduler_for(&cfg).unwrap();
        assert!(sched.tiered(), "origami splits into tiers");
        assert_eq!(sched.sample_bytes, 4 * 8 * 8 * 3);
        let img = &synth_images(1, 8, 3, cfg.seed)[0];
        let ct = encrypt_request(&cfg, 0, img);
        let (req, reply) = crate::coordinator::InferRequest::new(1, "sim8", ct, 0);
        let rec = sched.execute(vec![req]).unwrap();
        assert_eq!(rec.batch, 1);
        assert!(rec.sim_ms > 0.0);
        let resp = reply.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.probs.len(), 10);
        let sum: f32 = resp.probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-3, "softmax sums to 1: {sum}");
    }

    /// Hermetic version of the artifact-gated strategy agreement test:
    /// every strategy run on the reference backend must land close to
    /// the open (non-private) reference on the same encrypted input.
    #[test]
    fn sim_strategies_agree_with_open_reference() {
        let base = Config {
            model: "sim8".into(),
            pool_epochs: 4,
            ..Config::default()
        };
        let img = &synth_images(1, 8, 3, base.seed)[0];
        let run = |strategy: &str| -> Vec<f32> {
            let mut cfg = base.clone();
            cfg.strategy = strategy.into();
            let (executor, model) = executor_for(&cfg).unwrap();
            let mut s = build_strategy_with(executor, model, &cfg).unwrap();
            let ct = encrypt_request(&cfg, 0, img);
            s.infer(&ct, 1, &[0], &mut crate::enclave::cost::Ledger::new())
                .unwrap()
        };
        let open = run("open");
        assert_eq!(open.len(), 10);
        for strategy in ["baseline2", "split/6", "slalom", "origami/6"] {
            let probs = run(strategy);
            let diff = probs
                .iter()
                .zip(&open)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // blinded tiers quantize activations to 2^-8 per layer
            assert!(diff < 0.05, "{strategy}: max diff {diff}");
        }
    }

    #[test]
    fn factor_pool_depth_raises_worker_epc_charge() {
        let base = Config {
            model: "sim8".into(),
            strategy: "origami/6".into(),
            pool_epochs: 8,
            ..Config::default()
        };
        let (_, model) = executor_for(&base).unwrap();
        let inline = worker_epc_bytes_for(&model, &base).unwrap();

        let mut pooled = base.clone();
        pooled.factor_pool_depth = 4;
        let charged = worker_epc_bytes_for(&model, &pooled).unwrap();
        let plan = strategies::partition_plan_for(&model, &pooled.strategy, pooled.partition)
            .unwrap()
            .unwrap();
        let staged: u64 = plan
            .blinded_layers()
            .iter()
            .map(|&i| {
                let l = model.layer(i).unwrap();
                crate::blinding::pool::shape_bytes(l.in_elems(), l.out_elems())
            })
            .sum();
        assert_eq!(charged, inline + 4 * staged);
        assert!(staged > 0, "origami plan stages at least one blinded layer");

        // depth clamps to the unblinding store's epoch count
        let mut deep = pooled.clone();
        deep.factor_pool_depth = 1_000;
        assert_eq!(
            worker_epc_bytes_for(&model, &deep).unwrap(),
            inline + base.pool_epochs * staged
        );

        // strategies with no blinded layers never pay the charge
        let mut split = pooled.clone();
        split.strategy = "split/6".into();
        let mut split_inline = split.clone();
        split_inline.factor_pool_depth = 0;
        assert_eq!(
            worker_epc_bytes_for(&model, &split).unwrap(),
            worker_epc_bytes_for(&model, &split_inline).unwrap()
        );
    }

    #[test]
    fn int8_tails_shrink_the_device_resident_footprint() {
        use crate::model::partition::Placement;
        let base = Config {
            model: "sim8".into(),
            strategy: "origami/6".into(),
            ..Config::default()
        };
        let (_, model) = executor_for(&base).unwrap();
        let f32_bytes = tail_resident_bytes_for(&model, &base).unwrap();
        let mut quant = base.clone();
        quant.tail_precision = "int8".into();
        let i8_bytes = tail_resident_bytes_for(&model, &quant).unwrap();

        // recompute the exact expectation from the plan: int8 quarters
        // the weight bytes of every OpenOffload layer, biases stay f32
        let plan = strategies::partition_plan_for(&model, &base.strategy, base.partition)
            .unwrap()
            .unwrap();
        let (mut weights, mut biases) = (0u64, 0u64);
        for l in &model.layers {
            if plan.placement(l.index) == Placement::OpenOffload {
                let bias = 4 * l.bias.len() as u64;
                weights += l.params_bytes - bias;
                biases += bias;
            }
        }
        assert!(weights > 0, "origami/6 offloads at least one tail layer");
        assert_eq!(f32_bytes, weights + biases);
        assert_eq!(i8_bytes, weights / 4 + biases);
        assert!(i8_bytes < f32_bytes);

        // the enclave-side EPC charge is untouched: tails live off-EPC
        assert_eq!(
            worker_epc_bytes_for(&model, &base).unwrap(),
            worker_epc_bytes_for(&model, &quant).unwrap()
        );

        // fully-open strategies count every layer's parameters
        let mut open = base.clone();
        open.strategy = "open".into();
        let all = tail_resident_bytes_for(&model, &open).unwrap();
        assert!(all > f32_bytes);

        // int8 tails are sim-only: the artifact path is rejected early
        let mut arti = quant.clone();
        arti.model = "vgg16-32".into();
        assert!(executor_for(&arti).is_err());
    }

    #[test]
    fn synth_images_structured_and_deterministic() {
        let a = synth_images(2, 16, 3, 42);
        let b = synth_images(2, 16, 3, 42);
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 16 * 16 * 3);
        assert!(a[0].iter().all(|v| (0.0..=1.0).contains(v)));
        // neighboring-pixel smoothness (structure, not noise)
        let img = &a[0];
        let mut diff = 0.0f32;
        for i in 0..(16 * 15 * 3) {
            diff += (img[i] - img[i + 3 * 16]).abs();
        }
        assert!(diff / (16.0 * 15.0 * 3.0) < 0.2);
    }
}
