//! Launcher: assemble the full stack (PJRT client → registry → executor
//! → strategy → serving engine) from a [`Config`].  Shared by the CLI,
//! the examples and the benches.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::Config;
use crate::coordinator::scheduler::BatchScheduler;
use crate::coordinator::ServingEngine;
use crate::enclave::cost::CostModel;
use crate::model::{Manifest, Model};
use crate::runtime::{ArtifactRegistry, PjrtClient, StageExecutor};
use crate::strategies::{self, Strategy, StrategyCtx};

/// The assembled, strategy-agnostic lower stack.
pub struct Stack {
    pub client: Arc<PjrtClient>,
    pub manifest: Arc<Manifest>,
    pub registry: Arc<ArtifactRegistry>,
    pub executor: Arc<StageExecutor>,
}

impl Stack {
    /// Build the PJRT client + artifact registry once per process.
    pub fn load(config: &Config) -> Result<Self> {
        let client = Arc::new(PjrtClient::cpu().context("creating PJRT CPU client")?);
        let manifest = Arc::new(
            Manifest::load(&config.artifacts).context("loading artifacts manifest")?,
        );
        let registry = Arc::new(ArtifactRegistry::new(client.clone(), manifest.clone()));
        let executor = Arc::new(StageExecutor::new(registry.clone(), CostModel::default()));
        Ok(Self {
            client,
            manifest,
            registry,
            executor,
        })
    }

    pub fn model(&self, name: &str) -> Result<Arc<Model>> {
        Ok(Arc::new(self.manifest.model(name)?.clone()))
    }

    /// Build + set up one strategy instance per the config.
    pub fn build_strategy(&self, config: &Config) -> Result<Box<dyn Strategy>> {
        let model = self.model(&config.model)?;
        let ctx = StrategyCtx::new(self.executor.clone(), model, config.clone())?;
        let mut s = strategies::build(ctx, &config.strategy, config.partition)?;
        s.setup()
            .with_context(|| format!("setting up strategy {}", s.name()))?;
        Ok(s)
    }

    /// Plaintext image bytes per sample for a model.
    pub fn sample_bytes(&self, model: &str) -> Result<usize> {
        let m = self.manifest.model(model)?;
        Ok(4 * m.image * m.image * m.in_channels)
    }

    /// Batch sizes exported for the full/tail stages of a model.
    pub fn artifact_batches(&self, model: &str) -> Result<Vec<usize>> {
        let m = self.manifest.model(model)?;
        let mut b = m.batches_for("full_open");
        if b.is_empty() {
            b.push(1);
        }
        Ok(b)
    }

    /// Spin up a serving engine with `config.workers` independent
    /// strategy instances.  Each worker thread builds its *own* Stack
    /// (PJRT client + compiled artifacts + enclave + factor pools): the
    /// `xla` crate's handles are thread-local by construction.
    pub fn start_engine(&self, config: &Config) -> Result<ServingEngine> {
        let sample_bytes = self.sample_bytes(&config.model)?;
        let batches = self.artifact_batches(&config.model)?;
        start_engine_from_config(config.clone(), sample_bytes, batches)
    }
}

/// Start a serving engine without a pre-built Stack; every worker builds
/// its own inside its thread.
pub fn start_engine_from_config(
    config: Config,
    sample_bytes: usize,
    artifact_batches: Vec<usize>,
) -> Result<ServingEngine> {
    let workers = config.workers.max(1);
    let max_batch = config.max_batch;
    let max_delay = config.max_delay_ms;
    Ok(ServingEngine::start(
        workers,
        max_batch,
        max_delay,
        move |_worker| {
            let stack = Stack::load(&config)?;
            let strategy = stack.build_strategy(&config)?;
            Ok(BatchScheduler::new(
                strategy,
                sample_bytes,
                artifact_batches.clone(),
            ))
        },
    ))
}

/// Encrypt a plaintext image for `session` under the deployment seed —
/// the client side of the attested channel.
pub fn encrypt_request(config: &Config, session: u64, image: &[f32]) -> Vec<u8> {
    crate::enclave::Enclave::encrypt_for_session(
        &config.seed.to_le_bytes(),
        session,
        image,
    )
}

/// Deterministic synthetic image batch (structured, not white noise —
/// gradients + blocks, mirroring python/compile/data.py's spirit).
pub fn synth_images(n: usize, image: usize, channels: usize, seed: u64) -> Vec<Vec<f32>> {
    use crate::util::rng::Rng;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = Rng::with_stream(seed, i as u64);
        let mut img = vec![0f32; image * image * channels];
        // gradient background
        let horizontal = rng.below(2) == 0;
        let c0: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let c1: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
        for y in 0..image {
            for x in 0..image {
                let t = if horizontal {
                    x as f32 / image as f32
                } else {
                    y as f32 / image as f32
                };
                for c in 0..channels {
                    img[(y * image + x) * channels + c] = c0[c] * (1.0 - t) + c1[c] * t;
                }
            }
        }
        // a few random rectangles
        for _ in 0..(2 + rng.below(3)) {
            let x0 = rng.below(image as u32 - 2) as usize;
            let y0 = rng.below(image as u32 - 2) as usize;
            let w = 2 + rng.below((image / 2) as u32) as usize;
            let h = 2 + rng.below((image / 2) as u32) as usize;
            let col: Vec<f32> = (0..channels).map(|_| rng.range_f32(0.0, 1.0)).collect();
            for y in y0..(y0 + h).min(image) {
                for x in x0..(x0 + w).min(image) {
                    for c in 0..channels {
                        img[(y * image + x) * channels + c] = col[c];
                    }
                }
            }
        }
        out.push(img);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_images_structured_and_deterministic() {
        let a = synth_images(2, 16, 3, 42);
        let b = synth_images(2, 16, 3, 42);
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 16 * 16 * 3);
        assert!(a[0].iter().all(|v| (0.0..=1.0).contains(v)));
        // neighboring-pixel smoothness (structure, not noise)
        let img = &a[0];
        let mut diff = 0.0f32;
        for i in 0..(16 * 15 * 3) {
            diff += (img[i] - img[i + 3 * 16]).abs();
        }
        assert!(diff / (16.0 * 15.0 * 3.0) < 0.2);
    }
}
