//! Algorithm 1 — the paper's model-partitioning search.
//!
//! Walk the layers from the front; at each candidate p score the
//! strongest available adversary's reconstructions (SSIM).  Select the
//! first p whose SSIM falls below the threshold **and** whose next two
//! layers also stay below — the paper's guard against the "surprising
//! observation" that a pool layer can look safe while the following conv
//! recovers enough spatial structure to reconstruct again (§IV-C).

use anyhow::Result;

use super::adversary::PrivacyTable;

/// Default reconstructability threshold (paper: "stays below 0.2 for all
/// layers past layer 7").
pub const DEFAULT_THRESHOLD: f64 = 0.2;

/// Result of the partition search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Chosen partition layer p.
    pub partition: usize,
    /// Layers that individually passed but failed the look-ahead (the
    /// pool-then-conv rebound cases).
    pub rejected: Vec<(usize, String)>,
    /// (layer, worst-case ssim) trace for reporting.
    pub trace: Vec<(usize, f64)>,
}

/// Run Algorithm 1 over an offline privacy table.
pub fn search_partition(table: &PrivacyTable, threshold: f64) -> Result<SearchOutcome> {
    let mut trace = Vec::new();
    let mut rejected = Vec::new();
    let layers: Vec<usize> = table.layers.iter().map(|l| l.layer).collect();
    for (i, &p) in layers.iter().enumerate() {
        let ssim = table
            .worst_case_ssim(p)
            .ok_or_else(|| anyhow::anyhow!("missing ssim for layer {p}"))?;
        trace.push((p, ssim));
        if ssim >= threshold {
            continue;
        }
        // look-ahead: verify p+1, p+2 (when measured) also stay below
        let mut ok = true;
        for &q in layers.iter().skip(i + 1).take(2) {
            let s = table.worst_case_ssim(q).unwrap_or(0.0);
            if s >= threshold {
                rejected.push((
                    p,
                    format!("layer {q} rebounds to ssim {s:.3} >= {threshold}"),
                ));
                ok = false;
                break;
            }
        }
        if ok {
            // extend the trace through the look-ahead for reporting
            for &q in layers.iter().skip(i + 1).take(2) {
                if let Some(s) = table.worst_case_ssim(q) {
                    trace.push((q, s));
                }
            }
            return Ok(SearchOutcome {
                partition: p,
                rejected,
                trace,
            });
        }
    }
    anyhow::bail!(
        "no partition point found under threshold {threshold} — \
         adversary reconstructs everywhere measured"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::privacy::adversary::PrivacyTable;
    use std::path::PathBuf;

    fn table(rows: &[(usize, f64, Option<f64>)]) -> PrivacyTable {
        let dir = std::env::temp_dir().join(format!(
            "origami-psearch-{}-{}",
            std::process::id(),
            rows.len()
        ));
        std::fs::create_dir_all(dir.join("privacy")).unwrap();
        let body: Vec<String> = rows
            .iter()
            .map(|(l, inv, cg)| {
                let cgan = cg
                    .map(|c| format!(",\"ssim_cgan\":{c}"))
                    .unwrap_or_default();
                format!(
                    "{{\"layer\":{l},\"kind\":\"conv\",\"ssim_inversion\":{inv}{cgan}}}"
                )
            })
            .collect();
        std::fs::write(
            dir.join("privacy/ssim_by_layer.json"),
            format!("{{\"model\":\"m\",\"layers\":[{}]}}", body.join(",")),
        )
        .unwrap();
        let t = PrivacyTable::load(&dir).unwrap();
        std::fs::remove_dir_all(PathBuf::from(dir)).ok();
        t
    }

    #[test]
    fn picks_first_stable_layer() {
        let t = table(&[
            (1, 0.9, None),
            (2, 0.7, None),
            (3, 0.15, None),
            (4, 0.1, None),
            (5, 0.08, None),
        ]);
        let o = search_partition(&t, 0.2).unwrap();
        assert_eq!(o.partition, 3);
        assert!(o.rejected.is_empty());
    }

    #[test]
    fn pool_rebound_is_rejected() {
        // the paper's surprise: layer 3 (pool) looks safe, layer 4 (conv)
        // reconstructs again → must skip to layer 6
        let t = table(&[
            (1, 0.9, None),
            (2, 0.7, None),
            (3, 0.15, None),
            (4, 0.35, None),
            (5, 0.25, None),
            (6, 0.1, None),
            (7, 0.1, None),
            (8, 0.09, None),
        ]);
        let o = search_partition(&t, 0.2).unwrap();
        assert_eq!(o.partition, 6);
        assert!(o.rejected.iter().any(|(p, _)| *p == 3));
    }

    #[test]
    fn cgan_overrides_weak_inversion() {
        // inversion says layer 2 is safe but the c-GAN reconstructs it
        let t = table(&[
            (1, 0.9, None),
            (2, 0.1, Some(0.5)),
            (3, 0.1, Some(0.12)),
            (4, 0.08, None),
            (5, 0.07, None),
        ]);
        let o = search_partition(&t, 0.2).unwrap();
        assert_eq!(o.partition, 3);
    }

    #[test]
    fn fails_when_everything_reconstructs() {
        let t = table(&[(1, 0.9, None), (2, 0.8, None)]);
        assert!(search_partition(&t, 0.2).is_err());
    }
}
