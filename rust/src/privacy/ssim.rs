//! SSIM (structural similarity) in Rust — the Fig 8 metric, matching the
//! windowed-statistics definition of `python/compile/kernels/ssim.py`
//! (non-overlapping 8x8 windows, K1=0.01, K2=0.03, dynamic range 1).

const C1: f32 = 0.01 * 0.01;
const C2: f32 = 0.03 * 0.03;

/// Mean SSIM between two NHWC image batches in [0,1].
pub fn mean_ssim(x: &[f32], y: &[f32], n: usize, h: usize, w: usize, c: usize) -> f32 {
    mean_ssim_win(x, y, n, h, w, c, 8)
}

/// Mean SSIM with an explicit window size.
pub fn mean_ssim_win(
    x: &[f32],
    y: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    win: usize,
) -> f32 {
    assert_eq!(x.len(), n * h * w * c, "x shape mismatch");
    assert_eq!(y.len(), x.len(), "y shape mismatch");
    assert!(h % win == 0 && w % win == 0, "spatial dims not divisible");
    let gh = h / win;
    let gw = w / win;
    let mut total = 0.0f64;
    let mut count = 0u64;
    let area = (win * win) as f32;
    for b in 0..n {
        for wy in 0..gh {
            for wx in 0..gw {
                for ch in 0..c {
                    let (mut sx, mut sy, mut sxx, mut syy, mut sxy) =
                        (0.0f32, 0.0, 0.0, 0.0, 0.0);
                    for dy in 0..win {
                        for dx in 0..win {
                            let yy = wy * win + dy;
                            let xx = wx * win + dx;
                            let idx = ((b * h + yy) * w + xx) * c + ch;
                            let (a, bb) = (x[idx], y[idx]);
                            sx += a;
                            sy += bb;
                            sxx += a * a;
                            syy += bb * bb;
                            sxy += a * bb;
                        }
                    }
                    let mx = sx / area;
                    let my = sy / area;
                    let vx = sxx / area - mx * mx;
                    let vy = syy / area - my * my;
                    let cov = sxy / area - mx * my;
                    let lum = (2.0 * mx * my + C1) / (mx * mx + my * my + C1);
                    let s = (2.0 * cov + C2) / (vx + vy + C2);
                    total += (lum * s) as f64;
                    count += 1;
                }
            }
        }
    }
    (total / count as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_images_score_one() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..2 * 16 * 16 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let s = mean_ssim(&x, &x, 2, 16, 16, 3);
        assert!((s - 1.0).abs() < 1e-5, "{s}");
    }

    #[test]
    fn independent_noise_scores_low() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let y: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let s = mean_ssim(&x, &y, 1, 32, 32, 3);
        assert!(s < 0.3, "{s}");
    }

    #[test]
    fn monotone_in_noise() {
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..32 * 32).map(|i| (i / 32) as f32 / 32.0).collect();
        let mk = |sigma: f32, rng: &mut Rng| -> Vec<f32> {
            x.iter()
                .map(|v| (v + sigma * rng.normal() as f32).clamp(0.0, 1.0))
                .collect()
        };
        let near = mk(0.02, &mut rng);
        let far = mk(0.5, &mut rng);
        let s_near = mean_ssim(&x, &near, 1, 32, 32, 1);
        let s_far = mean_ssim(&x, &far, 1, 32, 32, 1);
        assert!(s_near > s_far, "{s_near} vs {s_far}");
    }

    #[test]
    fn symmetry() {
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..16 * 16).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let y: Vec<f32> = (0..16 * 16).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let a = mean_ssim(&x, &y, 1, 16, 16, 1);
        let b = mean_ssim(&y, &x, 1, 16, 16, 1);
        assert!((a - b).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_checked() {
        mean_ssim(&[0.0; 10], &[0.0; 10], 1, 8, 8, 1);
    }
}
