//! Rust-side adversary runner: execute a *trained* c-GAN generator
//! (exported by `python -m compile.privacy_experiment` as an HLO
//! artifact) against intermediate feature maps, entirely inside the
//! coordinator — partition search needs no Python at run time.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::runtime::PjrtClient;
use crate::util::json;

/// One per-layer row from `artifacts/privacy/ssim_by_layer.json`.
#[derive(Debug, Clone)]
pub struct LayerPrivacy {
    pub layer: usize,
    pub kind: String,
    pub ssim_inversion: f64,
    pub ssim_cgan: Option<f64>,
    /// Relative path of the exported generator HLO, if trained.
    pub generator_artifact: Option<String>,
    pub generator_input_shape: Option<Vec<usize>>,
}

/// The offline privacy-experiment results.
#[derive(Debug, Clone)]
pub struct PrivacyTable {
    pub model: String,
    pub layers: Vec<LayerPrivacy>,
    root: PathBuf,
}

impl PrivacyTable {
    /// Load from `<artifacts>/privacy/ssim_by_layer.json`.
    pub fn load(artifacts_root: &Path) -> Result<Self> {
        let path = artifacts_root.join("privacy").join("ssim_by_layer.json");
        let doc = json::from_file(&path).with_context(|| {
            format!(
                "loading {} — run `python -m compile.privacy_experiment` first",
                path.display()
            )
        })?;
        let mut layers = Vec::new();
        for row in doc.req("layers")?.as_arr().unwrap_or(&[]) {
            layers.push(LayerPrivacy {
                layer: row.req("layer")?.as_usize().unwrap_or(0),
                kind: row
                    .get("kind")
                    .and_then(|v| v.as_str())
                    .unwrap_or("?")
                    .to_string(),
                ssim_inversion: row
                    .req("ssim_inversion")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad ssim"))?,
                ssim_cgan: row.get("ssim_cgan").and_then(|v| v.as_f64()),
                generator_artifact: row
                    .get("generator_artifact")
                    .and_then(|v| v.as_str())
                    .map(String::from),
                generator_input_shape: row
                    .get("generator_input_shape")
                    .and_then(|v| v.as_usize_vec().ok()),
            });
        }
        anyhow::ensure!(!layers.is_empty(), "privacy table is empty");
        Ok(Self {
            model: doc
                .req("model")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            layers,
            root: artifacts_root.to_path_buf(),
        })
    }

    pub fn row(&self, layer: usize) -> Option<&LayerPrivacy> {
        self.layers.iter().find(|l| l.layer == layer)
    }

    /// Strongest observed adversary score at a layer (max of adversaries).
    pub fn worst_case_ssim(&self, layer: usize) -> Option<f64> {
        self.row(layer)
            .map(|r| r.ssim_cgan.map_or(r.ssim_inversion, |c| c.max(r.ssim_inversion)))
    }
}

/// A loaded c-GAN generator: feature map → reconstructed image batch.
pub struct GeneratorRunner {
    exe: xla::PjRtLoadedExecutable,
    pub input_shape: Vec<usize>,
}

impl GeneratorRunner {
    /// Compile a generator artifact for native reconstruction.
    pub fn load(client: &PjrtClient, table: &PrivacyTable, layer: usize) -> Result<Self> {
        let row = table
            .row(layer)
            .ok_or_else(|| anyhow!("no privacy row for layer {layer}"))?;
        let rel = row
            .generator_artifact
            .as_ref()
            .ok_or_else(|| anyhow!("no trained generator for layer {layer}"))?;
        let shape = row
            .generator_input_shape
            .clone()
            .ok_or_else(|| anyhow!("generator input shape missing"))?;
        let exe = client.compile_hlo_text(&table.root.join(rel))?;
        Ok(Self {
            exe,
            input_shape: shape,
        })
    }

    /// Reconstruct images from feature maps (flattened NHWC f32).
    pub fn reconstruct(&self, client: &PjrtClient, feats: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            feats.len() == self.input_shape.iter().product::<usize>(),
            "feature length {} vs generator input {:?}",
            feats.len(),
            self.input_shape
        );
        client.run_f32(&self.exe, &[(feats, &self.input_shape)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_parses_minimal_doc() {
        let dir = std::env::temp_dir().join(format!("origami-priv-{}", std::process::id()));
        std::fs::create_dir_all(dir.join("privacy")).unwrap();
        std::fs::write(
            dir.join("privacy/ssim_by_layer.json"),
            r#"{"model":"m","layers":[
                {"layer":1,"kind":"conv","ssim_inversion":0.9},
                {"layer":3,"kind":"pool","ssim_inversion":0.2,"ssim_cgan":0.35}
            ]}"#,
        )
        .unwrap();
        let t = PrivacyTable::load(&dir).unwrap();
        assert_eq!(t.model, "m");
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.worst_case_ssim(1), Some(0.9));
        // worst case takes the max of the adversaries
        assert_eq!(t.worst_case_ssim(3), Some(0.35));
        assert!(t.row(9).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_table_is_actionable_error() {
        let err = PrivacyTable::load(Path::new("/nonexistent-xyz")).unwrap_err();
        assert!(format!("{err:#}").contains("privacy_experiment"));
    }
}
