//! Privacy evaluation: SSIM scoring, the c-GAN adversary runner, and the
//! paper's Algorithm 1 partition search.

pub mod adversary;
pub mod partition_search;
pub mod ssim;

pub use partition_search::{search_partition, SearchOutcome};
pub use ssim::mean_ssim;
